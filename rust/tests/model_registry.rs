//! Multi-model registry coverage: several (network, config) entries
//! behind one coordinator, requests pinned to the model they name, and
//! zero-downtime hot swaps.
//!
//! * cross-model exactness: every paper config registered as its own
//!   model in a single coordinator, hit by interleaved concurrent
//!   producers — each reply must be bit-identical to *its own* model's
//!   `golden::forward`, never a neighbour's;
//! * unknown models answer a typed refusal and leave the pool serving;
//! * a hot swap under load never fails a request: pre-swap admissions
//!   drain on the plan they were admitted under, post-swap admissions
//!   run the new weights, and the accounting identity
//!   `submitted == completed + failed + refused` holds across the swap.

use std::sync::Arc;
use std::time::Duration;

use binarray::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::{ArrayConfig, PAPER_CONFIGS};
use binarray::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, InferError, InferRequest, ModelId, ModelRegistry,
};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256};

/// The stress-suite's tiny-but-complete net (conv+pool, two dense):
/// each call draws fresh weights, so successive calls give *different*
/// models with the same 10×10×3 input geometry — ideal for proving
/// requests land on the model they named.
fn tiny_net(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 2;
    let conv = QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, 4 * m * 3 * 3 * 3),
        alpha_q: (0..4 * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..4).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d: 4,
        m,
        kh: 3,
        kw: 3,
        c: 3,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool: 2,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, n_in: usize, relu: bool| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * n_in),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    };
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![conv, dense(rng, 8, 64, true), dense(rng, 5, 8, false)],
    };
    assert_eq!(binarray::isa::compiler::infer_input_dims(&net), (10, 10, 3));
    (net, Shape::new(10, 10, 3))
}

fn cfg(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        array: ArrayConfig::new(1, 8, 2),
        workers,
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_micros(200),
        },
        ..Default::default()
    }
}

/// All four paper configs as distinct models in one coordinator, one
/// producer thread per model submitting concurrently: interleaving is
/// a scheduling concern, never an arithmetic one.
#[test]
fn every_paper_config_serves_its_own_model_bit_exactly() {
    let mut rng = Xoshiro256::new(0xC0DE);
    let registry = Arc::new(ModelRegistry::new(2));
    // (id, image, want) per paper config — fresh weights each, so a
    // reply computed by the wrong model cannot match its golden
    let mut models = Vec::new();
    for (i, array) in PAPER_CONFIGS.into_iter().enumerate() {
        let (net, shape) = tiny_net(&mut rng);
        let image = prop::i8_vec(&mut rng, shape.len());
        let want = golden::forward(&net, &image, shape, None);
        let id = registry
            .register(&format!("paper-{i}"), array, net, 0)
            .expect("every paper config must register");
        models.push((id, image, want));
    }
    let coord = Coordinator::with_registry(cfg(2), Arc::clone(&registry)).unwrap();
    let per_model = 12usize;
    std::thread::scope(|s| {
        for (id, image, want) in &models {
            let h = coord.handle();
            s.spawn(move || {
                for i in 0..per_model {
                    let reply = h
                        .infer(InferRequest::new(image.clone()).model(*id))
                        .expect("interleaved multi-model traffic is served");
                    assert_eq!(&reply.logits, want, "model {id:?} frame {i}");
                }
            });
        }
    });
    let m = coord.shutdown();
    let total = (models.len() * per_model) as u64;
    assert_eq!(m.submitted, total);
    assert_eq!(m.completed, total);
    assert_eq!(m.failed, 0);
    assert_eq!(m.admission_refused, 0);
    // per-model counters saw exactly their own slice of the traffic
    for (id, _, _) in &models {
        let s = &m.models[&id.0];
        assert_eq!(s.submitted, per_model as u64, "model {id:?}");
        assert_eq!(s.completed, per_model as u64, "model {id:?}");
        assert_eq!(s.latency.count(), per_model, "model {id:?}");
    }
}

/// A request naming a slot the registry does not serve is answered with
/// the typed `UnknownModel` refusal — counted into the admission
/// identity, never a dropped receiver — and the pool keeps serving.
#[test]
fn unknown_model_is_a_typed_refusal_not_a_fault() {
    let mut rng = Xoshiro256::new(0x0D0);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let registry = Arc::new(ModelRegistry::new(1));
    registry.register("only", ArrayConfig::new(1, 8, 2), net, 0).unwrap();
    let coord = Coordinator::with_registry(cfg(1), Arc::clone(&registry)).unwrap();
    let err = coord
        .infer(InferRequest::new(image.clone()).model(ModelId(7)))
        .expect_err("slot 7 is not registered");
    let ie: InferError = err.downcast().expect("typed InferError");
    assert!(matches!(ie, InferError::UnknownModel { .. }), "got {ie:?}");
    assert!(ie.is_refused(), "unknown models count as refusals");
    let ok = coord.infer(InferRequest::new(image)).unwrap();
    assert_eq!(ok.logits, want, "the pool still serves the known model");
    let m = coord.shutdown();
    assert_eq!(m.submitted, 2);
    assert_eq!(m.completed, 1);
    assert_eq!(m.admission_refused, 1);
    assert_eq!(m.completed + m.failed + m.admission_refused, m.submitted);
}

/// Zero-downtime hot swap: traffic in flight when `swap` publishes new
/// weights drains on the plan it was admitted under, everything
/// admitted after the swap runs the new weights, and no request is
/// failed or refused because of the swap.
#[test]
fn hot_swap_under_load_never_fails_a_request() {
    let mut rng = Xoshiro256::new(0x5A17);
    let (net_a, shape) = tiny_net(&mut rng);
    let (net_b, _) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want_a = golden::forward(&net_a, &image, shape, None);
    let want_b = golden::forward(&net_b, &image, shape, None);
    assert_ne!(want_a, want_b, "the two generations must be tellable apart");
    let registry = Arc::new(ModelRegistry::new(2));
    let id = registry.register("live", ArrayConfig::new(1, 8, 2), net_a, 0).unwrap();
    let coord = Coordinator::with_registry(cfg(2), Arc::clone(&registry)).unwrap();
    let h = coord.handle();
    let total = 64usize;
    let swap_at = total / 2;
    let mut rxs = Vec::with_capacity(total);
    for i in 0..total {
        if i == swap_at {
            // compile + publish the replacement while the pool is busy;
            // the slot id survives, the epoch bumps
            let swapped = registry
                .swap("live", ArrayConfig::new(1, 32, 2), net_b.clone())
                .expect("hot swap");
            assert_eq!(swapped, id, "a swap keeps the slot id");
        }
        rxs.push(h.submit(InferRequest::new(image.clone())));
    }
    let (mut served_a, mut served_b) = (0u64, 0u64);
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .recv()
            .expect("answered, not dropped")
            .unwrap_or_else(|e| panic!("frame {i} failed across the swap: {e}"));
        if reply.logits == want_a {
            served_a += 1;
        } else if reply.logits == want_b {
            served_b += 1;
            // old weights can only appear on pre-swap admissions
        } else {
            panic!("frame {i} matches neither generation's golden");
        }
        // everything submitted after `swap` returned must run new weights
        if i >= swap_at {
            assert_eq!(reply.logits, want_b, "post-swap frame {i} served stale weights");
        }
    }
    assert!(served_b >= (total - swap_at) as u64, "the new generation took over");
    assert_eq!(served_a + served_b, total as u64, "every frame answered exactly once");
    let m = coord.shutdown();
    assert_eq!(m.submitted, total as u64);
    assert_eq!(m.completed, total as u64, "a swap never fails in-flight work");
    assert_eq!(m.failed, 0);
    assert_eq!(m.admission_refused, 0);
    assert_eq!(m.completed + m.failed + m.admission_refused, m.submitted);
    // the slot's counters span both epochs under one id
    let s = &m.models[&id.0];
    assert_eq!(s.submitted, total as u64);
    assert_eq!(s.completed, total as u64);
}
