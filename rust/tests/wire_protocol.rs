//! The TCP wire front-end, end to end: logits served over a real socket
//! are bit-identical to `golden::forward` for every paper array config ×
//! accuracy mode; malformed frames (bad magic/version, dims/length
//! mismatch, oversized length prefixes) are answered `BadRequest` and
//! never reach the coordinator; truncated headers and mid-frame
//! disconnects close cleanly without orphaning work; random garbage
//! never kills the server; and concurrent connections survive a drain
//! with every in-flight request answered.  The accounting identity
//! (`submitted == completed + failed + refused`) is re-checked across
//! the wire boundary on every run.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use binarray::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::ArrayConfig;
use binarray::coordinator::wire::{MAGIC, MAX_PAYLOAD, REQ_HEADER_LEN, RESP_HEADER_LEN, VERSION};
use binarray::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Metrics, Mode, RoutePolicy, ServiceClass,
    WireClient, WireServer, WireStatus,
};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256};

/// Tiny conv+dense net with M=4 binary levels, so the two accuracy modes
/// genuinely differ on M_arch=2 hardware (high-throughput truncates to 2
/// levels; a net with M == M_arch would make the mode sweep vacuous).
fn tiny_net_m4(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 4;
    let conv = QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, 4 * m * 3 * 3 * 3),
        alpha_q: (0..4 * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..4).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d: 4,
        m,
        kh: 3,
        kw: 3,
        c: 3,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool: 2,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, n_in: usize, relu: bool| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * n_in),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    };
    // 10×10×3 → conv3 → 8×8×4 → pool2 → 4×4×4 → dense 8 → dense 5
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![conv, dense(rng, 8, 64, true), dense(rng, 5, 8, false)],
    };
    assert_eq!(binarray::isa::compiler::infer_input_dims(&net), (10, 10, 3));
    (net, Shape::new(10, 10, 3))
}

const DIMS: (u16, u16, u16) = (10, 10, 3);

fn cfg(array: ArrayConfig, workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        array,
        workers,
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_micros(200),
        },
        route: RoutePolicy::BatchOnly,
        ..Default::default()
    }
}

/// Start a coordinator + wire server pair on an ephemeral port.
fn serve(array: ArrayConfig, workers: usize, net: QuantNetwork) -> (Coordinator, WireServer) {
    let coord = Coordinator::start(cfg(array, workers), net).unwrap();
    let wire = WireServer::start(
        "127.0.0.1:0",
        coord.handle(),
        std::sync::Arc::clone(&coord.metrics),
    )
    .unwrap();
    (coord, wire)
}

/// Drain wire-then-coordinator (the required order) and hand back the
/// final metrics ledger.
fn drain(coord: Coordinator, wire: WireServer) -> Metrics {
    wire.shutdown();
    coord.shutdown()
}

fn assert_identity(m: &Metrics) {
    assert_eq!(
        m.submitted,
        m.completed + m.failed + m.admission_refused,
        "submitted = completed + failed + refused must hold across the wire \
         (submitted {}, completed {}, failed {}, refused {})",
        m.submitted,
        m.completed,
        m.failed,
        m.admission_refused
    );
}

/// A raw request header the tests can deliberately corrupt — built by
/// hand so nothing in the client library "helpfully" fixes it first.
#[allow(clippy::too_many_arguments)]
fn raw_header(
    magic: [u8; 4],
    version: u8,
    mode: u8,
    service: u8,
    reserved: u8,
    id: u64,
    deadline_us: u64,
    payload_len: u32,
    dims: (u16, u16, u16),
) -> [u8; REQ_HEADER_LEN] {
    let mut b = [0u8; REQ_HEADER_LEN];
    b[0..4].copy_from_slice(&magic);
    b[4] = version;
    b[5] = mode;
    b[6] = service;
    b[7] = reserved;
    b[8..16].copy_from_slice(&id.to_le_bytes());
    b[16..24].copy_from_slice(&deadline_us.to_le_bytes());
    b[24..28].copy_from_slice(&payload_len.to_le_bytes());
    b[28..30].copy_from_slice(&dims.0.to_le_bytes());
    b[30..32].copy_from_slice(&dims.1.to_le_bytes());
    b[32..34].copy_from_slice(&dims.2.to_le_bytes());
    b
}

/// Read one raw response: (status byte, echoed id, payload length).
fn read_raw_response(stream: &mut TcpStream) -> (u8, u64, u32) {
    let mut head = [0u8; RESP_HEADER_LEN];
    stream.read_exact(&mut head).expect("response header");
    assert_eq!(head[0..4], MAGIC, "response magic");
    assert_eq!(head[4], VERSION, "response version");
    let id = u64::from_le_bytes(head[8..16].try_into().unwrap());
    let len = u32::from_le_bytes(head[24..28].try_into().unwrap());
    let mut payload = vec![0u8; len as usize];
    stream.read_exact(&mut payload).expect("response payload");
    (head[5], id, len)
}

/// Logits over the socket are byte-identical to the golden model for
/// every paper array config in both accuracy modes — the wire front-end
/// moves bytes, never semantics.
#[test]
fn wire_serves_golden_logits_for_every_config_and_mode() {
    let mut rng = Xoshiro256::new(0x3172E);
    let (net, shape) = tiny_net_m4(&mut rng);
    let images: Vec<Vec<i8>> = (0..3).map(|_| prop::i8_vec(&mut rng, shape.len())).collect();
    for array in [
        ArrayConfig::new(1, 8, 2),
        ArrayConfig::new(1, 32, 2),
        ArrayConfig::new(4, 32, 4),
    ] {
        for mode in [Mode::HighAccuracy, Mode::HighThroughput] {
            let m_run = match mode {
                Mode::HighAccuracy => None,
                Mode::HighThroughput => Some(mode.m_run(net.max_m(), array.m_arch)),
            };
            let (coord, wire) = serve(array, 2, net.clone());
            let mut client = WireClient::connect(wire.local_addr()).unwrap();
            for (i, image) in images.iter().enumerate() {
                let reply = client
                    .request(i as u64, mode, ServiceClass::Standard, 0, DIMS, image)
                    .unwrap();
                assert_eq!(reply.id, i as u64, "id echoed");
                assert_eq!(reply.status, WireStatus::Ok, "served ({array:?}, {mode:?})");
                assert_eq!(
                    reply.logits,
                    golden::forward(&net, image, shape, m_run),
                    "wire logits diverged from golden ({array:?}, {mode:?}, frame {i})"
                );
            }
            drop(client);
            let m = drain(coord, wire);
            assert_eq!(m.wire_requests, images.len() as u64);
            assert_eq!(m.wire_protocol_errors, 0);
            assert_eq!(m.completed, images.len() as u64);
            assert_identity(&m);
        }
    }
}

/// Every malformed-header shape is answered `BadRequest` (with the id
/// echoed whenever the id bytes could be trusted) and the connection is
/// closed; none of them ever reaches the coordinator.
#[test]
fn malformed_frames_get_bad_request_and_never_reach_the_coordinator() {
    let mut rng = Xoshiro256::new(0xBAD);
    let (net, shape) = tiny_net_m4(&mut rng);
    let (coord, wire) = serve(ArrayConfig::new(1, 8, 2), 1, net);
    let addr = wire.local_addr();
    let good_len = shape.len() as u32;

    let cases: Vec<(&str, [u8; REQ_HEADER_LEN], u64)> = vec![
        (
            "bad magic",
            raw_header(*b"XNRY", VERSION, 0, 1, 0, 7, 0, good_len, DIMS),
            0, // nothing after a bad magic is trusted, id echoes as 0
        ),
        (
            "bad version",
            raw_header(MAGIC, 9, 0, 1, 0, 8, 0, good_len, DIMS),
            8,
        ),
        (
            "unknown mode",
            raw_header(MAGIC, VERSION, 5, 1, 0, 9, 0, good_len, DIMS),
            9,
        ),
        (
            "reserved byte set",
            raw_header(MAGIC, VERSION, 0, 1, 1, 10, 0, good_len, DIMS),
            10,
        ),
        (
            "oversized length prefix",
            raw_header(MAGIC, VERSION, 0, 1, 0, 11, 0, MAX_PAYLOAD + 1, DIMS),
            11,
        ),
        (
            "dims/length mismatch",
            raw_header(MAGIC, VERSION, 0, 1, 0, 12, 0, good_len - 1, DIMS),
            12,
        ),
    ];
    let n_cases = cases.len() as u64;
    for (what, header, want_id) in cases {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(&header).unwrap();
        stream.flush().unwrap();
        let (status, id, len) = read_raw_response(&mut stream);
        assert_eq!(status, WireStatus::BadRequest as u8, "{what}: BadRequest");
        assert_eq!(id, want_id, "{what}: echoed id");
        assert_eq!(len, 0, "{what}: no payload on a reject");
        // the connection is closed after the reject — framing is untrusted
        let mut probe = [0u8; 1];
        assert_eq!(stream.read(&mut probe).unwrap(), 0, "{what}: closed after reject");
    }

    let m = drain(coord, wire);
    assert_eq!(m.wire_protocol_errors, n_cases, "every case counted");
    assert_eq!(m.wire_requests, 0, "nothing reached the coordinator");
    assert_eq!(m.submitted, 0);
    assert_identity(&m);
}

/// Truncated headers and mid-frame disconnects (header sent, payload cut
/// short) close cleanly: no reply owed, nothing submitted, no protocol
/// error counted (the peer vanished; there was no frame to judge), and
/// the server keeps serving other connections.
#[test]
fn truncated_and_midframe_disconnects_orphan_nothing() {
    let mut rng = Xoshiro256::new(0x7C);
    let (net, shape) = tiny_net_m4(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let (coord, wire) = serve(ArrayConfig::new(1, 8, 2), 1, net);
    let addr = wire.local_addr();

    // half a header, then gone
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        let header = raw_header(MAGIC, VERSION, 0, 1, 0, 1, 0, shape.len() as u32, DIMS);
        stream.write_all(&header[..10]).unwrap();
        stream.flush().unwrap();
    }
    // a full, valid header — then only half the payload, then gone
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        let header = raw_header(MAGIC, VERSION, 0, 1, 0, 2, 0, shape.len() as u32, DIMS);
        stream.write_all(&header).unwrap();
        stream.write_all(&vec![0u8; shape.len() / 2]).unwrap();
        stream.flush().unwrap();
    }
    // the server is still fully alive for a well-behaved client
    let mut client = WireClient::connect(addr).unwrap();
    let reply = client
        .request(3, Mode::HighAccuracy, ServiceClass::Standard, 0, DIMS, &image)
        .unwrap();
    assert_eq!(reply.status, WireStatus::Ok);
    assert_eq!(reply.logits, want);
    drop(client);

    let m = drain(coord, wire);
    assert_eq!(m.wire_requests, 1, "only the whole frame was submitted");
    assert_eq!(
        m.wire_protocol_errors, 0,
        "a vanished peer is not a protocol error — there was no frame to judge"
    );
    assert_eq!(m.completed, 1);
    assert_identity(&m);
}

/// Random garbage — wrong lengths, wrong bytes, abrupt closes — must
/// never panic a connection thread or wedge the server.
#[test]
fn fuzzed_garbage_never_kills_the_server() {
    let mut rng = Xoshiro256::new(0xF022);
    let (net, shape) = tiny_net_m4(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let (coord, wire) = serve(ArrayConfig::new(1, 8, 2), 1, net);
    let addr = wire.local_addr();

    for _ in 0..24 {
        let n = rng.below(3 * REQ_HEADER_LEN as u64) as usize;
        let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let mut stream = TcpStream::connect(addr).unwrap();
        let _ = stream.write_all(&junk);
        let _ = stream.flush();
        // drain whatever the server says (BadRequest or nothing); short
        // timeout — junk below a full header gets silence, not a reply
        let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
        let mut sink = Vec::new();
        let _ = stream.read_to_end(&mut sink);
    }

    // still serving, still golden
    let mut client = WireClient::connect(addr).unwrap();
    let reply = client
        .request(1, Mode::HighAccuracy, ServiceClass::Standard, 0, DIMS, &image)
        .unwrap();
    assert_eq!(reply.status, WireStatus::Ok);
    assert_eq!(reply.logits, want);
    drop(client);

    let m = drain(coord, wire);
    assert_eq!(m.completed, 1, "exactly the one real frame computed");
    assert_identity(&m);
}

/// Drain under concurrent connections: every request sent before or
/// during the drain is answered exactly once — `Ok` (it made it in) or
/// `Draining` (it arrived too late) — and the listener refuses new work
/// afterwards.  No reply is ever silently dropped.
#[test]
fn concurrent_connections_survive_drain_with_every_request_answered() {
    let mut rng = Xoshiro256::new(0xD8A1);
    let (net, shape) = tiny_net_m4(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let (coord, wire) = serve(ArrayConfig::new(1, 8, 2), 2, net);
    let addr = wire.local_addr();
    let n_conns = 4usize;

    let mut clients: Vec<WireClient> = (0..n_conns)
        .map(|_| WireClient::connect(addr).unwrap())
        .collect();
    // one settled round-trip per connection before the drain starts
    for (i, c) in clients.iter_mut().enumerate() {
        let reply = c
            .request(i as u64, Mode::HighAccuracy, ServiceClass::Standard, 0, DIMS, &image)
            .unwrap();
        assert_eq!(reply.status, WireStatus::Ok);
        assert_eq!(reply.logits, want);
    }

    // now race a second request on every connection against shutdown
    let outcomes = std::thread::scope(|s| {
        let handles: Vec<_> = clients
            .into_iter()
            .enumerate()
            .map(|(i, mut c)| {
                let img = image.clone();
                s.spawn(move || {
                    c.request(
                        (100 + i) as u64,
                        Mode::HighAccuracy,
                        ServiceClass::Standard,
                        0,
                        DIMS,
                        &img,
                    )
                })
            })
            .collect();
        wire.shutdown();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect::<Vec<_>>()
    });

    let mut served = 0u64;
    for out in outcomes {
        match out {
            Ok(reply) => match reply.status {
                WireStatus::Ok => {
                    assert_eq!(reply.logits, want, "drained reply still golden");
                    served += 1;
                }
                WireStatus::Draining => assert!(reply.logits.is_empty()),
                other => panic!("unexpected drain-race status {other:?}"),
            },
            // the drain closed the connection before the frame's first
            // byte was read: the client sees a clean EOF and nothing was
            // submitted — allowed, the frame never began processing
            Err(_) => {}
        }
    }

    // post-drain the port is dead: either the dial or the round-trip fails
    let refused = match WireClient::connect(addr) {
        Err(_) => true,
        Ok(mut c) => c
            .request(999, Mode::HighAccuracy, ServiceClass::Standard, 0, DIMS, &image)
            .is_err(),
    };
    assert!(refused, "the drained listener must not serve new work");

    let m = coord.shutdown();
    assert_eq!(
        m.wire_requests,
        n_conns as u64 + served,
        "wire_requests counts exactly the submitted frames"
    );
    assert_eq!(m.completed, n_conns as u64 + served);
    assert_eq!(m.wire_protocol_errors, 0);
    assert_identity(&m);
}
