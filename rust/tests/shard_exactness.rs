//! Bit-exactness matrix for cross-card sharding: for every paper
//! `ArrayConfig`, both runtime accuracy `Mode`s, and every worker-card
//! count under test, a frame served through the sharded scatter/gather
//! coordinator must be logit-identical to the unsharded `run_frames`
//! path and to the bit-accurate `golden::forward` model.  Neither the
//! row-tile split, the per-layer gather order, nor the card count may
//! ever change an output byte — and adding cards must never *increase*
//! the simulated frame latency.
//!
//! Card counts come from `BINARRAY_TEST_CARDS` (default `1,2,4`) so the
//! CI matrix genuinely exercises the widths it claims to cover.

use binarray::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::{BinArraySystem, PAPER_CONFIGS};
use binarray::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, InferRequest, Mode, RoutePolicy,
};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256, test_cards};

/// The structurally complete small net of the plan/execute suite: two
/// conv layers (pooled + ReLU-only), two dense layers, M = 4 so the two
/// accuracy modes differ on every paper config.
fn small_net(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 4;
    let conv = |rng: &mut Xoshiro256, d: usize, c: usize, pool: usize| QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, d * m * 3 * 3 * c),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-300, 300) as i32).collect(),
        d,
        m,
        kh: 3,
        kw: 3,
        c,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 8,
        relu: true,
        pool,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, nin: usize, relu: bool| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * nin),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-300, 300) as i32).collect(),
        d,
        m,
        kh: nin,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 7,
        relu,
        pool: 1,
        stride: 1,
    };
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![
            conv(rng, 6, 3, 2),  // 14×14×3 → 12×12×6 → pool2 → 6×6×6
            conv(rng, 10, 6, 1), // 6×6×6 → 4×4×10 (ReLU, no pooling)
            dense(rng, 20, 160, true),
            dense(rng, 7, 20, false),
        ],
    };
    assert_eq!(binarray::isa::compiler::infer_input_dims(&net), (14, 14, 3));
    (net, Shape::new(14, 14, 3))
}

#[test]
fn sharded_equals_unsharded_equals_golden_all_configs_modes_cards() {
    let mut rng = Xoshiro256::new(0xE8AC7);
    let (net, shape) = small_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    // sorted so the "more cards is never slower" assertion stays
    // meaningful whatever order the matrix lists the counts in
    let mut card_counts = test_cards();
    card_counts.sort_unstable();
    for cfg in PAPER_CONFIGS {
        let mut direct = BinArraySystem::new(cfg, net.clone()).unwrap();
        for mode in [Mode::HighAccuracy, Mode::HighThroughput] {
            let m_run = match mode {
                Mode::HighAccuracy => None,
                Mode::HighThroughput => Some(cfg.m_arch.min(net.max_m())),
            };
            let want = golden::forward(&net, &image, shape, m_run);
            direct.set_mode(m_run);
            let (unsharded, direct_stats) = direct.run_frame(&image).unwrap();
            assert_eq!(unsharded, want, "unsharded {} {mode:?} != golden", cfg.label());
            let mut prev_cycles = u64::MAX;
            for &cards in &card_counts {
                let coord = Coordinator::start(
                    CoordinatorConfig {
                        array: cfg,
                        workers: cards,
                        policy: BatchPolicy::default(),
                        route: RoutePolicy::ShardOnly,
                        max_shard_cards: cards,
                        ..Default::default()
                    },
                    net.clone(),
                )
                .unwrap();
                let reply = coord.infer(InferRequest::new(image.clone()).mode(mode)).unwrap();
                assert_eq!(
                    reply.logits,
                    want,
                    "sharded {} {mode:?} over {cards} cards != golden",
                    cfg.label()
                );
                // the single-card shard runs the exact parent schedule —
                // same layer walls, same CU cycles
                if cards == 1 {
                    assert_eq!(
                        reply.cycles,
                        direct_stats.cycles,
                        "1-card shard cycles drifted from unsharded ({} {mode:?})",
                        cfg.label()
                    );
                }
                // more cards must never cost simulated latency
                assert!(
                    reply.cycles <= prev_cycles,
                    "{} {mode:?}: {cards} cards took {} cycles > {prev_cycles}",
                    cfg.label(),
                    reply.cycles
                );
                prev_cycles = reply.cycles;
                let m = coord.shutdown();
                assert_eq!(m.completed, 1);
                assert_eq!(m.failed, 0);
            }
        }
    }
}
