//! Multi-producer stress coverage for the coordinator: N threads
//! submitting concurrently across both accuracy modes, small batch
//! limits, shutdown under load, and the sharded scatter/gather path under
//! the same concurrency.  The single-producer happy paths live in
//! `coordinator::server`'s unit tests; everything here is about what the
//! concurrent machine does when several clients lean on it at once.
//!
//! Pool widths come from `BINARRAY_TEST_CARDS` (default `1,2,4`) so the
//! CI matrix exercises lane arbitration at every width it claims.

use std::time::Duration;

use binarray::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::ArrayConfig;
use binarray::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, InferRequest, Mode, RoutePolicy,
};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256, test_cards};

/// A deliberately tiny but structurally complete net (conv+pool, two
/// dense) so stress tests push *request counts*, not frame compute.
fn tiny_net(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 2;
    let conv = QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, 4 * m * 3 * 3 * 3),
        alpha_q: (0..4 * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..4).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d: 4,
        m,
        kh: 3,
        kw: 3,
        c: 3,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool: 2,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, n_in: usize, relu: bool| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * n_in),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    };
    // 10×10×3 → conv3 → 8×8×4 → pool2 → 4×4×4 → dense 8 → dense 5
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![conv, dense(rng, 8, 64, true), dense(rng, 5, 8, false)],
    };
    assert_eq!(binarray::isa::compiler::infer_input_dims(&net), (10, 10, 3));
    (net, Shape::new(10, 10, 3))
}

#[test]
fn concurrent_producers_all_replied_ids_unique_metrics_consistent() {
    let mut rng = Xoshiro256::new(0x57E55);
    let (net, shape) = tiny_net(&mut rng);
    let producers = 4usize;
    let per_producer = 24usize;
    let total = (producers * per_producer) as u64;
    for workers in test_cards() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                array: ArrayConfig::new(2, 8, 2),
                workers,
                policy: BatchPolicy {
                    max_batch: 3,
                    max_delay: Duration::from_micros(200),
                },
                route: RoutePolicy::BatchOnly,
                max_shard_cards: 0,
                ..Default::default()
            },
            net.clone(),
        )
        .unwrap();

        let mut ids: Vec<u64> = Vec::new();
        std::thread::scope(|s| {
            let threads: Vec<_> = (0..producers)
                .map(|p| {
                    let h = coord.handle();
                    let mut prng = Xoshiro256::new(p as u64 + 1);
                    let image = prop::i8_vec(&mut prng, shape.len());
                    s.spawn(move || {
                        let mut got = Vec::with_capacity(per_producer);
                        for i in 0..per_producer {
                            let mode = if (p + i) % 2 == 0 {
                                Mode::HighAccuracy
                            } else {
                                Mode::HighThroughput
                            };
                            let reply = h
                                .submit(InferRequest::new(image.clone()).mode(mode))
                                .recv()
                                .expect("live channel")
                                .expect("successful inference");
                            assert_eq!(reply.mode, mode, "mode echoed back");
                            got.push(reply.id);
                        }
                        got
                    })
                })
                .collect();
            for t in threads {
                ids.extend(t.join().unwrap());
            }
        });

        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len() as u64, total, "every id unique, every request answered");
        assert_eq!(*ids.first().unwrap(), 0);
        assert_eq!(*ids.last().unwrap(), total - 1);

        let m = coord.shutdown();
        assert_eq!(m.completed, total);
        assert_eq!(m.failed, 0);
        assert_eq!(m.routed_batch, total, "{workers} workers");
        // batches: between "max batching" and "every frame alone"
        assert!(m.batches >= total / 3, "batches {} for {total} frames", m.batches);
        assert!(m.batches <= total, "batches {} for {total} frames", m.batches);
        assert!((m.mean_batch() - m.completed as f64 / m.batches as f64).abs() < 1e-9);
        assert_eq!(m.latency.count() as u64, total);
    }
}

#[test]
fn shutdown_drains_under_multi_producer_load() {
    let mut rng = Xoshiro256::new(0xD7A1);
    let (net, shape) = tiny_net(&mut rng);
    let workers = test_cards().into_iter().max().unwrap_or(2);
    let coord = Coordinator::start(
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers,
            policy: BatchPolicy {
                max_batch: 64,
                max_delay: Duration::from_secs(60), // never ripe on its own
            },
            route: RoutePolicy::BatchOnly,
            max_shard_cards: 0,
            ..Default::default()
        },
        net,
    )
    .unwrap();
    let producers = 4usize;
    let per_producer = 10usize;
    let mut rxs = Vec::new();
    std::thread::scope(|s| {
        let threads: Vec<_> = (0..producers)
            .map(|p| {
                let h = coord.handle();
                let mut prng = Xoshiro256::new(100 + p as u64);
                let image = prop::i8_vec(&mut prng, shape.len());
                s.spawn(move || {
                    (0..per_producer)
                        .map(|i| {
                            let mode = if i % 2 == 0 {
                                Mode::HighAccuracy
                            } else {
                                Mode::HighThroughput
                            };
                            h.submit(InferRequest::new(image.clone()).mode(mode))
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for t in threads {
            rxs.extend(t.join().unwrap());
        }
    });
    // everything is still parked in the batcher (max_delay is an hour);
    // shutdown must flush and answer every caller
    let m = coord.shutdown();
    assert_eq!(m.completed, (producers * per_producer) as u64);
    for rx in rxs {
        assert!(rx.recv().expect("drained, not dropped").is_ok());
    }
}

#[test]
fn sharded_path_survives_concurrent_producers() {
    let mut rng = Xoshiro256::new(0x5AAD);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want_hi = golden::forward(&net, &image, shape, None);
    let want_lo = golden::forward(&net, &image, shape, Some(2));
    for cards in test_cards() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                array: ArrayConfig::new(1, 8, 2),
                workers: cards,
                policy: BatchPolicy::default(),
                route: RoutePolicy::ShardOnly,
                max_shard_cards: cards,
                ..Default::default()
            },
            net.clone(),
        )
        .unwrap();
        let producers = 3usize;
        let per_producer = 10usize;
        std::thread::scope(|s| {
            for p in 0..producers {
                let h = coord.handle();
                let (image, want_hi, want_lo) = (&image, &want_hi, &want_lo);
                s.spawn(move || {
                    for i in 0..per_producer {
                        let (mode, want) = if (p + i) % 2 == 0 {
                            (Mode::HighAccuracy, want_hi)
                        } else {
                            (Mode::HighThroughput, want_lo)
                        };
                        let reply = h
                            .infer(InferRequest::new(image.clone()).mode(mode))
                            .expect("sharded inference");
                        assert_eq!(
                            &reply.logits, want,
                            "producer {p} frame {i} mode {mode:?} ({cards} cards)"
                        );
                    }
                });
            }
        });
        let m = coord.shutdown();
        assert_eq!(m.completed, (producers * per_producer) as u64);
        assert_eq!(m.failed, 0);
        // per-frame cutting: every sharded batch is a single frame
        assert_eq!(m.batches, m.completed);
        assert_eq!(m.routed_shard, m.completed);
        assert_eq!(m.shard_leases, m.completed);
    }
}
