//! Property coverage for the cross-card shard geometry: for random
//! networks, array configs and card counts, every layer's per-card tile
//! claims must be pairwise disjoint and their union must cover the
//! layer's output grid exactly — no overlap (two cards writing one cell)
//! and no gap (a cell no card computes).  This is the invariant that
//! makes the coordinator's gather step a pure stitch: tiles can land in
//! the frame buffer in any order and the result is the same.

use std::ops::Range;

use binarray::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::plan::{schedule, shard_schedule, ExecutionPlan, ShardPlan};
use binarray::binarray::ArrayConfig;
use binarray::isa::compile_network;
use binarray::util::{prop, rng::Xoshiro256};

/// Assert `claims` (from all cards of one layer) tile the `rows × chans`
/// grid exactly once.
fn assert_exact_partition(claims: &[(Range<usize>, Range<usize>)], rows: usize, chans: usize) {
    let mut seen = vec![0u32; rows * chans];
    for (r, c) in claims {
        assert!(r.end <= rows && c.end <= chans, "claim ({r:?},{c:?}) out of grid");
        for y in r.clone() {
            for x in c.clone() {
                seen[y * chans + x] += 1;
            }
        }
    }
    for (i, &v) in seen.iter().enumerate() {
        assert_eq!(v, 1, "cell (row {}, chan {}) covered {v} times", i / chans, i % chans);
    }
}

/// Per-card claims must be pairwise disjoint (a card hands them all to
/// one `claim_all`, which panics otherwise — this asserts the geometry
/// directly so a failure names the card).
fn assert_card_disjoint(claims: &[(Range<usize>, Range<usize>)], card: usize) {
    for (i, (r1, c1)) in claims.iter().enumerate() {
        for (r2, c2) in &claims[i + 1..] {
            let rows_meet = r1.start < r2.end && r2.start < r1.end;
            let chans_meet = c1.start < c2.end && c2.start < c1.end;
            assert!(
                !(rows_meet && chans_meet),
                "card {card}: overlapping claims ({r1:?},{c1:?}) vs ({r2:?},{c2:?})"
            );
        }
    }
}

#[test]
fn shard_schedule_partitions_random_geometry() {
    prop::check(300, "per-card claims partition the output grid", |rng| {
        let cfg = ArrayConfig::new(
            1 + rng.below(16) as usize,
            1 + rng.below(32) as usize,
            1 + rng.below(4) as usize,
        );
        let d = 1 + rng.below(200) as usize;
        let rows = 1 + rng.below(24) as usize;
        let m = 1 + rng.below(6) as usize;
        let n_cards = 1 + rng.below(6) as usize;
        let (assignments, _) = schedule(cfg, d, rows, m);
        let cards = shard_schedule(&assignments, n_cards);
        assert_eq!(cards.len(), n_cards);
        let mut all: Vec<(Range<usize>, Range<usize>)> = Vec::new();
        for (ci, card) in cards.iter().enumerate() {
            assert_card_disjoint(card.claims(), ci);
            all.extend(card.claims().iter().cloned());
        }
        assert_exact_partition(&all, rows, d);
    });
}

fn sign_conv(
    rng: &mut Xoshiro256,
    d: usize,
    c: usize,
    m: usize,
    kh: usize,
    pool: usize,
) -> QuantLayer {
    QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, d * m * kh * kh * c),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh,
        kw: kh,
        c,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool,
        stride: 1,
    }
}

fn sign_dense(rng: &mut Xoshiro256, d: usize, n_in: usize, m: usize, relu: bool) -> QuantLayer {
    QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * n_in),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    }
}

/// Random but compilable conv+dense stack (geometry walks forward so the
/// pool divides the conv output), plus its input edge length.
fn random_net(rng: &mut Xoshiro256) -> (QuantNetwork, usize) {
    let m = 1 + rng.below(4) as usize;
    let c0 = 1 + rng.below(3) as usize;
    let kh = 2 + rng.below(3) as usize; // 2..=4
    let pool = 1 + rng.below(2) as usize; // 1..=2
    let conv_out = pool * (2 + rng.below(6) as usize);
    let hw = conv_out + kh - 1;
    let d1 = 1 + rng.below(12) as usize;
    let l1 = sign_conv(rng, d1, c0, m, kh, pool);
    let hw1 = conv_out / pool;
    let flat = hw1 * hw1 * d1;
    let d2 = 2 + rng.below(24) as usize;
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![
            l1,
            sign_dense(rng, d2, flat, m, true),
            sign_dense(rng, 1 + rng.below(8) as usize, d2, m, false),
        ],
    };
    (net, hw)
}

#[test]
fn shard_plan_partitions_every_mode_and_layer() {
    prop::check(20, "ShardPlan partitions out_shape ∀ mode × layer × cards", |rng| {
        let (net, hw) = random_net(rng);
        let inferred = binarray::isa::compiler::infer_input_dims(&net);
        if inferred.0 != hw {
            return; // ambiguous geometry — legitimate skip, not a failure
        }
        let prog = compile_network(&net);
        let cfg = ArrayConfig::new(
            1 + rng.below(8) as usize,
            1 + rng.below(32) as usize,
            1 + rng.below(4) as usize,
        );
        let plan = ExecutionPlan::new(cfg, &net, &prog);
        for n_cards in [1usize, 2, 4, 5] {
            let sp = ShardPlan::new(&plan, n_cards);
            let mut modes = vec![None];
            modes.extend((1..=plan.max_m).map(Some));
            for m_run in modes {
                let layers = sp.mode(m_run);
                let planned = plan.mode(m_run);
                assert_eq!(layers.len(), planned.layers.len());
                for (ls, lp) in layers.iter().zip(&planned.layers) {
                    let mut all = Vec::new();
                    for (ci, card) in ls.cards.iter().enumerate() {
                        assert_card_disjoint(card.claims(), ci);
                        all.extend(card.claims().iter().cloned());
                    }
                    assert_exact_partition(&all, lp.out_shape.h, lp.out_shape.c);
                }
            }
        }
    });
}
