//! Hybrid dispatch under load: both lanes active concurrently over one
//! worker pool, from one `SubmitHandle`, with every reply bit-identical
//! to `golden::forward` — plus the routing-policy properties the router
//! relies on (total, stable, override-respecting).
//!
//! Pool widths ride the `BINARRAY_TEST_CARDS` matrix (default `1,2,4`)
//! so lane arbitration is raced at every width CI claims to cover.

use std::time::Duration;

use binarray::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::ArrayConfig;
use binarray::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, DispatchClass, InferRequest, Mode, RoutePolicy,
};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256, test_cards};

/// A deliberately tiny but structurally complete net (conv+pool, two
/// dense) so the stress pushes *request counts*, not frame compute.
fn tiny_net(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 2;
    let conv = QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, 4 * m * 3 * 3 * 3),
        alpha_q: (0..4 * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..4).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d: 4,
        m,
        kh: 3,
        kw: 3,
        c: 3,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool: 2,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, n_in: usize, relu: bool| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * n_in),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    };
    // 10×10×3 → conv3 → 8×8×4 → pool2 → 4×4×4 → dense 8 → dense 5
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![conv, dense(rng, 8, 64, true), dense(rng, 5, 8, false)],
    };
    assert_eq!(binarray::isa::compiler::infer_input_dims(&net), (10, 10, 3));
    (net, Shape::new(10, 10, 3))
}

/// The acceptance scenario: mixed traffic (explicit batch- and
/// shard-class requests interleaved by concurrent producers) on one
/// submit handle.  Both lanes must be active, cards must flow between
/// them, and every reply must equal the golden model whatever lane
/// served it.
#[test]
fn mixed_traffic_both_lanes_active_and_bit_exact() {
    let mut rng = Xoshiro256::new(0x417B);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want_hi = golden::forward(&net, &image, shape, None);
    let want_lo = golden::forward(&net, &image, shape, Some(2));
    for cards in test_cards() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                array: ArrayConfig::new(1, 8, 2),
                workers: cards,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_delay: Duration::from_micros(200),
                },
                // the policy says batch; shard traffic arrives as
                // explicit overrides — both lanes live on one pool
                route: RoutePolicy::BatchOnly,
                max_shard_cards: 0,
                ..Default::default()
            },
            net.clone(),
        )
        .unwrap();
        let producers = 4usize;
        let per_producer = 16usize;
        let total = (producers * per_producer) as u64;
        std::thread::scope(|s| {
            for p in 0..producers {
                let h = coord.handle();
                let (image, want_hi, want_lo) = (&image, &want_hi, &want_lo);
                s.spawn(move || {
                    for i in 0..per_producer {
                        let class = if (p + i) % 3 == 0 {
                            DispatchClass::Shard
                        } else {
                            DispatchClass::Batch
                        };
                        let (mode, want) = if i % 2 == 0 {
                            (Mode::HighAccuracy, want_hi)
                        } else {
                            (Mode::HighThroughput, want_lo)
                        };
                        let reply = h
                            .infer(InferRequest::new(image.clone()).mode(mode).route(class))
                            .expect("mixed-traffic inference");
                        assert_eq!(
                            &reply.logits, want,
                            "producer {p} frame {i} {class:?} {mode:?} ({cards} cards)"
                        );
                    }
                });
            }
        });
        let m = coord.shutdown();
        assert_eq!(m.completed, total, "{cards} cards");
        assert_eq!(m.failed, 0);
        // both lanes saw traffic and did real work
        assert!(m.routed_batch > 0 && m.routed_shard > 0, "{cards} cards");
        assert_eq!(m.routed_batch + m.routed_shard, total);
        assert!(m.batch_wall > Duration::ZERO, "batch lane idle ({cards} cards)");
        assert!(m.shard_wall > Duration::ZERO, "shard lane idle ({cards} cards)");
        // every shard frame leased at least one card, never more than
        // the pool, and the ledger balanced
        assert_eq!(m.shard_leases, m.routed_shard);
        assert!(m.shard_cards_granted >= m.shard_leases);
        assert!(m.shard_cards_granted <= m.shard_leases * cards as u64);
        assert_eq!(m.latency.count() as u64, total);
    }
}

/// The adaptive policy end-to-end: frames large enough to shard take the
/// shard lane while the queue is shallow, and every admitted request
/// lands in exactly one lane (the counters partition the total).
#[test]
fn adaptive_policy_serves_and_partitions_traffic() {
    let mut rng = Xoshiro256::new(0xADA);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let workers = test_cards().into_iter().max().unwrap_or(2);
    let coord = Coordinator::start(
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_micros(200),
            },
            route: RoutePolicy::Adaptive {
                shard_min_len: shape.len(), // every frame is "large"
                deep_queue: 3,
                tight_slack: Duration::ZERO,
            },
            max_shard_cards: 0,
            ..Default::default()
        },
        net,
    )
    .unwrap();
    let total = 32u64;
    let rxs: Vec<_> = (0..total)
        .map(|_| coord.submit(InferRequest::new(image.clone())))
        .collect();
    for rx in rxs {
        let reply = rx.recv().unwrap().expect("adaptive inference");
        assert_eq!(reply.logits, want);
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, total);
    assert_eq!(m.failed, 0);
    // totality: every request landed in exactly one lane
    assert_eq!(m.routed_batch + m.routed_shard, total);
    // the first frame hits an empty queue and a large frame ⇒ shard
    assert!(m.routed_shard > 0, "shallow-queue large frames must shard");
}

/// Property: `classify` is total and stable over arbitrary signals
/// (frame size, queue depth, deadline slack), an explicit override is
/// never reassigned, and the slack signal behaves monotonically — for
/// every policy shape.
#[test]
fn route_policy_total_stable_and_override_proof() {
    let mut rng = Xoshiro256::new(0x70407);
    for _ in 0..2000 {
        let tight_slack = Duration::from_micros(rng.range_i64(0, 5_000) as u64);
        let policy = match rng.range_i64(0, 3) {
            0 => RoutePolicy::BatchOnly,
            1 => RoutePolicy::ShardOnly,
            _ => RoutePolicy::Adaptive {
                shard_min_len: rng.range_i64(0, 100_000) as usize,
                deep_queue: rng.range_i64(0, 64) as usize,
                tight_slack,
            },
        };
        let frame_len = rng.range_i64(0, 1_000_000) as usize;
        let queue_depth = rng.range_i64(0, 10_000) as usize;
        let slack = match rng.range_i64(0, 3) {
            0 => None,
            _ => Some(Duration::from_micros(rng.range_i64(0, 10_000) as u64)),
        };
        // total: exactly one of the two lanes
        let lane = policy.classify(frame_len, queue_depth, slack);
        assert!(
            lane == DispatchClass::Batch || lane == DispatchClass::Shard,
            "{policy:?} produced no lane"
        );
        // stable: same inputs, same lane, every time
        for _ in 0..3 {
            assert_eq!(policy.classify(frame_len, queue_depth, slack), lane, "{policy:?}");
        }
        assert_eq!(policy.route(None, frame_len, queue_depth, slack), lane);
        // an explicit class is final whatever the policy would say
        for explicit in [DispatchClass::Batch, DispatchClass::Shard] {
            assert_eq!(
                policy.route(Some(explicit), frame_len, queue_depth, slack),
                explicit,
                "{policy:?} reassigned an explicit override"
            );
        }
        // slack semantics on the adaptive policy: under a shallow queue
        // a tight slack must shard; relaxing every other signal while
        // keeping slack tight must not flip it back to batching
        if let RoutePolicy::Adaptive {
            deep_queue,
            tight_slack,
            ..
        } = policy
        {
            if queue_depth < deep_queue {
                assert_eq!(
                    policy.classify(frame_len, queue_depth, Some(tight_slack)),
                    DispatchClass::Shard,
                    "tight slack under a shallow queue must take the latency lane"
                );
            }
            // no deadline can never be *tighter* than some deadline:
            // if None shards (by size), Some(anything) still shards
            if policy.classify(frame_len, queue_depth, None) == DispatchClass::Shard {
                assert_eq!(
                    policy.classify(frame_len, queue_depth, slack.or(Some(Duration::ZERO))),
                    DispatchClass::Shard,
                    "adding a deadline must never lose the shard lane"
                );
            }
        }
    }
}

/// End-to-end proof of the override guarantee: a `ShardOnly` coordinator
/// still batches an explicit batch-class request, and the lane counters
/// show it.
#[test]
fn explicit_override_survives_opposing_policy() {
    let mut rng = Xoshiro256::new(0x0BE);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let coord = Coordinator::start(
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers: 2,
            policy: BatchPolicy::default(),
            route: RoutePolicy::ShardOnly,
            max_shard_cards: 0,
            ..Default::default()
        },
        net,
    )
    .unwrap();
    let forced = coord
        .infer(InferRequest::new(image.clone()).route(DispatchClass::Batch))
        .unwrap();
    assert_eq!(forced.logits, want);
    let routed = coord.infer(InferRequest::new(image)).unwrap();
    assert_eq!(routed.logits, want);
    let m = coord.shutdown();
    assert_eq!(m.completed, 2);
    assert_eq!(m.routed_batch, 1, "override must reach the batch lane");
    assert_eq!(m.routed_shard, 1, "policy routes the rest to the shard lane");
}
