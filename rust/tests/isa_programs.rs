//! ISA-level integration: assembling Listing-1-style programs by hand and
//! executing them on the control unit, independent of the compiler.

use binarray::binarray::cu::ControlUnit;
use binarray::isa::{flags, Instr, Program, Reg};

/// Assemble a program from text lines (comments allowed).
fn assemble(lines: &[&str]) -> Vec<Instr> {
    lines
        .iter()
        .filter(|l| !l.split(';').next().unwrap_or("").trim().is_empty())
        .map(|l| Instr::assemble(l).expect(l))
        .collect()
}

fn wrap(instrs: Vec<Instr>) -> Program {
    Program {
        entry: instrs
            .iter()
            .position(|i| matches!(i, Instr::Hlt))
            .unwrap_or(0),
        instrs,
        bindings: vec![],
        fbuf_words: 0,
        wgt_words: 0,
        alpha_words: 0,
    }
}

#[test]
fn listing1_executes_two_conv_layers() {
    // The paper's Listing 1, verbatim semantics.
    let prog = wrap(assemble(&[
        "STI W_I 48 ; Set input width to 48 pixels",
        "STI W_B 7  ; Set kernel width to 7 pixels",
        "HLT        ; Wait for trigger from PS",
        "CONV 0     ; Start CONV of 1st layer",
        "STI W_I 21 ; Set input width to 21 pixels",
        "STI W_B 4  ; Set kernel width to 4 pixels",
        "CONV 1     ; 2nd CONV layer",
        "BRA 0      ; Branch back to step 1 (the paper's 'BRA 1', 0-indexed)",
    ]));
    let mut cu = ControlUnit::new();
    // Frame 1: initial STIs run, then the CU parks on HLT... the first
    // trigger carries it through both CONVs and back to the HLT.
    let mut seen = Vec::new();
    let run = cu.run_frame(&prog, |lr| {
        seen.push((lr.layer_id, lr.reg(Reg::WIn), lr.reg(Reg::WKer)));
        100
    });
    assert_eq!(seen, vec![(0, 48, 7), (1, 21, 4)]);
    assert_eq!(run.layers_run, 2);
    assert_eq!(run.layer_cycles, 200);

    // Frame 2 repeats identically (BRA loop).
    seen.clear();
    cu.run_frame(&prog, |lr| {
        seen.push((lr.layer_id, lr.reg(Reg::WIn), lr.reg(Reg::WKer)));
        100
    });
    assert_eq!(seen, vec![(0, 48, 7), (1, 21, 4)]);
}

#[test]
fn dense_and_flags_roundtrip() {
    let prog = wrap(assemble(&[
        "HLT",
        &format!("STI FLAGS {}", flags::RELU | flags::DENSE),
        "STI N_IN 1350",
        "STI D 340",
        "DENSE 2",
        &format!("STI FLAGS {}", flags::LAST),
        "DENSE 3",
        "BRA 0",
    ]));
    let mut cu = ControlUnit::new();
    let mut got = Vec::new();
    let run = cu.run_frame(&prog, |lr| {
        got.push((lr.layer_id, lr.dense, lr.flag(flags::RELU), lr.flag(flags::LAST)));
        1
    });
    assert_eq!(got, vec![(2, true, true, false), (3, true, false, true)]);
    assert!(run.frame_done);
}

#[test]
fn machine_code_image_runs_after_decode() {
    // encode → u32 memory image → decode → execute: the IMEM path of
    // Fig. 10 (the CPU loads the program into instruction memory).
    let src = wrap(assemble(&["HLT", "STI W_I 9", "CONV 0", "BRA 0"]));
    let image: Vec<u32> = src.instrs.iter().map(Instr::encode).collect();
    let decoded: Vec<Instr> = image
        .iter()
        .map(|&w| Instr::decode(w).unwrap())
        .collect();
    assert_eq!(decoded, src.instrs);
    let prog = wrap(decoded);
    let mut cu = ControlUnit::new();
    let mut widths = Vec::new();
    cu.run_frame(&prog, |lr| {
        widths.push(lr.reg(Reg::WIn));
        0
    });
    assert_eq!(widths, vec![9]);
}

#[test]
fn nop_only_program_terminates() {
    let prog = wrap(vec![Instr::Nop, Instr::Hlt, Instr::Nop, Instr::Bra(1)]);
    let mut cu = ControlUnit::new();
    let run = cu.run_frame(&prog, |_| 0);
    assert_eq!(run.layers_run, 0);
    // second frame also terminates (parks back on HLT via BRA)
    let run2 = cu.run_frame(&prog, |_| 0);
    assert_eq!(run2.layers_run, 0);
}

#[test]
fn assembler_rejects_garbage() {
    assert!(Instr::assemble("FLY 1").is_err());
    assert!(Instr::assemble("STI NOPE 3").is_err());
    assert!(Instr::assemble("STI W_I").is_err());
    assert!(Instr::assemble("CONV banana").is_err());
    assert!(Instr::assemble("   ; only a comment").is_err());
}
