//! End-to-end integration tests over the real build artifacts.
//!
//! These tests require `make artifacts` to have run (they are skipped
//! gracefully otherwise, so `cargo test` works on a fresh checkout).
//! They pin the full cross-language contract:
//!
//!   numpy int8 oracle  ==  Rust golden model  ==  cycle-accurate sim
//!                      ==  coordinator serving path
//!   analytical model   ≈   simulator cycles (sub-percent)
//!   PJRT float model   ≈   int8 pipeline (top-1 agreement)

use binarray::artifacts::{CalibBatch, GoldenLogits, QuantNetwork};
use binarray::binarray::{ArrayConfig, BinArraySystem, PAPER_CONFIGS};
use binarray::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, InferRequest};
use binarray::tensor::Shape;
use binarray::{golden, isa, nn, perf};

fn load() -> Option<(QuantNetwork, CalibBatch, GoldenLogits)> {
    let dir = binarray::artifacts::default_dir();
    let net = QuantNetwork::load(&dir.join("cnn_a.weights.bin")).ok()?;
    let calib = CalibBatch::load(&dir.join("calib.bin")).ok()?;
    let gold = GoldenLogits::load(&dir.join("golden.bin")).ok()?;
    Some((net, calib, gold))
}

macro_rules! need_artifacts {
    () => {
        match load() {
            Some(v) => v,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn golden_model_bit_exact_vs_numpy_oracle() {
    let (net, calib, gold) = need_artifacts!();
    let shape = Shape::new(calib.h, calib.w, calib.c);
    for i in 0..gold.n {
        let logits = golden::forward(&net, calib.image(i), shape, None);
        assert_eq!(
            logits.as_slice(),
            gold.row(i),
            "frame {i}: Rust golden model != numpy oracle"
        );
    }
}

#[test]
fn simulator_bit_exact_vs_golden_all_configs() {
    let (net, calib, _) = need_artifacts!();
    let shape = Shape::new(calib.h, calib.w, calib.c);
    for cfg in PAPER_CONFIGS {
        let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
        for i in 0..4 {
            let (logits, _) = sys.run_frame(calib.image(i)).unwrap();
            let want = golden::forward(&net, calib.image(i), shape, None);
            assert_eq!(logits, want, "config {} frame {i}", cfg.label());
        }
    }
}

#[test]
fn accuracy_on_calib_set_is_high() {
    // The trained + binarized + quantized network must still classify the
    // synthetic test set well — the end-to-end signal that nothing in the
    // pipeline (approximation, quantization, simulation) silently died.
    let (net, calib, _) = need_artifacts!();
    let shape = Shape::new(calib.h, calib.w, calib.c);
    let mut correct = 0;
    for i in 0..calib.n {
        let logits = golden::forward(&net, calib.image(i), shape, None);
        if golden::argmax(&logits) as i32 == calib.labels[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / calib.n as f64;
    assert!(acc > 0.80, "int8 accuracy {acc} too low — pipeline regression");
}

#[test]
fn high_throughput_mode_loses_little_accuracy() {
    // §IV-D: the M_arch-level fast mode trades a controlled amount of
    // accuracy; with M=4→2 on this easy task it should stay usable.
    let (net, calib, _) = need_artifacts!();
    let shape = Shape::new(calib.h, calib.w, calib.c);
    let mut correct_fast = 0;
    for i in 0..calib.n {
        let logits = golden::forward(&net, calib.image(i), shape, Some(2));
        if golden::argmax(&logits) as i32 == calib.labels[i] {
            correct_fast += 1;
        }
    }
    let acc = correct_fast as f64 / calib.n as f64;
    assert!(acc > 0.5, "fast-mode accuracy collapsed: {acc}");
}

#[test]
fn analytical_model_tracks_simulator_full_network() {
    let (net, calib, _) = need_artifacts!();
    for cfg in [ArrayConfig::new(1, 8, 2), ArrayConfig::new(1, 32, 2)] {
        let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
        sys.set_mode(Some(2));
        let (_, stats) = sys.run_frame(calib.image(0)).unwrap();
        let analytic = perf::network_cycles(&nn::cnn_a(), cfg, 2, false);
        let err = (analytic - stats.cycles as f64).abs() / stats.cycles as f64;
        assert!(
            err < 0.01,
            "config {}: analytic {analytic} vs sim {} ({err:.4})",
            cfg.label(),
            stats.cycles
        );
    }
}

#[test]
fn serving_path_equals_direct_simulation() {
    let (net, calib, _) = need_artifacts!();
    let coord = Coordinator::start(
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers: 2,
            policy: BatchPolicy::default(),
            ..Default::default()
        },
        net.clone(),
    )
    .unwrap();
    let shape = Shape::new(calib.h, calib.w, calib.c);
    let rxs: Vec<_> = (0..16)
        .map(|i| coord.submit(InferRequest::new(calib.image(i).to_vec())))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().unwrap().unwrap();
        let want = golden::forward(&net, calib.image(i), shape, None);
        assert_eq!(reply.logits, want, "served frame {i}");
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 16);
}

#[test]
fn program_compiles_and_mentions_listing1_values() {
    let (net, _, _) = need_artifacts!();
    let prog = isa::compile_network(&net);
    let listing = prog.listing();
    // Listing 1's layer parameters for CNN-A
    assert!(listing.contains("STI W_I 48"));
    assert!(listing.contains("STI W_B 7"));
    assert!(listing.contains("STI W_I 21"));
    assert!(listing.contains("STI W_B 4"));
    assert!(listing.contains("HLT"));
    assert!(listing.contains("BRA 1"));
    // machine-code roundtrip of the whole program
    for ins in &prog.instrs {
        assert_eq!(isa::Instr::decode(ins.encode()).unwrap(), *ins);
    }
}

#[test]
fn compression_factor_matches_eq6_on_real_network() {
    // Table II cf column: CNN-A at M = 2/3/4 → ~15.8/10.6/7.9
    let (net, _, _) = need_artifacts!();
    let _ = net;
    let layer_sizes: Vec<(usize, usize)> = nn::cnn_a()
        .layers
        .iter()
        .map(|l| (l.d_out(), l.n_c()))
        .collect();
    for (m, want) in [(2usize, 15.8f64), (3, 10.6), (4, 7.9)] {
        let orig: u64 = layer_sizes
            .iter()
            .map(|&(d, nc)| (d * (nc + 1) * 32) as u64)
            .sum();
        let comp: u64 = layer_sizes
            .iter()
            .map(|&(d, nc)| (d * m * (nc + 8)) as u64)
            .sum();
        let cf = orig as f64 / comp as f64;
        assert!(
            (cf - want).abs() < 0.35,
            "M={m}: cf {cf:.2} vs paper {want}"
        );
    }
}

#[test]
fn mode_switch_cycle_ratio_near_two() {
    let (net, calib, _) = need_artifacts!();
    let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net).unwrap();
    let (_, full) = sys.run_frame(calib.image(0)).unwrap();
    sys.set_mode(Some(2));
    let (_, fast) = sys.run_frame(calib.image(0)).unwrap();
    let ratio = full.cycles as f64 / fast.cycles as f64;
    assert!(
        (1.8..2.2).contains(&ratio),
        "M=4 vs M=2 cycle ratio {ratio} (expect ≈2)"
    );
}
