//! Kernel exactness: the bit-packed popcount kernel raced bit-for-bit
//! against the scalar golden arithmetic at every level — single plane
//! dots (auto backend and pinned-portable), the α cascade, and whole
//! networks through the simulator in both accuracy modes and under both
//! kernel choices.  The exactness bar is absolute: the kernel is a
//! host-speed knob, any divergence here is a bug, never a tolerance.

use binarray::artifacts::{self, LayerKind, PackedPlanes, QuantLayer};
use binarray::binarray::{ArrayConfig, BinArraySystem};
use binarray::golden;
use binarray::kernel::{self, BitPatch, KernelKind};
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256};

/// A 1×1 dense layer carrying one sign plane — the smallest carrier that
/// lets [`PackedPlanes::pack`] build kernel-ready words from raw signs.
fn plane_layer(signs: Vec<i8>) -> QuantLayer {
    QuantLayer {
        kind: LayerKind::Dense,
        kh: signs.len(),
        planes: signs,
        alpha_q: vec![1],
        bias_q: vec![0],
        d: 1,
        m: 1,
        kw: 0,
        c: 0,
        f_alpha: 6,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu: false,
        pool: 1,
        stride: 1,
    }
}

/// Race the packed dot — the auto-detected backend and the pinned
/// portable path — against the scalar reference on one plane.
fn race(signs: &[i8], x: &[i8]) {
    let want = golden::signed_dot(signs, x);
    let layer = plane_layer(signs.to_vec());
    let pk = PackedPlanes::pack(&layer);
    let mut patch = BitPatch::default();
    patch.pack(x);
    assert_eq!(kernel::plane_dot(pk.plane(0, 0), &patch), want, "n={}", x.len());
    assert_eq!(
        kernel::plane_dot_portable(pk.plane(0, 0), &patch),
        want,
        "portable n={}",
        x.len()
    );
}

#[test]
fn packed_plane_dot_matches_signed_dot_on_random_lengths() {
    prop::check(200, "plane_dot == signed_dot", |rng| {
        let n = rng.below(400) as usize;
        let signs = prop::sign_vec(rng, n);
        let x = prop::i8_vec(rng, n);
        race(&signs, &x);
    });
}

#[test]
fn every_word_boundary_tail_is_exact() {
    // zero-length plus every tail remainder 0..=63 at several word bases
    let mut rng = Xoshiro256::new(0x7A11);
    for base in [0usize, 64, 128, 192, 256] {
        for tail in 0..=63usize {
            let n = base + tail;
            let signs = prop::sign_vec(&mut rng, n);
            let x = prop::i8_vec(&mut rng, n);
            race(&signs, &x);
        }
    }
}

#[test]
fn overflow_adjacent_extremes_are_exact() {
    // all-(+1)/(−1) planes against all-MIN/MAX activations: the largest
    // |P| and |S| any plane of length n can produce
    for n in [1usize, 63, 64, 65, 127, 129, 1350] {
        for s in [-1i8, 1] {
            for v in [i8::MIN, i8::MAX] {
                race(&vec![s; n], &vec![v; n]);
            }
        }
    }
}

#[test]
fn alpha_cascade_matches_golden_binary_dot() {
    prop::check(60, "binary_dot_packed == binary_dot", |rng| {
        let d = 1 + rng.below(6) as usize;
        let m = 1 + rng.below(4) as usize;
        let n_c = 1 + rng.below(300) as usize;
        let layer = QuantLayer {
            kind: LayerKind::Dense,
            planes: prop::sign_vec(rng, d * m * n_c),
            alpha_q: (0..d * m).map(|_| rng.range_i64(1, 128) as i8).collect(),
            bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
            d,
            m,
            kh: n_c,
            kw: 0,
            c: 0,
            f_alpha: 6,
            f_in: 6,
            f_out: 6,
            shift: 6,
            relu: false,
            pool: 1,
            stride: 1,
        };
        let pk = PackedPlanes::pack(&layer);
        let x = prop::i8_vec(rng, n_c);
        let mut patch = BitPatch::default();
        patch.pack(&x);
        for dd in 0..d {
            for m_run in 1..=m {
                assert_eq!(
                    kernel::binary_dot_packed(&layer, &pk, dd, &patch, m_run),
                    golden::binary_dot(&layer, dd, &x, m_run),
                    "d={dd} m_run={m_run}"
                );
            }
        }
    });
}

#[test]
fn full_network_race_scalar_vs_packed_vs_golden() {
    let mut rng = Xoshiro256::new(0xBEEF);
    for m in [1usize, 4] {
        let net = artifacts::synthetic_cnn_a(&mut rng, m);
        let dims = binarray::isa::compiler::infer_input_dims(&net);
        let shape = Shape::new(dims.1, dims.0, dims.2);
        let image = prop::i8_vec(&mut rng, shape.len());
        for cfg in [ArrayConfig::new(1, 8, 2), ArrayConfig::new(4, 32, 4)] {
            for mode in [None, Some(1)] {
                let want = golden::forward(&net, &image, shape, mode);
                for kind in [KernelKind::Scalar, KernelKind::Packed] {
                    let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
                    sys.set_mode(mode);
                    sys.set_kernel(kind);
                    let (logits, _) = sys.run_frame(&image).unwrap();
                    let tag = format!("m={m} cfg={} mode={mode:?} {kind:?}", cfg.label());
                    assert_eq!(logits, want, "{tag}");
                }
            }
        }
    }
}
