//! Per-class SLO admission control, end to end: named service classes
//! carry latency SLOs, the capacity model refuses provably-unmeetable
//! work at `submit` with the typed `InferError::AdmissionRefused` —
//! never queued, never computed — the class admission budget caps
//! inflight work per class, and SLO-aware cross-lane arbitration meets
//! strictly more Interactive SLOs than the oldest-first pick on the
//! same overload.  Every admitted reply stays bit-identical to
//! `golden::forward`.
//!
//! Pool widths ride the `BINARRAY_TEST_CARDS` matrix (default `1,2,4`)
//! where the pool is involved, like the other cross-card suites.

use std::time::{Duration, Instant};

use binarray::artifacts::{self, LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::{ArrayConfig, BinArraySystem};
use binarray::coordinator::{
    Arbitration, BatchPolicy, ClassSpec, ClassTable, Coordinator, CoordinatorConfig, InferError,
    InferRequest, Metrics, Mode, RoutePolicy, ServiceClass,
};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256, test_cards};

/// A deliberately tiny but structurally complete net (conv+pool, two
/// dense) so the admission paths are pushed with request counts, not
/// compute.
fn tiny_net(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 2;
    let conv = QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, 4 * m * 3 * 3 * 3),
        alpha_q: (0..4 * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..4).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d: 4,
        m,
        kh: 3,
        kw: 3,
        c: 3,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool: 2,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, n_in: usize, relu: bool| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * n_in),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    };
    // 10×10×3 → conv3 → 8×8×4 → pool2 → 4×4×4 → dense 8 → dense 5
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![conv, dense(rng, 8, 64, true), dense(rng, 5, 8, false)],
    };
    assert_eq!(binarray::isa::compiler::infer_input_dims(&net), (10, 10, 3));
    (net, Shape::new(10, 10, 3))
}

fn cfg(workers: usize, classes: ClassTable) -> CoordinatorConfig {
    CoordinatorConfig {
        array: ArrayConfig::new(1, 8, 2),
        workers,
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_micros(200),
        },
        route: RoutePolicy::BatchOnly,
        classes,
        ..Default::default()
    }
}

/// The accounting identity every run of this suite re-checks: all
/// submitted work is answered exactly once — completed, failed (sheds
/// included), or refused at admission.
fn assert_identity(m: &Metrics) {
    assert_eq!(
        m.submitted,
        m.completed + m.failed + m.admission_refused,
        "submitted = completed + failed + refused must hold \
         (submitted {}, completed {}, failed {}, refused {})",
        m.submitted,
        m.completed,
        m.failed,
        m.admission_refused
    );
    let per_class: u64 = m.classes.iter().map(|c| c.submitted).sum();
    assert_eq!(per_class, m.submitted, "per-class submitted sums to the total");
}

/// The class admission budget refuses at the cap, before any queue or
/// compute cost: refusals are typed, answered instantly (the admitted
/// work is still parked in the batcher), and the refused requests never
/// touch the simulator.
#[test]
fn admission_budget_refuses_before_any_cost() {
    let mut rng = Xoshiro256::new(0xB0D6);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    for workers in test_cards() {
        let classes = ClassTable::default().with(
            ServiceClass::Interactive,
            ClassSpec {
                slo: None, // isolate the budget gate from the capacity gate
                dispatch_bias: None,
                admission_limit: 2,
            },
        );
        let coord = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy {
                    max_batch: 64,
                    max_delay: Duration::from_secs(60), // nothing cuts on its own
                },
                ..cfg(workers, classes)
            },
            net.clone(),
        )
        .unwrap();
        let rxs: Vec<_> = (0..5)
            .map(|_| {
                coord.submit(
                    InferRequest::new(image.clone()).service(ServiceClass::Interactive),
                )
            })
            .collect();
        // the three over-budget requests are answered *now*, while the
        // two admitted ones are still parked in the batcher
        for rx in &rxs[2..] {
            let err = rx
                .recv()
                .expect("refused work is answered, not dropped")
                .expect_err("over-budget work must be refused");
            assert!(err.is_refused(), "typed refusal, got {err:?}");
            assert!(!err.is_deadline());
        }
        let m = coord.shutdown(); // flush serves the two admitted requests
        for rx in &rxs[..2] {
            let reply = rx.recv().unwrap().expect("admitted work served");
            assert_eq!(reply.logits, want, "{workers} workers");
        }
        assert_eq!(m.submitted, 5, "{workers} workers");
        assert_eq!(m.completed, 2);
        assert_eq!(m.failed, 0);
        assert_eq!(m.admission_refused, 3);
        assert_identity(&m);
        let ci = ServiceClass::Interactive.index();
        assert_eq!(m.classes[ci].submitted, 5);
        assert_eq!(m.classes[ci].completed, 2);
        assert_eq!(m.classes[ci].admission_refused, 3);
        // refused work burned nothing: the only cycles belong to the
        // two admitted frames, served as one flush batch
        assert_eq!(m.latency.count(), 2, "only served frames record latency");
        assert!(m.sim_cycles > 0);
        assert_eq!(m.batches, 1, "both admitted frames share the flush batch");
    }
}

/// The capacity gate, end to end on a full-size frame: an SLO far below
/// the per-frame cost floor is refused at admission — typed, instant,
/// zero compute — on a *fresh* coordinator (the model is seeded with
/// the plan-derived pace at construction, so hopeless work is provable
/// before any completion) as well as after calibration, while SLO-free
/// traffic is never refused.
#[test]
fn capacity_gate_refuses_unmeetable_slo_after_calibration() {
    let mut rng = Xoshiro256::new(0xCA9A);
    // Full-size synthetic CNN-A: per-frame compute in the milliseconds,
    // so a 100 µs SLO is provably hopeless once the pace is known.
    let net = artifacts::synthetic_cnn_a(&mut rng, 2);
    let dims = binarray::isa::compiler::infer_input_dims(&net);
    let shape = Shape::new(dims.1, dims.0, dims.2);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let hopeless = Duration::from_micros(100);
    let classes = ClassTable::default().with(
        ServiceClass::Interactive,
        ClassSpec {
            slo: Some(hopeless),
            dispatch_bias: None,
            admission_limit: 0,
        },
    );

    // Fresh pool, no completion observed: the seeded pace (one
    // estimated cycle per simulated 400 MHz tick — cheaper than any
    // host could serve) already prices a ms-scale frame above 100 µs,
    // so the hopeless SLO is refused before the first byte of compute.
    {
        let coord = Coordinator::start(cfg(1, classes), net.clone()).unwrap();
        let err = coord
            .infer(InferRequest::new(image.clone()).service(ServiceClass::Interactive))
            .expect_err("the seeded model proves a 100 µs SLO hopeless at startup");
        let ie: InferError = err.downcast().expect("typed InferError");
        assert!(ie.is_refused(), "typed refusal on a fresh coordinator, got {ie:?}");
        let m = coord.shutdown();
        assert_eq!(m.admission_refused, 1, "seeded floor refuses before calibration");
        assert_eq!(m.completed, 0, "refused work never computed");
        assert_identity(&m);
    }

    // Calibrated: serve two standard frames (each one serial batch),
    // then the same hopeless SLO is refused at the gate — and a final
    // standard frame shows SLO-free traffic is never refused.  All
    // counts are asserted on the post-shutdown totals, which are exact.
    let coord = Coordinator::start(cfg(1, classes), net).unwrap();
    for _ in 0..2 {
        let reply = coord.infer(InferRequest::new(image.clone())).unwrap();
        assert_eq!(reply.logits, want);
    }
    let err = coord
        .infer(InferRequest::new(image.clone()).service(ServiceClass::Interactive))
        .expect_err("a 100 µs SLO on a ms-scale frame must be refused");
    let ie: InferError = err.downcast().expect("typed InferError");
    let InferError::AdmissionRefused { earliest_feasible, .. } = ie else {
        panic!("expected AdmissionRefused, got {ie:?}");
    };
    assert!(
        earliest_feasible > hopeless,
        "the refusal names a floor above the SLO ({earliest_feasible:?})"
    );
    // SLO-free traffic on the same calibrated coordinator is never
    // refused — admission control is a class contract.
    let reply = coord.infer(InferRequest::new(image.clone())).unwrap();
    assert_eq!(reply.logits, want);
    let m = coord.shutdown();
    assert_identity(&m);
    assert_eq!(m.submitted, 4);
    assert_eq!(m.completed, 3, "the refused request never computed");
    assert_eq!(m.failed, 0);
    assert_eq!(m.admission_refused, 1);
    assert_eq!(m.batches, 3, "a refusal costs no batch");
    assert_eq!(m.latency.count(), 3, "no latency sample for refused work");
    assert_eq!(m.classes[ServiceClass::Interactive.index()].admission_refused, 1);
}

/// Cold-start regression: a full burst on a *fresh* coordinator, every
/// frame carrying a generous-but-real SLO, must be admitted and served
/// in full.  Before the model was seeded, the first burst was priced
/// off whatever the first completion happened to measure — a slow
/// outlier (cold caches, page faults) could mass-refuse work the pool
/// served comfortably; with the pace seeded at construction and
/// observations only ever lowering it, the whole burst rides through.
#[test]
fn fresh_coordinator_admits_a_full_burst_under_a_generous_slo() {
    let mut rng = Xoshiro256::new(0xC01D);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    for workers in test_cards() {
        let classes = ClassTable::default().with(
            ServiceClass::Interactive,
            ClassSpec {
                slo: Some(Duration::from_secs(30)),
                dispatch_bias: None,
                admission_limit: 0,
            },
        );
        // No warmup, no calibration: the burst is the first traffic the
        // coordinator ever sees.
        let coord = Coordinator::start(cfg(workers, classes), net.clone()).unwrap();
        let burst = 64usize;
        let rxs: Vec<_> = (0..burst)
            .map(|_| {
                coord.submit(
                    InferRequest::new(image.clone()).service(ServiceClass::Interactive),
                )
            })
            .collect();
        for (i, rx) in rxs.iter().enumerate() {
            let reply = rx
                .recv()
                .expect("answered")
                .unwrap_or_else(|e| panic!("burst frame {i} must be admitted and served: {e}"));
            assert_eq!(reply.logits, want, "frame {i}, {workers} workers");
        }
        let m = coord.shutdown();
        assert_eq!(m.submitted, burst as u64, "{workers} workers");
        assert_eq!(m.completed, burst as u64);
        assert_eq!(m.admission_refused, 0, "cold-start burst is never mass-refused");
        assert_eq!(m.failed, 0);
        assert_identity(&m);
    }
}

/// `coordinator_stress`-style concurrency over mixed classes, budgets
/// and deadlines: every receiver is answered exactly once, and
/// `completed + failed + refused == submitted` holds on the final
/// metrics whatever the interleaving.
#[test]
fn identity_holds_under_concurrent_mixed_class_load() {
    let mut rng = Xoshiro256::new(0x1DE7);
    let (net, shape) = tiny_net(&mut rng);
    for workers in test_cards() {
        let classes = ClassTable::default()
            .with(
                ServiceClass::Interactive,
                ClassSpec {
                    slo: Some(Duration::from_secs(30)), // generous: admission stays open
                    dispatch_bias: None,
                    admission_limit: 0,
                },
            )
            .with(
                ServiceClass::Bulk,
                ClassSpec {
                    slo: None,
                    dispatch_bias: None,
                    admission_limit: 3, // tight: refusals under load
                },
            );
        let coord = Coordinator::start(cfg(workers, classes), net.clone()).unwrap();
        let producers = 4usize;
        let per_producer = 24usize;
        let total = (producers * per_producer) as u64;
        let (mut ok, mut refused, mut shed) = (0u64, 0u64, 0u64);
        std::thread::scope(|s| {
            let threads: Vec<_> = (0..producers)
                .map(|p| {
                    let h = coord.handle();
                    let mut prng = Xoshiro256::new(900 + p as u64);
                    let image = prop::i8_vec(&mut prng, shape.len());
                    s.spawn(move || {
                        let (mut ok, mut refused, mut shed) = (0u64, 0u64, 0u64);
                        for i in 0..per_producer {
                            let service = match i % 3 {
                                0 => ServiceClass::Interactive,
                                1 => ServiceClass::Standard,
                                _ => ServiceClass::Bulk,
                            };
                            // every fifth request arrives already expired
                            // (exercises the shed gates alongside refusal)
                            let deadline = (i % 5 == 0).then(Instant::now);
                            let reply = h
                                .submit(
                                    InferRequest::new(image.clone())
                                        .deadline(deadline)
                                        .service(service),
                                )
                                .recv()
                                .expect("every request answered exactly once");
                            match reply {
                                Ok(_) => ok += 1,
                                Err(e) if e.is_refused() => refused += 1,
                                Err(e) if e.is_deadline() => shed += 1,
                                Err(e) => panic!("unexpected serving fault: {e}"),
                            }
                        }
                        (ok, refused, shed)
                    })
                })
                .collect();
            for t in threads {
                let (o, r, sh) = t.join().unwrap();
                ok += o;
                refused += r;
                shed += sh;
            }
        });
        assert_eq!(ok + refused + shed, total);
        let m = coord.shutdown();
        assert_eq!(m.submitted, total, "{workers} workers");
        assert_eq!(m.completed, ok);
        assert_eq!(m.admission_refused, refused);
        assert_eq!(m.failed, shed, "every failure here is a typed shed");
        assert_eq!(m.deadline_shed, shed);
        assert_identity(&m);
    }
}

/// The acceptance scenario: a bulk flood ahead of an Interactive
/// trickle on one card.  Oldest-first arbitration serves the older bulk
/// lane until the Interactive SLOs are long dead; SLO-aware arbitration
/// hands each freed card to the lane with the least relative slack and
/// meets them — strictly more Interactive SLOs met on the same load,
/// with every admitted reply still bit-identical to the golden model.
#[test]
fn slo_aware_arbitration_meets_strictly_more_interactive_slos() {
    let mut rng = Xoshiro256::new(0x510A);
    // Full-size synthetic CNN-A: per-frame compute in the milliseconds,
    // so the SLO margins dwarf scheduler jitter.
    let net = artifacts::synthetic_cnn_a(&mut rng, 2);
    let dims = binarray::isa::compiler::infer_input_dims(&net);
    let shape = Shape::new(dims.1, dims.0, dims.2);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want_hi = golden::forward(&net, &image, shape, None);
    let want_lo = golden::forward(&net, &image, shape, Some(2));

    // Calibrate the per-frame wall on this machine.
    let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net.clone()).unwrap();
    sys.run_frame(&image).unwrap(); // warmup
    let t0 = Instant::now();
    for _ in 0..3 {
        sys.run_frame(&image).unwrap();
    }
    let per = t0.elapsed() / 3;
    drop(sys);

    let bulk = 20usize;
    let interactive = 4usize;
    // SLO 10× one frame: ~2× what the SLO-aware schedule needs (≤ ~5
    // frames ahead of the last Interactive), ~½ the bulk flood's serial
    // time (~20 frames ahead of the first under oldest-first).
    let slo = per * 10;
    let serve = |arbitration: Arbitration| -> (u64, u64) {
        let classes = ClassTable::default()
            .with(
                ServiceClass::Interactive,
                ClassSpec {
                    slo: Some(slo),
                    dispatch_bias: None,
                    admission_limit: 0,
                },
            )
            .with(
                ServiceClass::Bulk,
                ClassSpec {
                    slo: None,
                    dispatch_bias: None,
                    admission_limit: 0,
                },
            );
        let coord = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy {
                    max_batch: 1, // arbitrate on every frame boundary
                    max_delay: Duration::ZERO,
                },
                arbitration,
                ..cfg(1, classes)
            },
            net.clone(),
        )
        .unwrap();
        coord.infer(InferRequest::new(image.clone())).unwrap(); // warmup
        let h = coord.handle();
        let mut rxs = Vec::new();
        // the flood first (the older lane), the urgent trickle behind it
        for _ in 0..bulk {
            rxs.push(h.submit(InferRequest::new(image.clone()).service(ServiceClass::Bulk)));
        }
        for _ in 0..interactive {
            rxs.push(h.submit(
                InferRequest::new(image.clone())
                    .mode(Mode::HighThroughput)
                    .service(ServiceClass::Interactive),
            ));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            match rx.recv().expect("answered") {
                Ok(reply) => {
                    let want = if i < bulk { &want_hi } else { &want_lo };
                    assert_eq!(&reply.logits, want, "frame {i} ({arbitration:?})");
                }
                Err(e) => assert!(
                    e.is_deadline() || e.is_refused(),
                    "only QoS answers expected: {e}"
                ),
            }
        }
        let m = coord.shutdown();
        assert_identity(&m);
        let c = &m.classes[ServiceClass::Interactive.index()];
        assert_eq!(
            c.slo_met + c.slo_missed + c.shed + c.admission_refused,
            interactive as u64
        );
        (c.slo_met, m.classes[ServiceClass::Bulk.index()].completed)
    };

    let (met_oldest, bulk_oldest) = serve(Arbitration::OldestFirst);
    let (met_aware, bulk_aware) = serve(Arbitration::SloAware);
    assert_eq!(bulk_oldest, bulk as u64, "bulk is never starved (oldest)");
    assert_eq!(bulk_aware, bulk as u64, "bulk is never starved (slo-aware)");
    assert!(
        met_aware > met_oldest,
        "SLO-aware arbitration must meet strictly more Interactive SLOs \
         (aware {met_aware} vs oldest {met_oldest})"
    );
    assert!(met_aware >= 1, "at least one Interactive SLO met");
}
