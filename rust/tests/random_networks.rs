//! Fuzz-style equivalence: random network topologies through the whole
//! Rust pipeline — approximation → quantization → compiler → simulator —
//! checked against the golden model at every step.
//!
//! This is the deepest invariant in the repo: for ANY network the
//! compiler accepts and ANY [N_SA, D_arch, M_arch], the cycle-accurate
//! simulator must be output-identical to the bit-accurate functional
//! model, in both accuracy modes.

use binarray::approx::algorithm2;
use binarray::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::{ArrayConfig, BinArraySystem};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256};

/// Build a random conv layer whose planes/alphas come from a *real*
/// Algorithm 2 run on random float weights (not just random signs) so the
/// value distributions match production use.
fn random_conv(
    rng: &mut Xoshiro256,
    c_in: usize,
    m: usize,
    max_d: usize,
    kh: usize,
    pool: usize,
) -> QuantLayer {
    let d = 1 + rng.below(max_d as u64) as usize;
    let n_c = kh * kh * c_in;
    let mut planes = Vec::with_capacity(d * m * n_c);
    let mut alpha_q = Vec::with_capacity(d * m);
    for _ in 0..d {
        let w: Vec<f32> = (0..n_c).map(|_| rng.normal() as f32 * 0.3).collect();
        let ap = algorithm2(&w, m, 50);
        for p in &ap.planes {
            planes.extend_from_slice(p);
        }
        for &a in &ap.alpha {
            alpha_q.push(((a * 64.0).round() as i32).clamp(1, 127) as i8);
        }
    }
    QuantLayer {
        kind: LayerKind::Conv,
        planes,
        alpha_q,
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh,
        kw: kh,
        c: c_in,
        f_alpha: 6,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool,
        stride: 1,
    }
}

fn random_dense(rng: &mut Xoshiro256, n_in: usize, m: usize, relu: bool) -> QuantLayer {
    let d = 2 + rng.below(24) as usize;
    let mut planes = Vec::new();
    let mut alpha_q = Vec::new();
    for _ in 0..d {
        let w: Vec<f32> = (0..n_in).map(|_| rng.normal() as f32 * 0.2).collect();
        let ap = algorithm2(&w, m, 50);
        for p in &ap.planes {
            planes.extend_from_slice(p);
        }
        for &a in &ap.alpha {
            alpha_q.push(((a * 64.0).round() as i32).clamp(1, 127) as i8);
        }
    }
    QuantLayer {
        kind: LayerKind::Dense,
        planes,
        alpha_q,
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 6,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    }
}

/// Generate a random but *compilable* network: conv stack whose dims walk
/// cleanly (pool divides conv output), then 1–2 dense layers.
fn random_network(rng: &mut Xoshiro256, m: usize) -> (QuantNetwork, usize) {
    // choose geometry walking forward from a random input size
    let mut layers = Vec::new();
    let c0 = 1 + rng.below(3) as usize;
    let mut c = c0;
    // first conv: pick (kh, pool) then input size that works
    let kh1 = 2 + rng.below(3) as usize; // 2..4
    let pool1 = 1 + rng.below(2) as usize; // 1..2
    let conv_out1 = pool1 * (3 + rng.below(5) as usize); // pooled-divisible
    let hw = conv_out1 + kh1 - 1;
    let l1 = random_conv(rng, c, m, 8, kh1, pool1);
    c = l1.d;
    layers.push(l1);
    let hw1 = conv_out1 / pool1;

    // optional second conv
    let mut flat_hw = hw1;
    if rng.below(2) == 0 && hw1 >= 5 {
        let kh2 = 2;
        let conv_out2 = hw1 - kh2 + 1;
        // pool that divides conv_out2 (1 always works)
        let pool2 = if conv_out2 % 2 == 0 { 2 } else { 1 };
        let l2 = random_conv(rng, c, m, 12, kh2, pool2);
        c = l2.d;
        flat_hw = conv_out2 / pool2;
        layers.push(l2);
    }

    let flat = flat_hw * flat_hw * c;
    layers.push(random_dense(rng, flat, m, true));
    let d_last = layers.last().unwrap().d;
    layers.push(random_dense(rng, d_last, m, false));

    (
        QuantNetwork {
            f_input: 7,
            layers,
        },
        hw,
    )
}

#[test]
fn simulator_equals_golden_on_random_networks() {
    prop::check(25, "sim == golden on random topologies", |rng| {
        let m = 1 + rng.below(4) as usize;
        let (net, hw) = random_network(rng, m);
        // input dims must be inferable for the compiler; skip nets whose
        // geometry is ambiguous (infer returns a different-but-valid size).
        let inferred = binarray::isa::compiler::infer_input_dims(&net);
        if inferred.0 != hw {
            return; // ambiguous geometry — legitimate skip, not a failure
        }
        let shape = Shape::new(hw, hw, net.layers[0].c);
        let image = prop::i8_vec(rng, shape.len());
        let want = golden::forward(&net, &image, shape, None);

        let cfgs = [
            ArrayConfig::new(1, 4, 1),
            ArrayConfig::new(1, 8, 2),
            ArrayConfig::new(3, 16, 2),
        ];
        for cfg in cfgs {
            if cfg.m_arch > m {
                continue;
            }
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (logits, stats) = sys.run_frame(&image).unwrap();
            assert_eq!(
                logits,
                want,
                "cfg {} m={m} hw={hw} layers={}",
                cfg.label(),
                net.layers.len()
            );
            assert!(stats.cycles > 0);
            // fast mode must equal golden with truncated levels
            if m > 1 {
                let mut sys2 = BinArraySystem::new(cfg, net.clone()).unwrap();
                sys2.set_mode(Some(1));
                let (fast, _) = sys2.run_frame(&image).unwrap();
                let want_fast = golden::forward(&net, &image, shape, Some(1));
                assert_eq!(fast, want_fast, "fast mode cfg {}", cfg.label());
            }
        }
    });
}

#[test]
fn cycle_counts_scale_down_with_bigger_arrays() {
    // "More hardware never means more cycles" holds only while windows
    // are long enough to hide the per-PA DSP serialization (window cost
    // is max(N_c, D_arch) — §V-A3's depth-wise caveat).  Restrict the
    // comparison to configs with D_arch ≤ the network's smallest N_c.
    prop::check(10, "more hardware never means more cycles", |rng| {
        let (net, hw) = random_network(rng, 2);
        let inferred = binarray::isa::compiler::infer_input_dims(&net);
        if inferred.0 != hw {
            return;
        }
        let min_nc = net.layers.iter().map(|l| l.n_c()).min().unwrap();
        let shape = Shape::new(hw, hw, net.layers[0].c);
        let image = prop::i8_vec(rng, shape.len());
        let mut prev = u64::MAX;
        for cfg in [
            ArrayConfig::new(1, 4, 2),
            ArrayConfig::new(1, 16, 2),
            ArrayConfig::new(4, 16, 2),
        ] {
            if cfg.d_arch > min_nc {
                continue;
            }
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (_, stats) = sys.run_frame(&image).unwrap();
            assert!(
                stats.cycles <= prev,
                "{}: {} > previous {prev}",
                cfg.label(),
                stats.cycles
            );
            prev = stats.cycles;
        }
    });
}

#[test]
fn pe_utilization_bounded_by_one() {
    prop::check(10, "PE utilization ∈ (0, 1]", |rng| {
        let (net, hw) = random_network(rng, 2);
        let inferred = binarray::isa::compiler::infer_input_dims(&net);
        if inferred.0 != hw {
            return;
        }
        let shape = Shape::new(hw, hw, net.layers[0].c);
        let image = prop::i8_vec(rng, shape.len());
        let cfg = ArrayConfig::new(1, 8, 2);
        let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
        let (_, stats) = sys.run_frame(&image).unwrap();
        for s in &stats.sa_stats {
            if s.cycles == 0 {
                continue;
            }
            let u = s.pe_utilization(cfg.d_arch, cfg.m_arch);
            assert!(u > 0.0 && u <= 1.0 + 1e-9, "utilization {u}");
        }
    });
}
