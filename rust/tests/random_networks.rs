//! Fuzz-style equivalence: random network topologies through the whole
//! Rust pipeline — approximation → quantization → compiler → simulator —
//! checked against the golden model at every step.
//!
//! This is the deepest invariant in the repo: for ANY network the
//! compiler accepts and ANY [N_SA, D_arch, M_arch], the cycle-accurate
//! simulator must be output-identical to the bit-accurate functional
//! model, in both accuracy modes.

use binarray::artifacts::QuantNetwork;
use binarray::binarray::{ArrayConfig, BinArraySystem};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256};
use binarray::verify::Budget;

/// Generate a random but *compilable* network via the shared generator
/// in `binarray::verify` (the differential racer's corpus source —
/// keeping this suite on the same generator means any topology it can
/// draw is also raced across kernels and shard widths over there).
fn random_network(rng: &mut Xoshiro256, m: usize) -> (QuantNetwork, usize) {
    binarray::verify::random_network(
        rng,
        m,
        &Budget {
            convs: 2,
            max_d: 12,
            max_kh: 4,
            max_pool: 2,
            max_m: 4,
            denses: 2,
        },
    )
}

#[test]
fn simulator_equals_golden_on_random_networks() {
    prop::check(25, "sim == golden on random topologies", |rng| {
        let m = 1 + rng.below(4) as usize;
        let (net, hw) = random_network(rng, m);
        // input dims must be inferable for the compiler; skip nets whose
        // geometry is ambiguous (infer returns a different-but-valid size).
        let inferred = binarray::isa::compiler::infer_input_dims(&net);
        if inferred.0 != hw {
            return; // ambiguous geometry — legitimate skip, not a failure
        }
        let shape = Shape::new(hw, hw, net.layers[0].c);
        let image = prop::i8_vec(rng, shape.len());
        let want = golden::forward(&net, &image, shape, None);

        let cfgs = [
            ArrayConfig::new(1, 4, 1),
            ArrayConfig::new(1, 8, 2),
            ArrayConfig::new(3, 16, 2),
        ];
        for cfg in cfgs {
            if cfg.m_arch > m {
                continue;
            }
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (logits, stats) = sys.run_frame(&image).unwrap();
            assert_eq!(
                logits,
                want,
                "cfg {} m={m} hw={hw} layers={}",
                cfg.label(),
                net.layers.len()
            );
            assert!(stats.cycles > 0);
            // fast mode must equal golden with truncated levels
            if m > 1 {
                let mut sys2 = BinArraySystem::new(cfg, net.clone()).unwrap();
                sys2.set_mode(Some(1));
                let (fast, _) = sys2.run_frame(&image).unwrap();
                let want_fast = golden::forward(&net, &image, shape, Some(1));
                assert_eq!(fast, want_fast, "fast mode cfg {}", cfg.label());
            }
        }
    });
}

#[test]
fn cycle_counts_scale_down_with_bigger_arrays() {
    // "More hardware never means more cycles" holds only while windows
    // are long enough to hide the per-PA DSP serialization (window cost
    // is max(N_c, D_arch) — §V-A3's depth-wise caveat).  Restrict the
    // comparison to configs with D_arch ≤ the network's smallest N_c.
    prop::check(10, "more hardware never means more cycles", |rng| {
        let (net, hw) = random_network(rng, 2);
        let inferred = binarray::isa::compiler::infer_input_dims(&net);
        if inferred.0 != hw {
            return;
        }
        let min_nc = net.layers.iter().map(|l| l.n_c()).min().unwrap();
        let shape = Shape::new(hw, hw, net.layers[0].c);
        let image = prop::i8_vec(rng, shape.len());
        let mut prev = u64::MAX;
        for cfg in [
            ArrayConfig::new(1, 4, 2),
            ArrayConfig::new(1, 16, 2),
            ArrayConfig::new(4, 16, 2),
        ] {
            if cfg.d_arch > min_nc {
                continue;
            }
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (_, stats) = sys.run_frame(&image).unwrap();
            assert!(
                stats.cycles <= prev,
                "{}: {} > previous {prev}",
                cfg.label(),
                stats.cycles
            );
            prev = stats.cycles;
        }
    });
}

#[test]
fn pe_utilization_bounded_by_one() {
    prop::check(10, "PE utilization ∈ (0, 1]", |rng| {
        let (net, hw) = random_network(rng, 2);
        let inferred = binarray::isa::compiler::infer_input_dims(&net);
        if inferred.0 != hw {
            return;
        }
        let shape = Shape::new(hw, hw, net.layers[0].c);
        let image = prop::i8_vec(rng, shape.len());
        let cfg = ArrayConfig::new(1, 8, 2);
        let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
        let (_, stats) = sys.run_frame(&image).unwrap();
        for s in &stats.sa_stats {
            if s.cycles == 0 {
                continue;
            }
            let u = s.pe_utilization(cfg.d_arch, cfg.m_arch);
            assert!(u > 0.0 && u <= 1.0 + 1e-9, "utilization {u}");
        }
    });
}
