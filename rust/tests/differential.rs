//! Tier-1 differential racing: random networks through every independent
//! implementation of the paper's arithmetic, raced to bit-identity.
//!
//! The corpus, arms, seed-replay (`BINARRAY_FUZZ_SEED=...`) and budget
//! shrinking live in `binarray::verify`; this suite is the tier-1 entry
//! point.  To replay a printed failure:
//!
//! ```text
//! BINARRAY_FUZZ_SEED=0x1234abcd/c1d4k2p1m1f1 cargo test --test differential
//! ```

use binarray::util::{prop, rng::Xoshiro256};
use binarray::verify::{self, Budget, Outcome};

/// ≥ 64 random networks × {golden, scalar plan, packed kernel, shard
/// widths 1/2/4, fast mode} to bit-identity.  On mismatch, panics with a
/// shrunk minimal reproducer seed.
#[test]
fn differential_corpus_races_64_random_networks() {
    verify::run_corpus(64);
}

/// The comparator must catch a single-logit, single-bit divergence in
/// any arm: race a healthy case against a deliberately perturbed oracle
/// (the same off-by-one an injected kernel bug would produce) and demand
/// a reported mismatch.  This is the standing proof that the corpus
/// above cannot pass vacuously.
#[test]
fn comparator_catches_a_single_bit_divergence() {
    let budget = Budget::default();
    let case = (0..64u64)
        .find_map(|s| verify::gen_case(prop::case_seed(s), &budget))
        .expect("some seed generates a network");
    // healthy: every arm agrees with the true oracle
    verify::race_case(&case).expect("healthy case races clean");

    // perturbed oracle: flip the low bit of one logit — every arm now
    // disagrees with "golden", and the racer must say so
    let shape = binarray::tensor::Shape::new(case.hw, case.hw, case.net.layers[0].c);
    let want = binarray::golden::forward(&case.net, &case.image, shape, None);
    let mut bad = want.clone();
    bad[0] ^= 1;
    let err = verify::race_case_against(&case, &bad, &bad)
        .expect_err("perturbed oracle must be detected");
    assert_eq!(err.arm, "plan+scalar", "first arm raced reports first");
    assert!(err.detail.contains("diverge"), "{err}");
}

/// A shrunk reproducer must itself fail, and replay deterministically:
/// run_one is a pure function of (seed, budget).
#[test]
fn outcomes_replay_deterministically() {
    let budget = Budget::default();
    let mut raced = 0;
    for s in 0..48u64 {
        let seed = prop::case_seed(s);
        let a = matches!(verify::run_one(seed, &budget), Outcome::Pass);
        let b = matches!(verify::run_one(seed, &budget), Outcome::Pass);
        assert_eq!(a, b, "seed {seed:#x} outcome not reproducible");
        if a {
            raced += 1;
            break; // one full double-race is enough; the corpus covers volume
        }
    }
    assert!(raced > 0, "no seed in 0..48 raced");
}

/// The generator respects its budget caps end to end (the shrinker's
/// reductions must actually make cases smaller).
#[test]
fn shrink_budgets_generate_smaller_networks() {
    let tiny = Budget {
        convs: 1,
        max_d: 2,
        max_kh: 1,
        max_pool: 1,
        max_m: 1,
        denses: 1,
    };
    let mut rng = Xoshiro256::new(11);
    let (net, hw) = verify::random_network(&mut rng, 1, &tiny);
    let full_rng = &mut Xoshiro256::new(11);
    let (big, _) = verify::random_network(full_rng, 4, &Budget::default());
    let tiny_weights: usize = net.layers.iter().map(|l| l.planes.len()).sum();
    let big_weights: usize = big.layers.iter().map(|l| l.planes.len()).sum();
    assert!(
        tiny_weights < big_weights,
        "tiny {tiny_weights} !< full {big_weights} (hw={hw})"
    );
}
