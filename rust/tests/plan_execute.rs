//! Property coverage for the plan/execute split: `run_frames` over the
//! precomputed `ExecutionPlan` must be logit-identical to the bit-accurate
//! golden model for random images across **all** paper configs, **both**
//! runtime accuracy modes and batch sizes 1/3/8 — i.e. neither the cached
//! schedules, nor the zero-copy feature-buffer views, nor the host thread
//! pool may ever change an output byte.

use binarray::artifacts::{self, LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::{ArrayConfig, BinArraySystem, PAPER_CONFIGS};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256};

/// A small but structurally complete network: two conv layers (one with
/// pooling, one ReLU-only), two dense layers (ReLU + plain), M = 4 so
/// both accuracy modes differ on every paper config.
fn small_net(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 4;
    let conv = |rng: &mut Xoshiro256, d: usize, c: usize, pool: usize, shift: u32| QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, d * m * 3 * 3 * c),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-300, 300) as i32).collect(),
        d,
        m,
        kh: 3,
        kw: 3,
        c,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift,
        relu: true,
        pool,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, nin: usize, relu: bool, shift: u32| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * nin),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-300, 300) as i32).collect(),
        d,
        m,
        kh: nin,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift,
        relu,
        pool: 1,
        stride: 1,
    };
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![
            conv(rng, 6, 3, 2, 8),  // 14×14×3 → 12×12×6 → pool2 → 6×6×6
            conv(rng, 10, 6, 1, 8), // 6×6×6 → 4×4×10 (ReLU, no pooling)
            dense(rng, 20, 160, true, 8),
            dense(rng, 7, 20, false, 7),
        ],
    };
    (net, Shape::new(14, 14, 3))
}

#[test]
fn run_frames_equals_golden_all_configs_modes_batches() {
    prop::check(4, "run_frames == golden ∀ config × mode × batch", |rng| {
        let (net, shape) = small_net(rng);
        // sanity: the compiler must reconstruct the intended geometry
        assert_eq!(
            binarray::isa::compiler::infer_input_dims(&net),
            (14, 14, 3)
        );
        let images: Vec<Vec<i8>> = (0..8).map(|_| prop::i8_vec(rng, shape.len())).collect();
        for cfg in PAPER_CONFIGS {
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            for mode in [None, Some(cfg.m_arch)] {
                sys.set_mode(mode);
                for batch_size in [1usize, 3, 8] {
                    let batch: Vec<&[i8]> =
                        images[..batch_size].iter().map(Vec::as_slice).collect();
                    let results = sys.run_frames(&batch).unwrap();
                    assert_eq!(results.len(), batch_size);
                    for (img, (logits, stats)) in batch.iter().zip(&results) {
                        let want = golden::forward(&net, img, shape, mode);
                        assert_eq!(
                            *logits,
                            want,
                            "cfg {} mode {mode:?} batch {batch_size}",
                            cfg.label()
                        );
                        assert!(stats.cycles > 0);
                    }
                }
            }
        }
    });
}

#[test]
fn host_thread_count_is_invisible_in_outputs_and_cycles() {
    prop::check(2, "threading never changes logits or cycle accounting", |rng| {
        let (net, shape) = small_net(rng);
        let img = prop::i8_vec(rng, shape.len());
        let cfg = ArrayConfig::new(4, 32, 4);
        let mut runs = Vec::new();
        for threads in [1usize, 2, 5] {
            let mut sys =
                BinArraySystem::with_host_threads(cfg, net.clone(), threads).unwrap();
            let (logits, stats) = sys.run_frame(&img).unwrap();
            runs.push((threads, logits, stats.cycles, stats.sa_stats));
        }
        let (_, logits0, cycles0, sa0) = &runs[0];
        for (threads, logits, cycles, sa) in &runs[1..] {
            assert_eq!(logits, logits0, "{threads} threads");
            assert_eq!(cycles, cycles0, "{threads} threads");
            assert_eq!(sa, sa0, "{threads} threads");
        }
    });
}

#[test]
fn cnn_a_batch_on_multi_sa_config_matches_golden() {
    // One full-size confirmation on the speedup config of the hot-path
    // bench: CNN-A, [4,32,4], a 3-frame batch in both modes.
    let mut rng = Xoshiro256::new(0xB1A);
    let net = artifacts::synthetic_cnn_a(&mut rng, 2);
    let shape = Shape::new(48, 48, 3);
    let images: Vec<Vec<i8>> = (0..3).map(|_| prop::i8_vec(&mut rng, shape.len())).collect();
    let batch: Vec<&[i8]> = images.iter().map(Vec::as_slice).collect();
    let mut sys = BinArraySystem::new(ArrayConfig::new(4, 32, 4), net.clone()).unwrap();
    for mode in [None, Some(2)] {
        sys.set_mode(mode);
        for (img, (logits, _)) in batch.iter().zip(sys.run_frames(&batch).unwrap()) {
            assert_eq!(logits, golden::forward(&net, img, shape, mode), "mode {mode:?}");
        }
    }
}
