//! Mutation tests for the static plan verifier (tier-1).
//!
//! The analyzer is only worth trusting if it is non-vacuous: every
//! corruption class it claims to catch must actually be caught, with
//! the *right* typed [`AnalysisError`] variant, through the same public
//! entry points production uses (`verify_model`, `ModelRegistry::
//! register`).  Mirrors PR 9's perturbed-oracle test for the dynamic
//! racers: first prove the clean artifact verifies, then corrupt one
//! thing and assert the verdict flips.

use binarray::analysis::{self, AnalysisError};
use binarray::artifacts::{cnn_a_or_synthetic, LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::plan::{ExecutionPlan, WorkUnit};
use binarray::binarray::{ArrayConfig, PAPER_CONFIGS};
use binarray::coordinator::ModelRegistry;
use binarray::isa::{compile_network, Instr, Reg, IMM_BITS};

/// Compile + plan a network under one config, the way the registry does.
fn compiled(cfg: ArrayConfig, net: &QuantNetwork) -> (binarray::isa::Program, ExecutionPlan) {
    let prog = compile_network(net);
    let plan = ExecutionPlan::new(cfg, net, &prog);
    (prog, plan)
}

/// A single-dense-layer network sized so the MULW proof holds at α = 1
/// but fails once α widens: `n_c · 128 · 127 > MULW_MAX` while
/// `n_c · 128 · m` stays far inside it.
fn big_dense(alpha: i8) -> QuantNetwork {
    let n_c = 16_384usize;
    let (d, m) = (2usize, 2usize);
    QuantNetwork {
        f_input: 7,
        layers: vec![QuantLayer {
            kind: LayerKind::Dense,
            planes: vec![1i8; d * m * n_c],
            alpha_q: vec![alpha; d * m],
            bias_q: vec![5; d],
            d,
            m,
            kh: n_c,
            kw: 0,
            c: 0,
            f_alpha: 6,
            f_in: 7,
            f_out: 7,
            shift: 7,
            relu: false,
            pool: 1,
            stride: 1,
        }],
    }
}

/// The CI acceptance criterion, runnable locally: every paper config ×
/// accuracy mode × shard width 1..4 proves MULW-overflow-freedom and
/// exactly-once coverage (`verify_model` iterates modes and widths
/// internally).
#[test]
fn every_paper_config_proves() {
    for cfg in PAPER_CONFIGS {
        let net = cnn_a_or_synthetic(cfg.m_arch);
        let (prog, plan) = compiled(cfg, &net);
        let report = analysis::verify_model(&net, &prog, &plan, 4)
            .unwrap_or_else(|e| panic!("config {}: {e}", cfg.label()));
        assert_eq!(report.widths, vec![1, 2, 3, 4]);
        assert_eq!(report.mode_cycles.len(), plan.max_m + 1);
    }
}

/// Mutation 1 — widen an α: the same network that proves clean at α = 1
/// is rejected with a concrete `MulwOverflow` witness at α = 127, all
/// the way through `verify_model`.
#[test]
fn widened_alpha_flips_the_verdict() {
    let cfg = ArrayConfig::new(1, 8, 2);
    let good = big_dense(1);
    let (prog, plan) = compiled(cfg, &good);
    analysis::verify_model(&good, &prog, &plan, 4).expect("α = 1 proves clean");

    let bad = big_dense(127);
    let (prog, plan) = compiled(cfg, &bad);
    match analysis::verify_model(&bad, &prog, &plan, 4).unwrap_err() {
        AnalysisError::MulwOverflow { layer, m, .. } => {
            assert_eq!((layer, m), (0, 0), "witness pins the first bad level");
        }
        other => panic!("wrong variant: {other:?}"),
    }
}

/// Mutation 2 — drop the QS shift out of the barrel shifter's range.
#[test]
fn bad_qs_shift_flips_the_verdict() {
    let cfg = ArrayConfig::new(1, 8, 2);
    let mut net = cnn_a_or_synthetic(2);
    let (prog, plan) = compiled(cfg, &net);
    analysis::verify_model(&net, &prog, &plan, 4).expect("clean network proves");

    net.layers[1].shift = 33;
    let (prog, plan) = compiled(cfg, &net);
    assert_eq!(
        analysis::verify_model(&net, &prog, &plan, 4).unwrap_err(),
        AnalysisError::BadShift { layer: 1, shift: 33 }
    );
}

/// Mutation 3 — overlap two shard tiles: the coverage lint (the exact
/// function `lint_plan`/`lint_shards` run over every real partition)
/// reports the doubly-written cell; a gapped partition reports the
/// dropped cell.
#[test]
fn overlapping_shard_tiles_flip_the_verdict() {
    let disjoint = vec![
        WorkUnit { rows: 0..3, d: 0..8 },
        WorkUnit { rows: 3..6, d: 0..8 },
    ];
    analysis::lint_cover(&disjoint, 6, 8, 2, 2).expect("disjoint cover proves");

    let overlapping = vec![
        WorkUnit { rows: 0..4, d: 0..8 },
        WorkUnit { rows: 3..6, d: 0..8 },
    ];
    match analysis::lint_cover(&overlapping, 6, 8, 2, 2).unwrap_err() {
        AnalysisError::Coverage { layer, cards, row, count, .. } => {
            assert_eq!((layer, cards, row, count), (2, 2, 3, 2));
        }
        other => panic!("wrong variant: {other:?}"),
    }

    let gapped = vec![WorkUnit { rows: 0..5, d: 0..8 }];
    match analysis::lint_cover(&gapped, 6, 8, 2, 2).unwrap_err() {
        AnalysisError::Coverage { row, count, .. } => assert_eq!((row, count), (5, 0)),
        other => panic!("wrong variant: {other:?}"),
    }
}

/// Mutation 4 — an out-of-range STI immediate: an in-memory `Instr` can
/// hold what `encode()` would refuse, and the lint must catch it before
/// emission rather than trust the assembler's panic.
#[test]
fn out_of_range_sti_immediate_flips_the_verdict() {
    let cfg = ArrayConfig::new(1, 8, 2);
    let net = cnn_a_or_synthetic(2);
    let mut prog = compile_network(&net);
    let plan = ExecutionPlan::new(cfg, &net, &prog);
    analysis::verify_model(&net, &prog, &plan, 4).expect("compiler output proves");

    let pc = prog
        .instrs
        .iter()
        .position(|i| matches!(i, Instr::Sti(Reg::InBase, _)))
        .expect("every program loads InBase");
    prog.instrs[pc] = Instr::Sti(Reg::InBase, 1 << IMM_BITS);
    assert_eq!(
        analysis::verify_model(&net, &prog, &plan, 4).unwrap_err(),
        AnalysisError::ImmOutOfRange { pc, imm: 1 << IMM_BITS }
    );
}

/// The publication gate end-to-end: the registry compiles-and-verifies
/// on `register` (and `swap`, which funnels through the same path) and
/// refuses to publish an unprovable model with a typed error message —
/// no slot is ever occupied by a plan the analyzer rejected.
#[test]
fn registry_refuses_unprovable_models() {
    let registry = ModelRegistry::new(2);
    registry
        .register("good", ArrayConfig::new(1, 8, 2), big_dense(1), 0)
        .expect("provable model publishes");

    let err = registry
        .register("bad", ArrayConfig::new(1, 8, 2), big_dense(127), 0)
        .expect_err("unprovable model must be refused");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("static analysis rejected the plan"),
        "refusal must name the analyzer: {msg}"
    );
    assert!(
        msg.contains("exceeds MULW"),
        "refusal must carry the concrete witness: {msg}"
    );
    // the refusal left no trace: only the good model is registered
    assert_eq!(registry.names().len(), 1);
}
