//! Deadline-aware dispatch end to end: expired work is shed with the
//! typed `InferError::DeadlineExceeded` before any card computes it,
//! slack routes small-but-urgent frames to the shard (latency) lane,
//! met/missed/shed are counted per lane, and — the acceptance scenario —
//! the deadline-aware router meets strictly more deadlines than a
//! deadline-blind FIFO router under the same overload, while every
//! non-shed reply stays bit-identical to `golden::forward`.
//!
//! Pool widths ride the `BINARRAY_TEST_CARDS` matrix (default `1,2,4`)
//! where arbitration is involved, like the other cross-card suites.

use std::time::{Duration, Instant};

use binarray::artifacts::{self, LayerKind, QuantLayer, QuantNetwork};
use binarray::binarray::{ArrayConfig, BinArraySystem};
use binarray::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, DispatchClass, InferError, InferRequest, Mode,
    RoutePolicy,
};
use binarray::golden;
use binarray::tensor::Shape;
use binarray::util::{prop, rng::Xoshiro256, test_cards};

/// A deliberately tiny but structurally complete net (conv+pool, two
/// dense) so the QoS paths are pushed with request counts, not compute.
fn tiny_net(rng: &mut Xoshiro256) -> (QuantNetwork, Shape) {
    let m = 2;
    let conv = QuantLayer {
        kind: LayerKind::Conv,
        planes: prop::sign_vec(rng, 4 * m * 3 * 3 * 3),
        alpha_q: (0..4 * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..4).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d: 4,
        m,
        kh: 3,
        kw: 3,
        c: 3,
        f_alpha: 5,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool: 2,
        stride: 1,
    };
    let dense = |rng: &mut Xoshiro256, d: usize, n_in: usize, relu: bool| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * n_in),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    };
    // 10×10×3 → conv3 → 8×8×4 → pool2 → 4×4×4 → dense 8 → dense 5
    let net = QuantNetwork {
        f_input: 7,
        layers: vec![conv, dense(rng, 8, 64, true), dense(rng, 5, 8, false)],
    };
    assert_eq!(binarray::isa::compiler::infer_input_dims(&net), (10, 10, 3));
    (net, Shape::new(10, 10, 3))
}

fn cfg(workers: usize, route: RoutePolicy) -> CoordinatorConfig {
    CoordinatorConfig {
        array: ArrayConfig::new(1, 8, 2),
        workers,
        policy: BatchPolicy {
            max_batch: 4,
            max_delay: Duration::from_micros(200),
        },
        route,
        ..Default::default()
    }
}

/// A request that arrives already expired is answered with the typed
/// deadline error and never touches a card: zero simulated cycles, zero
/// batches, and the pool still serves the next (live) request.
#[test]
fn expired_on_arrival_is_shed_before_any_compute() {
    let mut rng = Xoshiro256::new(0xDEAD);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    for cards in test_cards() {
        let coord = Coordinator::start(cfg(cards, RoutePolicy::BatchOnly), net.clone()).unwrap();
        let expired = Instant::now();
        let err = coord
            .infer(InferRequest::new(image.clone()).deadline(expired))
            .expect_err("expired work must be refused");
        let err: InferError = err.downcast().expect("typed InferError");
        assert!(err.is_deadline(), "typed shed, got {err:?}");
        assert!(matches!(err, InferError::DeadlineExceeded { .. }));
        // the pool is unharmed and still bit-exact
        let ok = coord
            .infer(
                InferRequest::new(image.clone())
                    .deadline(Instant::now() + Duration::from_secs(60)),
            )
            .expect("live request served");
        assert_eq!(ok.logits, want, "{cards} cards");
        let m = coord.shutdown();
        assert_eq!(m.deadline_shed, 1, "{cards} cards");
        assert_eq!(m.failed, 1, "sheds are answered failures");
        assert_eq!(m.completed, 1);
        assert_eq!(m.deadline_met, 1);
        assert_eq!(m.deadline_missed, 0);
        // the shed frame burned nothing: all cycles belong to the one
        // completed frame
        assert_eq!(m.latency.count(), 1, "only served frames record latency");
    }
}

/// Slack is the third routing signal: a frame far too small to shard by
/// size still takes the shard (latency) lane when its deadline is
/// tight, and best-effort twins batch.
#[test]
fn tight_slack_routes_small_frames_to_the_shard_lane() {
    let mut rng = Xoshiro256::new(0x51AC);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let route = RoutePolicy::Adaptive {
        shard_min_len: usize::MAX, // size alone never shards
        deep_queue: 64,
        tight_slack: Duration::from_secs(5),
    };
    let coord = Coordinator::start(cfg(2, route), net).unwrap();
    // tight slack (3s ≤ 5s) ⇒ latency lane
    let urgent = coord
        .infer(InferRequest::new(image.clone()).deadline(Instant::now() + Duration::from_secs(3)))
        .unwrap();
    assert_eq!(urgent.logits, want);
    // no deadline ⇒ never tight ⇒ batch lane
    let relaxed = coord.infer(InferRequest::new(image.clone())).unwrap();
    assert_eq!(relaxed.logits, want);
    // plenty of slack (60s > 5s) ⇒ batch lane
    let lazy = coord
        .infer(InferRequest::new(image).deadline(Instant::now() + Duration::from_secs(60)))
        .unwrap();
    assert_eq!(lazy.logits, want);
    let m = coord.shutdown();
    assert_eq!(m.routed_shard, 1, "exactly the urgent frame sharded");
    assert_eq!(m.routed_batch, 2);
    assert_eq!(m.deadline_met, 2);
    assert_eq!(m.shard_leases, 1);
}

/// Deadlined traffic across both lanes and every pool width stays
/// bit-identical to the golden model — deadlines move scheduling, never
/// arithmetic.
#[test]
fn deadlined_replies_stay_bit_exact_on_both_lanes() {
    let mut rng = Xoshiro256::new(0xB17);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want_hi = golden::forward(&net, &image, shape, None);
    let want_lo = golden::forward(&net, &image, shape, Some(2));
    for cards in test_cards() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                lease_slack: Duration::from_micros(200),
                ..cfg(cards, RoutePolicy::BatchOnly)
            },
            net.clone(),
        )
        .unwrap();
        let total = 24usize;
        let rxs: Vec<_> = (0..total)
            .map(|i| {
                let class = if i % 3 == 0 {
                    DispatchClass::Shard
                } else {
                    DispatchClass::Batch
                };
                let mode = if i % 2 == 0 {
                    Mode::HighAccuracy
                } else {
                    Mode::HighThroughput
                };
                coord.submit(
                    InferRequest::new(image.clone())
                        .mode(mode)
                        .route(class)
                        .deadline(Instant::now() + Duration::from_secs(120)),
                )
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let reply = rx.recv().unwrap().expect("generous deadlines all served");
            let want = if i % 2 == 0 { &want_hi } else { &want_lo };
            assert_eq!(&reply.logits, want, "frame {i} ({cards} cards)");
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, total as u64);
        assert_eq!(m.deadline_met, total as u64, "{cards} cards");
        assert_eq!(m.deadline_missed + m.deadline_shed, 0, "{cards} cards");
        // hysteresis observability: every lease's wait was recorded
        assert_eq!(m.lease_wait.count() as u64, m.shard_leases);
    }
}

/// The `max_batch: 0` wedge, end to end: a zero policy used to make the
/// router's cut loop spin on empty batches forever (no request was ever
/// served and `shutdown` never returned).  Clamped, it serves like
/// `max_batch: 1`.
#[test]
fn max_batch_zero_coordinator_serves_and_shuts_down() {
    let mut rng = Xoshiro256::new(0x0B0);
    let (net, shape) = tiny_net(&mut rng);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);
    let coord = Coordinator::start(
        CoordinatorConfig {
            policy: BatchPolicy {
                max_batch: 0,
                max_delay: Duration::from_micros(200),
            },
            ..cfg(1, RoutePolicy::BatchOnly)
        },
        net,
    )
    .unwrap();
    for _ in 0..3 {
        let reply = coord.infer(InferRequest::new(image.clone())).unwrap();
        assert_eq!(reply.logits, want);
    }
    let m = coord.shutdown();
    assert_eq!(m.completed, 3);
    assert_eq!(m.failed, 0);
}

/// The acceptance scenario: a mixed-QoS overload on one card.  A
/// deadline-blind FIFO router burns the card on a pile of
/// already-expired frames, so the feasible deadlines behind them miss;
/// the deadline-aware router sheds the expired pile unserved (typed
/// errors, zero compute) and meets the feasible deadlines — strictly
/// more met deadlines on the same load, with every served reply still
/// bit-identical to the golden model.
#[test]
fn aware_router_meets_strictly_more_deadlines_than_fifo() {
    let mut rng = Xoshiro256::new(0xACCE);
    // Full-size synthetic CNN-A: per-frame compute in the milliseconds,
    // so the deadline margins dwarf scheduler jitter.
    let net = artifacts::synthetic_cnn_a(&mut rng, 2);
    let dims = binarray::isa::compiler::infer_input_dims(&net);
    let shape = Shape::new(dims.1, dims.0, dims.2);
    let image = prop::i8_vec(&mut rng, shape.len());
    let want = golden::forward(&net, &image, shape, None);

    // Calibrate the per-frame wall on this machine.
    let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net.clone()).unwrap();
    sys.run_frame(&image).unwrap(); // warmup
    let t0 = Instant::now();
    for _ in 0..3 {
        sys.run_frame(&image).unwrap();
    }
    let per = t0.elapsed() / 3;
    drop(sys);

    let junk = 24usize; // expired on arrival
    let feasible = 6usize; // deadline 12×per: ~2× what aware needs, ~½ what FIFO needs
    let budget = per * 12;
    let serve = |aware: bool| -> (u64, u64, u64) {
        let coord = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy {
                    max_batch: 1,
                    max_delay: Duration::ZERO,
                },
                ..cfg(1, RoutePolicy::BatchOnly)
            },
            net.clone(),
        )
        .unwrap();
        coord.infer(InferRequest::new(image.clone())).unwrap(); // warmup
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        // the expired pile first, the feasible tail behind it — FIFO
        // order is the worst case the deadline signal exists to fix
        for i in 0..junk + feasible {
            let deadline = if i < junk { t0 } else { t0 + budget };
            rxs.push(
                coord.submit(InferRequest::new(image.clone()).deadline(aware.then_some(deadline))),
            );
        }
        let (mut met, mut missed, mut shed) = (0u64, 0u64, 0u64);
        for (i, rx) in rxs.into_iter().enumerate() {
            let deadline = if i < junk { t0 } else { t0 + budget };
            match rx.recv().unwrap() {
                Ok(reply) => {
                    assert_eq!(reply.logits, want, "served reply diverged (aware={aware})");
                    if Instant::now() <= deadline {
                        met += 1;
                    } else {
                        missed += 1;
                    }
                }
                Err(e) => {
                    assert!(e.is_deadline(), "only deadline sheds expected: {e}");
                    shed += 1;
                }
            }
        }
        coord.shutdown();
        (met, missed, shed)
    };

    let (met_fifo, _missed_fifo, shed_fifo) = serve(false);
    let (met_aware, _missed_aware, shed_aware) = serve(true);
    assert_eq!(shed_fifo, 0, "a blind router computes everything");
    assert!(
        shed_aware >= junk as u64,
        "the expired pile must be shed, got {shed_aware}"
    );
    assert!(
        met_aware > met_fifo,
        "deadline-aware router must meet strictly more deadlines \
         (aware {met_aware} vs fifo {met_fifo})"
    );
    assert!(
        met_aware >= 1,
        "at least one feasible deadline met by the aware router"
    );
}
