//! Cross-model consistency: the analytical performance model, the area
//! model, and the energy model must agree with each other and with the
//! architecture's identities wherever their domains overlap.

use binarray::binarray::{ArrayConfig, CLOCK_HZ, PAPER_CONFIGS};
use binarray::perf::energy::{binarray_energy, cpu_energy, EnergyCosts};
use binarray::util::prop;
use binarray::{area, nn, perf};

#[test]
fn fps_times_cycles_is_clock() {
    // fps = CLOCK / cycles must hold exactly for every (net, cfg, M)
    for net in [nn::cnn_a(), nn::cnn_b1(), nn::cnn_b2()] {
        for cfg in PAPER_CONFIGS {
            for m in [2usize, 4, 6] {
                let cc = perf::network_cycles(&net, cfg, m, false);
                let fps = perf::fps(&net, cfg, m, false);
                assert!((fps * cc - CLOCK_HZ).abs() / CLOCK_HZ < 1e-9);
            }
        }
    }
}

#[test]
fn offloading_the_tail_never_hurts() {
    for net in [nn::cnn_b1(), nn::cnn_b2()] {
        for cfg in PAPER_CONFIGS {
            let with = perf::network_cycles(&net, cfg, 4, true);
            let without = perf::network_cycles(&net, cfg, 4, false);
            assert!(with <= without, "{}: {with} > {without}", cfg.label());
        }
    }
}

#[test]
fn perf_monotone_in_each_design_parameter() {
    // Growing any single design parameter must never *reduce* fps, for
    // networks whose N_c always covers D_arch (CNN-A's smallest N_c is 80).
    let net = nn::cnn_a();
    prop::check(100, "fps monotone in N_SA / D_arch / M_arch", |rng| {
        let base = ArrayConfig::new(
            1 + rng.below(8) as usize,
            [8usize, 16, 32][rng.below(3) as usize],
            1 + rng.below(4) as usize,
        );
        let m = base.m_arch; // M = M_arch: single level group
        let f0 = perf::fps(&net, base, m, false);
        let more_sa = ArrayConfig::new(base.n_sa * 2, base.d_arch, base.m_arch);
        assert!(perf::fps(&net, more_sa, m, false) >= f0 - 1e-9);
        if base.d_arch < 64 {
            let more_d = ArrayConfig::new(base.n_sa, base.d_arch * 2, base.m_arch);
            assert!(perf::fps(&net, more_d, m, false) >= f0 * 0.99);
        }
    });
}

#[test]
fn area_monotone_in_each_design_parameter() {
    prop::check(100, "LUT/FF/DSP monotone in design params", |rng| {
        let base = ArrayConfig::new(
            1 + rng.below(8) as usize,
            4 + rng.below(60) as usize,
            1 + rng.below(4) as usize,
        );
        let l0 = area::logic(base);
        for bigger in [
            ArrayConfig::new(base.n_sa + 1, base.d_arch, base.m_arch),
            ArrayConfig::new(base.n_sa, base.d_arch + 8, base.m_arch),
            ArrayConfig::new(base.n_sa, base.d_arch, base.m_arch + 1),
        ] {
            let l1 = area::logic(bigger);
            assert!(l1.lut >= l0.lut && l1.ff >= l0.ff && l1.dsp >= l0.dsp);
        }
    });
}

#[test]
fn dsp_identity_for_arbitrary_configs() {
    prop::check(200, "DSP == N_SA * M_arch always", |rng| {
        let cfg = ArrayConfig::new(
            1 + rng.below(32) as usize,
            1 + rng.below(64) as usize,
            1 + rng.below(8) as usize,
        );
        assert_eq!(area::logic(cfg).dsp as usize, cfg.n_sa * cfg.m_arch);
    });
}

#[test]
fn energy_scales_linearly_in_m_arithmetic() {
    let costs = EnergyCosts::default();
    for net in [nn::cnn_a(), nn::cnn_b2()] {
        let e1 = binarray_energy(&net, 1, &costs);
        let e4 = binarray_energy(&net, 4, &costs);
        // arithmetic is exactly linear in M (M sign-adds per MAC)
        let ratio = e4.arithmetic / e1.arithmetic;
        assert!((ratio - 4.0).abs() < 0.01, "{}: ratio {ratio}", net.name);
    }
}

#[test]
fn cpu_energy_independent_of_binarization() {
    let costs = EnergyCosts::default();
    let net = nn::cnn_a();
    let a = cpu_energy(&net, &costs).total();
    let b = cpu_energy(&net, &costs).total();
    assert_eq!(a, b);
    // and strictly greater than BinArray for every M the paper uses
    for m in 1..=6 {
        assert!(a > binarray_energy(&net, m, &costs).total() * 10.0);
    }
}

#[test]
fn weight_storage_vs_compression_factor_consistent() {
    // Eq. 6's network compression factor equals
    // float_bits / weight_storage_bits computed by the area module.
    let net = nn::cnn_a();
    for m in [2usize, 3, 4] {
        let storage = area::weight_storage_bits(&net, m) as f64;
        let float_bits: f64 = net
            .layers
            .iter()
            .map(|l| (l.d_out() * (l.n_c() + 1) * 32) as f64)
            .sum();
        let cf = float_bits / storage;
        // paper Table II column: 15.8 / 10.6 / 7.9
        let want = [15.8, 10.6, 7.9][m - 2];
        // area counts bias at 32 bits vs Eq. 6's bits_alpha=8 per level —
        // allow the corresponding slack
        assert!(
            (cf - want).abs() < 0.9,
            "M={m}: storage-based cf {cf:.2} vs Eq.6 {want}"
        );
    }
}

#[test]
fn eyeriss_and_edgetpu_reference_points_in_range() {
    // Table III context columns: our largest configs should bracket the
    // published accelerator points within an order of magnitude.
    let b1_best = perf::fps(&nn::cnn_b1(), PAPER_CONFIGS[3], 4, true);
    let b2_best = perf::fps(&nn::cnn_b2(), PAPER_CONFIGS[3], 4, true);
    assert!(b1_best > perf::published::EYERISS_V2_CNN_B1_FPS * 0.3);
    assert!(b2_best > perf::published::EDGE_TPU_CNN_B2_FPS * 0.3);
}
