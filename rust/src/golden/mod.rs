//! Bit-accurate functional model of the BinArray datapath.
//!
//! The paper verifies its VHDL against "a bit-accurate Python model"
//! (§V-A2, Fig. 11).  This module is that model in Rust: an int8/int32
//! implementation of every accelerated operation with *exactly* the RTL's
//! arithmetic (sign-controlled accumulation, α cascade, QS rounding and
//! saturation, fused ReLU+max-pool).  It is the reference the
//! cycle-accurate simulator must match output-for-output, and it must in
//! turn match the numpy oracle logits shipped in `golden.bin`.

use crate::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use crate::fixp;
use crate::tensor::{FeatureMap, Shape};

/// Below this plane length the per-call activation packing of the
/// popcount path costs more than it saves, so [`binary_dot`] keeps the
/// scalar walk.  At or above it, one [`PackedActs::pack`] of the patch is
/// amortized over the layer's `m_run` binary levels, and each level's dot
/// shrinks from `n_c` multiply-adds to `n_c/64` AND+popcount words per
/// activation bit.
const POPCOUNT_MIN_NC: usize = 64;

/// Run one binary dot product (Eq. 8) over an im2col patch / dense input.
///
/// `m_run` truncates to the first `m_run` binary levels (high-throughput
/// mode, §IV-D); pass `layer.m` for high-accuracy mode.
///
/// Long patches take the explicit `count_ones` path (the `2P − S`
/// identity of [`signed_dot_popcount`], activations packed once per call
/// and reused across all `m_run` levels); short ones keep the scalar
/// walk.  Both are exact — `tests` race them on every length.
#[inline]
pub fn binary_dot(layer: &QuantLayer, d: usize, x: &[i8], m_run: usize) -> i32 {
    let n_c = layer.n_c();
    debug_assert_eq!(x.len(), n_c);
    let levels = m_run.min(layer.m);
    let mut acc_total: i32 = layer.bias_q[d];
    if n_c >= POPCOUNT_MIN_NC && levels > 0 {
        return ACTS_SCRATCH.with(|cell| {
            let mut acts = cell.borrow_mut();
            acts.pack(x);
            for m in 0..levels {
                let base = (d * layer.m + m) * n_c;
                let plane = &layer.planes[base..base + n_c];
                let p = signed_dot_popcount(plane, &acts);
                debug_assert!(fixp::fits_mulw(p), "PE accumulator overflow: {p}");
                acc_total += p * i32::from(layer.alpha(d, m));
            }
            acc_total
        });
    }
    for m in 0..levels {
        // PE: sign-controlled accumulation, Eq. 9
        let base = (d * layer.m + m) * n_c;
        let plane = &layer.planes[base..base + n_c];
        let p = signed_dot(plane, x);
        debug_assert!(fixp::fits_mulw(p), "PE accumulator overflow: {p}");
        // DSP: multiply by α and cascade-add (Eq. 11)
        acc_total += p * i32::from(layer.alpha(d, m));
    }
    acc_total
}

thread_local! {
    /// Per-thread activation-pack scratch for [`binary_dot`] — keeps the
    /// oracle's public API stateless while avoiding an allocation per dot.
    static ACTS_SCRATCH: std::cell::RefCell<PackedActs> =
        std::cell::RefCell::new(PackedActs::default());
}

/// An int8 activation vector sliced into its 8 two's-complement bitplanes
/// (bit `k` of every element gathered into one `u64`-packed plane) — the
/// activation half of the `2P − S` popcount identity, mirrored from the
/// product kernel's `BitPatch` but kept dependency-free so the oracle
/// never shares code with the implementation it checks.
#[derive(Default)]
pub struct PackedActs {
    /// `planes[k][w]` holds bit `k` of elements `64w .. 64w+63`.
    planes: [Vec<u64>; 8],
    /// Per-bitplane total popcount `S_k` (element count with bit `k`
    /// set), precomputed at pack time — plane-independent in `2P − S`.
    s: [i32; 8],
    len: usize,
}

impl PackedActs {
    /// Pack `x` into bitplanes, reusing the existing buffers.
    pub fn pack(&mut self, x: &[i8]) {
        let words = x.len().div_ceil(64);
        for plane in &mut self.planes {
            plane.clear();
            plane.resize(words, 0);
        }
        for (w, chunk) in x.chunks(64).enumerate() {
            for (j, &xi) in chunk.iter().enumerate() {
                let v = xi as u8 as u64;
                for k in 0..8 {
                    self.planes[k][w] |= ((v >> k) & 1) << j;
                }
            }
        }
        for k in 0..8 {
            self.s[k] = self.planes[k].iter().map(|w| w.count_ones() as i32).sum();
        }
        self.len = x.len();
    }

    /// Number of packed activations.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// `Σ b_i·x_i` via the explicit `count_ones` path: with `x_i =
/// Σ_{k<7} 2^k·bit_k(x_i) − 128·bit_7(x_i)` and `b ∈ {±1}`,
///
/// ```text
/// Σ b_i·x_i = Σ_k w_k·(2·P_k − S_k),   w_k = 2^k (k<7), −128 (k=7)
/// ```
///
/// where `S_k` is the popcount of activation bitplane `k` and `P_k` its
/// popcount restricted to positions with `b_i = +1` — the same `2P − S`
/// identity the paper's PE (Eq. 9) and the product kernel
/// ([`crate::kernel`]) are built on, derived independently here so the
/// oracle and the kernel can disagree only if one of them is wrong.
pub fn signed_dot_popcount(plane: &[i8], acts: &PackedActs) -> i32 {
    assert_eq!(plane.len(), acts.len, "plane/activation length mismatch");
    let mut p = [0i32; 8];
    for (w, chunk) in plane.chunks(64).enumerate() {
        let mut bplus = 0u64;
        for (j, &b) in chunk.iter().enumerate() {
            bplus |= u64::from(b > 0) << j;
        }
        for (k, pk) in p.iter_mut().enumerate() {
            *pk += (acts.planes[k][w] & bplus).count_ones() as i32;
        }
    }
    let mut total = 0i32;
    for k in 0..7 {
        total += (2 * p[k] - acts.s[k]) << k;
    }
    total - ((2 * p[7] - acts.s[7]) << 7)
}

/// `Σ b_i·x_i` with `b ∈ {±1}` — the PE datapath's arithmetic, written to
/// autovectorize: 64-element chunks accumulate in i16 lanes (|chunk sum| ≤
/// 64·128 = 8192 < 2^15, so i16 never overflows), folded into i32.
/// ~2.4× faster than the scalar widening loop on the simulator hot path
/// (EXPERIMENTS.md §Perf).  This stays the semantic reference: the
/// product path's bit-packed popcount twin lives in [`crate::kernel`]
/// and is raced against this function bit-for-bit in
/// `tests/kernel_exactness.rs`.
#[inline]
pub fn signed_dot(plane: &[i8], x: &[i8]) -> i32 {
    debug_assert_eq!(plane.len(), x.len());
    let mut total = 0i32;
    let mut it_b = plane.chunks_exact(64);
    let mut it_x = x.chunks_exact(64);
    for (cb, cx) in (&mut it_b).zip(&mut it_x) {
        let mut s = 0i16;
        for i in 0..64 {
            s += i16::from(cb[i]) * i16::from(cx[i]);
        }
        total += i32::from(s);
    }
    for (&b, &xi) in it_b.remainder().iter().zip(it_x.remainder()) {
        total += i32::from(b) * i32::from(xi);
    }
    total
}

/// Convolution layer: AGU-ordered windows → PE dot products → QS.
/// Returns the pre-pool feature map.
pub fn conv_layer(layer: &QuantLayer, input: &FeatureMap, m_run: usize) -> FeatureMap {
    assert_eq!(layer.kind, LayerKind::Conv);
    let out_shape = input
        .shape
        .conv_out(layer.kh, layer.kw, layer.stride, layer.d);
    let mut out = FeatureMap::zeros(out_shape);
    let mut patch = Vec::with_capacity(layer.n_c());
    for y in 0..out_shape.h {
        for x in 0..out_shape.w {
            input.patch(
                y * layer.stride,
                x * layer.stride,
                layer.kh,
                layer.kw,
                &mut patch,
            );
            for d in 0..layer.d {
                let acc = binary_dot(layer, d, &patch, m_run);
                out.set(y, x, d, fixp::qs(acc, layer.shift));
            }
        }
    }
    out
}

/// Fused ReLU + N_p×N_p max-pool (the AMU, Eq. 13: y_0 = 0 seeds the max,
/// which implements ReLU).
pub fn relu_maxpool(input: &FeatureMap, pool: usize) -> FeatureMap {
    assert!(
        input.shape.h % pool == 0 && input.shape.w % pool == 0,
        "AMU supports downsampling only ({}x{} vs pool {pool})",
        input.shape.h,
        input.shape.w,
    );
    let out_shape = input.shape.pool_out(pool);
    let mut out = FeatureMap::zeros(out_shape);
    for y in 0..out_shape.h {
        for x in 0..out_shape.w {
            for c in 0..out_shape.c {
                let mut best: i8 = 0; // y_0 = 0 → ReLU for free
                for dy in 0..pool {
                    for dx in 0..pool {
                        best = best.max(input.get(y * pool + dy, x * pool + dx, c));
                    }
                }
                out.set(y, x, c, best);
            }
        }
    }
    out
}

/// ReLU only (conv layers without pooling).
pub fn relu(input: &mut FeatureMap) {
    for v in &mut input.data {
        *v = (*v).max(0);
    }
}

/// Dense layer over a flat int8 input.
pub fn dense_layer(layer: &QuantLayer, input: &[i8], m_run: usize) -> Vec<i8> {
    assert_eq!(layer.kind, LayerKind::Dense);
    assert_eq!(input.len(), layer.n_c(), "dense input length mismatch");
    (0..layer.d)
        .map(|d| {
            let mut v = fixp::qs(binary_dot(layer, d, input, m_run), layer.shift);
            if layer.relu {
                v = v.max(0);
            }
            v
        })
        .collect()
}

/// Full-network int8 inference. `m_run = None` runs all binary levels.
pub fn forward(net: &QuantNetwork, image: &[i8], shape: Shape, m_run: Option<usize>) -> Vec<i8> {
    let mut fm = FeatureMap::from_vec(shape, image.to_vec());
    let mut flat: Option<Vec<i8>> = None;
    for layer in &net.layers {
        let mr = m_run.unwrap_or(layer.m);
        match layer.kind {
            LayerKind::Conv => {
                let conv = conv_layer(layer, &fm, mr);
                fm = if layer.pool > 1 {
                    relu_maxpool(&conv, layer.pool)
                } else {
                    let mut c = conv;
                    if layer.relu {
                        relu(&mut c);
                    }
                    c
                };
            }
            LayerKind::Dense => {
                let input = flat.take().unwrap_or_else(|| fm.data.clone());
                flat = Some(dense_layer(layer, &input, mr));
            }
        }
    }
    flat.unwrap_or_else(|| fm.data.clone())
}

/// Argmax over int8 logits (first maximum wins, matching numpy).
pub fn argmax(logits: &[i8]) -> usize {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Xoshiro256};

    /// Hand-build a conv QuantLayer for tests.
    pub(crate) fn test_conv_layer(
        rng: &mut Xoshiro256,
        d: usize,
        m: usize,
        kh: usize,
        kw: usize,
        c: usize,
        shift: u32,
        pool: usize,
    ) -> QuantLayer {
        let n_c = kh * kw * c;
        QuantLayer {
            kind: LayerKind::Conv,
            planes: prop::sign_vec(rng, d * m * n_c),
            alpha_q: (0..d * m).map(|_| rng.range_i64(1, 64) as i8).collect(),
            bias_q: (0..d).map(|_| rng.range_i64(-500, 500) as i32).collect(),
            d,
            m,
            kh,
            kw,
            c,
            f_alpha: 5,
            f_in: 7,
            f_out: 6,
            shift,
            relu: true,
            pool,
            stride: 1,
        }
    }

    #[test]
    fn binary_dot_matches_naive() {
        prop::check(100, "binary_dot == naive Eq.8", |rng| {
            let (d, m, nc) = (
                1 + rng.below(4) as usize,
                1 + rng.below(4) as usize,
                1 + rng.below(64) as usize,
            );
            let layer = QuantLayer {
                kind: LayerKind::Dense,
                planes: prop::sign_vec(rng, d * m * nc),
                alpha_q: (0..d * m).map(|_| rng.i8()).collect(),
                bias_q: (0..d).map(|_| rng.range_i64(-1000, 1000) as i32).collect(),
                d,
                m,
                kh: nc,
                kw: 0,
                c: 0,
                f_alpha: 5,
                f_in: 7,
                f_out: 6,
                shift: 6,
                relu: false,
                pool: 1,
                stride: 1,
            };
            let x = prop::i8_vec(rng, nc);
            for dd in 0..d {
                let mut want: i64 = layer.bias_q[dd] as i64;
                for mm in 0..m {
                    let mut p: i64 = 0;
                    for i in 0..nc {
                        p += i64::from(layer.plane(dd, mm, i)) * i64::from(x[i]);
                    }
                    want += p * i64::from(layer.alpha(dd, mm));
                }
                assert_eq!(binary_dot(&layer, dd, &x, m) as i64, want);
            }
        });
    }

    #[test]
    fn signed_dot_matches_scalar_all_lengths() {
        // the vectorized chunked kernel must be exact for every length,
        // including the i16-overflow-adjacent extremes
        prop::check(200, "signed_dot == scalar reference", |rng| {
            let n = rng.below(300) as usize;
            let plane = prop::sign_vec(rng, n);
            let x = prop::i8_vec(rng, n);
            let want: i32 = plane
                .iter()
                .zip(&x)
                .map(|(&b, &xi)| i32::from(b) * i32::from(xi))
                .sum();
            assert_eq!(signed_dot(&plane, &x), want, "n={n}");
        });
        // extreme case: all -1 signs against all -128 activations (the
        // largest per-chunk magnitude: 64·128 = 8192, must not wrap i16)
        let plane = vec![-1i8; 192];
        let x = vec![-128i8; 192];
        assert_eq!(signed_dot(&plane, &x), 192 * 128);
        let plane = vec![1i8; 192];
        assert_eq!(signed_dot(&plane, &x), -192 * 128);
    }

    #[test]
    fn signed_dot_popcount_matches_scalar_walk() {
        // the explicit count_ones path must agree with the scalar walk on
        // every length: word boundaries, tails, and the sign extremes
        prop::check(200, "popcount 2P−S == scalar walk", |rng| {
            let n = rng.below(400) as usize;
            let plane = prop::sign_vec(rng, n);
            let x = prop::i8_vec(rng, n);
            let mut acts = PackedActs::default();
            acts.pack(&x);
            assert_eq!(acts.len(), n);
            assert_eq!(signed_dot_popcount(&plane, &acts), signed_dot(&plane, &x), "n={n}");
        });
        // extremes: ±1 planes against the most negative activation, where
        // the bit-7 weight (−128) dominates every other bitplane
        let mut acts = PackedActs::default();
        for n in [0usize, 1, 63, 64, 65, 192] {
            let x = vec![-128i8; n];
            acts.pack(&x);
            let plane = vec![-1i8; n];
            assert_eq!(signed_dot_popcount(&plane, &acts), n as i32 * 128);
            let plane = vec![1i8; n];
            assert_eq!(signed_dot_popcount(&plane, &acts), -(n as i32) * 128);
        }
    }

    #[test]
    fn binary_dot_popcount_branch_matches_naive() {
        // n_c straddles POPCOUNT_MIN_NC so both binary_dot branches race
        // the same naive i64 reference
        prop::check(60, "binary_dot (both branches) == naive", |rng| {
            let (d, m) = (1 + rng.below(3) as usize, 1 + rng.below(4) as usize);
            let nc = POPCOUNT_MIN_NC - 8 + rng.below(200) as usize;
            let layer = QuantLayer {
                kind: LayerKind::Dense,
                planes: prop::sign_vec(rng, d * m * nc),
                alpha_q: (0..d * m).map(|_| rng.i8()).collect(),
                bias_q: (0..d).map(|_| rng.range_i64(-1000, 1000) as i32).collect(),
                d,
                m,
                kh: nc,
                kw: 0,
                c: 0,
                f_alpha: 5,
                f_in: 7,
                f_out: 6,
                shift: 6,
                relu: false,
                pool: 1,
                stride: 1,
            };
            let x = prop::i8_vec(rng, nc);
            for dd in 0..d {
                for m_run in 0..=m {
                    let mut want: i64 = layer.bias_q[dd] as i64;
                    for mm in 0..m_run {
                        let mut p: i64 = 0;
                        for i in 0..nc {
                            p += i64::from(layer.plane(dd, mm, i)) * i64::from(x[i]);
                        }
                        want += p * i64::from(layer.alpha(dd, mm));
                    }
                    assert_eq!(
                        binary_dot(&layer, dd, &x, m_run) as i64,
                        want,
                        "d={dd} m_run={m_run} nc={nc}"
                    );
                }
            }
        });
    }

    #[test]
    fn m_run_truncation_partial_sums() {
        let mut rng = Xoshiro256::new(3);
        let layer = test_conv_layer(&mut rng, 1, 4, 1, 1, 8, 0, 1);
        let x = prop::i8_vec(&mut rng, 8);
        // m_run=k equals bias + sum of first k level contributions
        let mut partials = vec![layer.bias_q[0]];
        for m in 0..4 {
            let mut p = 0i32;
            for i in 0..8 {
                p += i32::from(layer.plane(0, m, i)) * i32::from(x[i]);
            }
            partials.push(partials[m] + p * i32::from(layer.alpha(0, m)));
        }
        for k in 0..=4 {
            assert_eq!(binary_dot(&layer, 0, &x, k), partials[k]);
        }
    }

    #[test]
    fn relu_maxpool_seeded_zero() {
        // all-negative inputs pool to exactly 0
        let fm = FeatureMap::from_vec(Shape::new(4, 4, 2), vec![-5; 32]);
        let out = relu_maxpool(&fm, 2);
        assert!(out.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn relu_maxpool_matches_separate_ops() {
        prop::check(100, "fused == relu then pool", |rng| {
            let pool = [2usize, 3][rng.below(2) as usize];
            let hw = pool * (1 + rng.below(4) as usize);
            let c = 1 + rng.below(5) as usize;
            let fm = FeatureMap::from_vec(
                Shape::new(hw, hw, c),
                prop::i8_vec(rng, hw * hw * c),
            );
            let fused = relu_maxpool(&fm, pool);
            // separate: relu first, then max
            let mut r = fm.clone();
            relu(&mut r);
            for y in 0..hw / pool {
                for x in 0..hw / pool {
                    for ch in 0..c {
                        let mut best = i8::MIN;
                        for dy in 0..pool {
                            for dx in 0..pool {
                                best = best.max(r.get(y * pool + dy, x * pool + dx, ch));
                            }
                        }
                        assert_eq!(fused.get(y, x, ch), best);
                    }
                }
            }
        });
    }

    #[test]
    fn conv_layer_shapes() {
        let mut rng = Xoshiro256::new(5);
        let layer = test_conv_layer(&mut rng, 5, 2, 7, 7, 3, 8, 2);
        let input = FeatureMap::from_vec(
            Shape::new(48, 48, 3),
            prop::i8_vec(&mut rng, 48 * 48 * 3),
        );
        let out = conv_layer(&layer, &input, 2);
        assert_eq!(out.shape, Shape::new(42, 42, 5));
        let pooled = relu_maxpool(&out, 2);
        assert_eq!(pooled.shape, Shape::new(21, 21, 5));
    }

    #[test]
    fn dense_relu_applied() {
        let mut rng = Xoshiro256::new(7);
        let mut layer = QuantLayer {
            kind: LayerKind::Dense,
            planes: prop::sign_vec(&mut rng, 2 * 1 * 4),
            alpha_q: vec![1, 1],
            bias_q: vec![-10_000, 10_000],
            d: 2,
            m: 1,
            kh: 4,
            kw: 0,
            c: 0,
            f_alpha: 0,
            f_in: 7,
            f_out: 7,
            shift: 0,
            relu: true,
            pool: 1,
            stride: 1,
        };
        let out = dense_layer(&layer, &[0, 0, 0, 0], 1);
        assert_eq!(out, vec![0, 127]); // relu clamps the −, QS saturates the +
        layer.relu = false;
        let out = dense_layer(&layer, &[0, 0, 0, 0], 1);
        assert_eq!(out, vec![-128, 127]);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1, 5, 5, 2]), 1);
        assert_eq!(argmax(&[-3]), 0);
    }
}
