//! Differential racing of every inference path against the golden model.
//!
//! The exactness suites pin the four paper configs; this module is the
//! *generative* half of the correctness story: it builds random-but-
//! compilable networks (random layer counts, channel widths, kernel
//! sizes, pooling geometries, M ∈ 1..=4 approximation orders) and races
//! every independent implementation of the same arithmetic to
//! bit-identity:
//!
//! - [`crate::golden::forward`] — the bit-accurate reference;
//! - the plan executor with the **scalar** kernel forced;
//! - the plan executor with the **packed** popcount kernel forced;
//! - the sharded data path at widths 1, 2 and 4
//!   ([`BinArraySystem::run_frame_sharded`]);
//! - high-throughput mode (`m_run = 1`) on both kernels when `M > 1`;
//! - the static analyzer ([`crate::analysis::verify_model`]) as a
//!   proof-side arm: every compilable case must also *verify* (range
//!   proof + schedule/ISA lint), so analyzer false-positives surface
//!   under the same seed-replayable fuzz loop as logits divergences.
//!
//! Every case derives from one `u64` seed, so a failure replays exactly:
//!
//! ```text
//! BINARRAY_FUZZ_SEED=0x1234abcd cargo test --test differential
//! BINARRAY_FUZZ_SEED=0x1234abcd/c1d4k2p1m1f1 cargo test --test differential
//! ```
//!
//! (the optional `/c..d..k..p..m..f..` suffix is the generator [`Budget`]
//! the shrinker minimized the failure under — omitted, the full default
//! budget is used).  On a mismatch the corpus runner shrinks the budget
//! dimension by dimension until the failure stops reproducing, then
//! prints the minimal `seed/budget` reproducer.  See EXPERIMENTS.md
//! §Correctness for the workflow.

use crate::approx::algorithm2;
use crate::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use crate::binarray::plan::ShardPlan;
use crate::binarray::{ArrayConfig, BinArraySystem};
use crate::golden;
use crate::kernel::KernelKind;
use crate::tensor::Shape;
use crate::util::{prop, rng::Xoshiro256};

/// Size caps for the network generator — the shrinker's knobs.  Every
/// field is a cap, not an exact count: the generator draws below it.
/// Shrinking lowers one cap at a time and re-races; a failure that still
/// reproduces under `c1d2k1p1m1f1` involves one 1×1-kernel conv with ≤ 2
/// output channels, one classifier dense and a single binary level —
/// about the smallest network the compiler accepts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Budget {
    /// Max conv layers (≥ 1).
    pub convs: usize,
    /// Max output channels per layer (≥ 1).
    pub max_d: usize,
    /// Max conv kernel height/width (≥ 1; 1 = 1×1 convs only).
    pub max_kh: usize,
    /// Max pooling factor (≥ 1; 1 = no pooling).
    pub max_pool: usize,
    /// Max approximation order M (≥ 1).
    pub max_m: usize,
    /// Max dense layers before the classifier (≥ 1 total dense layers).
    pub denses: usize,
}

impl Default for Budget {
    fn default() -> Self {
        Self {
            convs: 3,
            max_d: 16,
            max_kh: 4,
            max_pool: 3,
            max_m: 4,
            denses: 2,
        }
    }
}

impl std::fmt::Display for Budget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "c{}d{}k{}p{}m{}f{}",
            self.convs, self.max_d, self.max_kh, self.max_pool, self.max_m, self.denses
        )
    }
}

impl std::str::FromStr for Budget {
    type Err = String;

    /// Parse the `c..d..k..p..m..f..` form [`Display`](std::fmt::Display)
    /// prints (the replay suffix of `BINARRAY_FUZZ_SEED`).
    fn from_str(s: &str) -> Result<Self, String> {
        let mut vals = [0usize; 6];
        let mut rest = s;
        for (i, tag) in ['c', 'd', 'k', 'p', 'm', 'f'].into_iter().enumerate() {
            rest = rest
                .strip_prefix(tag)
                .ok_or_else(|| format!("budget {s:?}: expected '{tag}' next"))?;
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if digits.is_empty() {
                return Err(format!("budget {s:?}: '{tag}' needs a number"));
            }
            vals[i] = digits.parse().map_err(|e| format!("budget {s:?}: {e}"))?;
            if vals[i] == 0 {
                return Err(format!("budget {s:?}: '{tag}' must be ≥ 1"));
            }
            rest = &rest[digits.len()..];
        }
        if !rest.is_empty() {
            return Err(format!("budget {s:?}: trailing {rest:?}"));
        }
        Ok(Self {
            convs: vals[0],
            max_d: vals[1],
            max_kh: vals[2],
            max_pool: vals[3],
            max_m: vals[4],
            denses: vals[5],
        })
    }
}

/// Build a random conv layer whose planes/alphas come from a *real*
/// Algorithm 2 run on random float weights (not just random signs), so
/// value distributions match production networks.
fn random_conv(
    rng: &mut Xoshiro256,
    c_in: usize,
    m: usize,
    max_d: usize,
    kh: usize,
    pool: usize,
) -> QuantLayer {
    let d = 1 + rng.below(max_d as u64) as usize;
    let n_c = kh * kh * c_in;
    let mut planes = Vec::with_capacity(d * m * n_c);
    let mut alpha_q = Vec::with_capacity(d * m);
    for _ in 0..d {
        let w: Vec<f32> = (0..n_c).map(|_| rng.normal() as f32 * 0.3).collect();
        let ap = algorithm2(&w, m, 50);
        for p in &ap.planes {
            planes.extend_from_slice(p);
        }
        for &a in &ap.alpha {
            alpha_q.push(((a * 64.0).round() as i32).clamp(1, 127) as i8);
        }
    }
    QuantLayer {
        kind: LayerKind::Conv,
        planes,
        alpha_q,
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh,
        kw: kh,
        c: c_in,
        f_alpha: 6,
        f_in: 7,
        f_out: 6,
        shift: 7,
        relu: true,
        pool,
        stride: 1,
    }
}

/// Build a random dense layer the same way.
fn random_dense(
    rng: &mut Xoshiro256,
    n_in: usize,
    m: usize,
    max_d: usize,
    relu: bool,
) -> QuantLayer {
    let d = 2 + rng.below(2 * max_d as u64) as usize;
    let mut planes = Vec::new();
    let mut alpha_q = Vec::new();
    for _ in 0..d {
        let w: Vec<f32> = (0..n_in).map(|_| rng.normal() as f32 * 0.2).collect();
        let ap = algorithm2(&w, m, 50);
        for p in &ap.planes {
            planes.extend_from_slice(p);
        }
        for &a in &ap.alpha {
            alpha_q.push(((a * 64.0).round() as i32).clamp(1, 127) as i8);
        }
    }
    QuantLayer {
        kind: LayerKind::Dense,
        planes,
        alpha_q,
        bias_q: (0..d).map(|_| rng.range_i64(-200, 200) as i32).collect(),
        d,
        m,
        kh: n_in,
        kw: 0,
        c: 0,
        f_alpha: 6,
        f_in: 6,
        f_out: 6,
        shift: 6,
        relu,
        pool: 1,
        stride: 1,
    }
}

/// Generate a random but *compilable* network under `budget`: a conv
/// stack whose dims walk cleanly forward (every pool divides its conv
/// output), then dense layers.  Returns the network and the input
/// height/width it was built for.  The caller must still skip networks
/// whose geometry is ambiguous to [`crate::isa::compiler::infer_input_dims`]
/// (the compiler would pick a different-but-valid input size).
pub fn random_network(rng: &mut Xoshiro256, m: usize, budget: &Budget) -> (QuantNetwork, usize) {
    let mut layers = Vec::new();
    let c0 = 1 + rng.below(3) as usize;
    let mut c = c0;

    // First conv: pick (kernel, pool), then an input size that works.
    let kh1 = 1 + rng.below(budget.max_kh as u64) as usize;
    let pool1 = 1 + rng.below(budget.max_pool as u64) as usize;
    let conv_out1 = pool1 * (2 + rng.below(5) as usize); // pooled-divisible
    let hw = conv_out1 + kh1 - 1;
    let l1 = random_conv(rng, c, m, budget.max_d, kh1, pool1);
    c = l1.d;
    layers.push(l1);
    let mut cur_hw = conv_out1 / pool1;

    // Deeper convs while the budget and the geometry allow.
    let extra_convs = rng.below(budget.convs as u64) as usize;
    for _ in 0..extra_convs {
        if cur_hw < 2 {
            break;
        }
        let kh = 1 + rng.below(budget.max_kh.min(cur_hw) as u64) as usize;
        let conv_out = cur_hw - kh + 1;
        // random pool among the factors of conv_out within budget
        let pools: Vec<usize> = (1..=budget.max_pool)
            .filter(|p| conv_out % p == 0)
            .collect();
        let pool = pools[rng.below(pools.len() as u64) as usize];
        let l = random_conv(rng, c, m, budget.max_d, kh, pool);
        c = l.d;
        cur_hw = conv_out / pool;
        layers.push(l);
    }

    // Dense stack: 0..budget.denses hidden relu denses + one classifier.
    let mut flat = cur_hw * cur_hw * c;
    for _ in 0..rng.below(budget.denses as u64) as usize {
        let l = random_dense(rng, flat, m, budget.max_d, true);
        flat = l.d;
        layers.push(l);
    }
    layers.push(random_dense(rng, flat, m, budget.max_d, false));

    (QuantNetwork { f_input: 7, layers }, hw)
}

/// One fully-drawn differential case: the network, its input image, and
/// the array config the plan arms compile for.
pub struct Case {
    pub net: QuantNetwork,
    pub hw: usize,
    pub image: Vec<i8>,
    pub cfg: ArrayConfig,
    pub m: usize,
}

/// Draw the case for `seed` under `budget`.  `None` = the drawn geometry
/// is ambiguous to the compiler, or degenerate — a legitimate skip, not
/// a failure (the corpus runner draws another seed).
pub fn gen_case(seed: u64, budget: &Budget) -> Option<Case> {
    let mut rng = Xoshiro256::new(seed);
    let m = 1 + rng.below(budget.max_m as u64) as usize;
    let (net, hw) = random_network(&mut rng, m, budget);
    if crate::isa::compiler::infer_input_dims(&net).0 != hw {
        return None; // ambiguous geometry
    }
    let c0 = net.layers[0].c;
    if hw * hw * c0 > 8192 {
        return None; // keep the corpus cheap enough for tier-1
    }
    let image = prop::i8_vec(&mut rng, hw * hw * c0);
    let n_sa = [1usize, 2, 3][rng.below(3) as usize];
    let d_arch = [4usize, 8, 16][rng.below(3) as usize];
    let m_arch = 1 + rng.below(m as u64) as usize;
    Some(Case {
        net,
        hw,
        image,
        cfg: ArrayConfig::new(n_sa, d_arch, m_arch),
        m,
    })
}

/// One divergence between an arm and the golden reference.
#[derive(Debug)]
pub struct Mismatch {
    /// Which arm diverged (`"plan+scalar"`, `"shard×2"`, …).
    pub arm: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Mismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.arm, self.detail)
    }
}

fn check_arm(arm: &'static str, got: &[i8], want: &[i8]) -> Result<(), Mismatch> {
    if got == want {
        return Ok(())
    }
    Err(Mismatch {
        arm,
        detail: format!("logits diverge from golden: got {got:?}, want {want:?}"),
    })
}

/// Race every arm of `case` against the supplied oracle logits.  Split
/// from [`race_case`] so the comparator itself is testable: feeding a
/// deliberately perturbed oracle must report a mismatch on every arm.
pub fn race_case_against(case: &Case, want: &[i8], want_fast: &[i8]) -> Result<(), Mismatch> {
    let fail = |arm: &'static str, e: anyhow::Error| Mismatch {
        arm,
        detail: format!("arm failed to build/run: {e:#}"),
    };
    let shape = Shape::new(case.hw, case.hw, case.net.layers[0].c);
    debug_assert_eq!(shape.len(), case.image.len());

    // Arm: the static analyzer.  Not a logits comparison — the proof
    // obligation is that every randomly generated, compilable network
    // verifies: the MULW range analysis must not reject a network the
    // dynamic arms execute correctly (the generator's worst-case
    // activation mass sits far inside the 28-bit envelope), and the
    // schedule/ISA lints must accept every plan the racers run.
    {
        let prog = crate::isa::compile_network(&case.net);
        let plan = crate::binarray::plan::ExecutionPlan::new(case.cfg, &case.net, &prog);
        crate::analysis::verify_model(&case.net, &prog, &plan, 4).map_err(|e| Mismatch {
            arm: "analysis",
            detail: format!("static analyzer rejected a racing-clean case: {e}"),
        })?;
    }

    // Arm: plan executor, scalar kernel forced.
    let mut scalar = BinArraySystem::with_host_threads(case.cfg, case.net.clone(), 1)
        .map_err(|e| fail("plan+scalar", e))?;
    scalar.set_kernel(KernelKind::Scalar);
    let (logits, _) = scalar.run_frame(&case.image).map_err(|e| fail("plan+scalar", e))?;
    check_arm("plan+scalar", &logits, want)?;

    // Arm: plan executor, packed popcount kernel forced.
    let mut packed = BinArraySystem::with_host_threads(case.cfg, case.net.clone(), 1)
        .map_err(|e| fail("plan+packed", e))?;
    packed.set_kernel(KernelKind::Packed);
    let (logits, _) = packed.run_frame(&case.image).map_err(|e| fail("plan+packed", e))?;
    check_arm("plan+packed", &logits, want)?;

    // Arms: the sharded data path at widths 1, 2 and 4.  Four cards are
    // built once; width w uses the first w (the shard partition, not the
    // card, changes per width).  The cards run the process default
    // kernel, so the CI kernel matrix re-races these arms per kernel.
    let mut cards: Vec<BinArraySystem> = Vec::with_capacity(4);
    for _ in 0..4 {
        cards.push(
            BinArraySystem::with_host_threads(case.cfg, case.net.clone(), 1)
                .map_err(|e| fail("shard", e))?,
        );
    }
    let plan = cards[0].plan.clone();
    for (width, arm) in [(1usize, "shard×1"), (2, "shard×2"), (4, "shard×4")] {
        let shards = ShardPlan::new(&plan, width);
        let (logits, _) =
            BinArraySystem::run_frame_sharded(&mut cards[..width], &shards, &case.image, None)
                .map_err(|e| fail(arm, e))?;
        check_arm(arm, &logits, want)?;
    }

    // Arms: high-throughput mode (m_run = 1) on both kernels.
    if case.m > 1 {
        scalar.set_mode(Some(1));
        let (logits, _) = scalar.run_frame(&case.image).map_err(|e| fail("plan+scalar/m1", e))?;
        check_arm("plan+scalar/m1", &logits, want_fast)?;
        packed.set_mode(Some(1));
        let (logits, _) = packed.run_frame(&case.image).map_err(|e| fail("plan+packed/m1", e))?;
        check_arm("plan+packed/m1", &logits, want_fast)?;
    }
    Ok(())
}

/// Race every arm of `case` to bit-identity with [`golden::forward`].
pub fn race_case(case: &Case) -> Result<(), Mismatch> {
    let shape = Shape::new(case.hw, case.hw, case.net.layers[0].c);
    let want = golden::forward(&case.net, &case.image, shape, None);
    let want_fast = if case.m > 1 {
        golden::forward(&case.net, &case.image, shape, Some(1))
    } else {
        want.clone()
    };
    race_case_against(case, &want, &want_fast)
}

/// Outcome of racing one seed.
pub enum Outcome {
    /// The seed drew an uncompilable/ambiguous geometry; nothing raced.
    Skip,
    /// Every arm was bit-identical to golden.
    Pass,
    Fail(Mismatch),
}

/// Generate and race one seed under `budget`.
pub fn run_one(seed: u64, budget: &Budget) -> Outcome {
    match gen_case(seed, budget) {
        None => Outcome::Skip,
        Some(case) => match race_case(&case) {
            Ok(()) => Outcome::Pass,
            Err(m) => Outcome::Fail(m),
        },
    }
}

/// Candidate one-step reductions of `b`, hardest-hitting first.
fn reductions(b: &Budget) -> Vec<Budget> {
    let mut out = Vec::new();
    if b.max_m > 1 {
        out.push(Budget { max_m: 1, ..*b });
        out.push(Budget { max_m: b.max_m - 1, ..*b });
    }
    if b.convs > 1 {
        out.push(Budget { convs: 1, ..*b });
        out.push(Budget { convs: b.convs - 1, ..*b });
    }
    if b.max_d > 1 {
        out.push(Budget { max_d: (b.max_d / 2).max(1), ..*b });
        out.push(Budget { max_d: b.max_d - 1, ..*b });
    }
    if b.denses > 1 {
        out.push(Budget { denses: b.denses - 1, ..*b });
    }
    if b.max_kh > 1 {
        out.push(Budget { max_kh: b.max_kh - 1, ..*b });
    }
    if b.max_pool > 1 {
        out.push(Budget { max_pool: b.max_pool - 1, ..*b });
    }
    out
}

/// Shrink a failing `(seed, budget)` to a minimal reproducer: repeatedly
/// try every one-step budget reduction (probing a few derived seeds per
/// reduction, since a smaller budget redraws the network), keeping any
/// that still fails, until no reduction reproduces.  Bounded at ~300
/// races.  Returns the minimal failing pair — always itself a failure.
pub fn shrink(seed: u64, budget: Budget) -> (u64, Budget) {
    let mut cur = (seed, budget);
    let mut races = 0usize;
    loop {
        let mut improved = false;
        'cand: for cand in reductions(&cur.1) {
            // same seed first, then derived probes: any failure under the
            // smaller budget is a strictly better reproducer
            for probe in 0..8u64 {
                let s = if probe == 0 {
                    cur.0
                } else {
                    cur.0 ^ probe.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                };
                races += 1;
                if races > 300 {
                    return cur;
                }
                if let Outcome::Fail(_) = run_one(s, &cand) {
                    cur = (s, cand);
                    improved = true;
                    break 'cand;
                }
            }
        }
        if !improved {
            return cur;
        }
    }
}

/// Parse a `BINARRAY_FUZZ_SEED` replay value: `<seed>` or
/// `<seed>/<budget>` (seed decimal or `0x`-hex, budget as printed by the
/// shrinker, e.g. `0xb1aa4201/c1d4k2p1m1f1`).
fn replay_from_env() -> Option<(u64, Budget)> {
    let raw = std::env::var("BINARRAY_FUZZ_SEED").ok()?;
    let s = raw.trim();
    let (seed_s, budget) = match s.split_once('/') {
        Some((a, b)) => (
            a,
            b.parse::<Budget>()
                .unwrap_or_else(|e| panic!("BINARRAY_FUZZ_SEED={raw:?}: {e}")),
        ),
        None => (s, Budget::default()),
    };
    let seed_s = seed_s.trim();
    let seed = match seed_s.strip_prefix("0x").or_else(|| seed_s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => seed_s.parse::<u64>(),
    }
    .unwrap_or_else(|_| panic!("BINARRAY_FUZZ_SEED={raw:?}: bad seed {seed_s:?}"));
    Some((seed, budget))
}

/// Race `races` random networks (each across every arm) and panic with a
/// shrunk reproducer on the first mismatch.  With `BINARRAY_FUZZ_SEED`
/// set, replays exactly that seed (and optional budget) instead.
pub fn run_corpus(races: usize) {
    if let Some((seed, budget)) = replay_from_env() {
        match run_one(seed, &budget) {
            Outcome::Pass => println!("replay {seed:#x}/{budget}: every arm bit-identical"),
            Outcome::Skip => panic!(
                "replay {seed:#x}/{budget}: seed generates no compilable network \
                 (did the generator change since the seed was printed?)"
            ),
            Outcome::Fail(m) => panic!("replay {seed:#x}/{budget}: {m}"),
        }
        return;
    }
    let budget = Budget::default();
    let mut done = 0usize;
    let mut case = 0u64;
    while done < races {
        assert!(
            case < 8 * races as u64,
            "generator skip rate too high: {done}/{races} races after {case} seeds"
        );
        let seed = prop::case_seed(case);
        case += 1;
        match run_one(seed, &budget) {
            Outcome::Skip => continue,
            Outcome::Pass => done += 1,
            Outcome::Fail(m) => {
                let (s2, b2) = shrink(seed, budget);
                // re-race the minimal case to print *its* arm/detail
                let detail = match run_one(s2, &b2) {
                    Outcome::Fail(m2) => m2.to_string(),
                    _ => m.to_string(), // races exhausted mid-shrink; report the original
                };
                panic!(
                    "differential mismatch at case {case} — minimal reproducer: {detail}\n\
                     replay with: BINARRAY_FUZZ_SEED={s2:#x}/{b2} cargo test --test differential\n\
                     (original failing seed: BINARRAY_FUZZ_SEED={seed:#x}/{budget})"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let b = Budget::default();
        let (a, b1) = {
            let mut rng = Xoshiro256::new(42);
            random_network(&mut rng, 2, &b)
        };
        let (c, b2) = {
            let mut rng = Xoshiro256::new(42);
            random_network(&mut rng, 2, &b)
        };
        assert_eq!(b1, b2);
        assert_eq!(a.layers.len(), c.layers.len());
        for (la, lc) in a.layers.iter().zip(&c.layers) {
            assert_eq!(la.planes, lc.planes);
            assert_eq!(la.alpha_q, lc.alpha_q);
            assert_eq!(la.bias_q, lc.bias_q);
        }
    }

    #[test]
    fn budget_roundtrips_through_display() {
        for b in [
            Budget::default(),
            Budget { convs: 1, max_d: 2, max_kh: 1, max_pool: 1, max_m: 1, denses: 1 },
            Budget { convs: 9, max_d: 31, max_kh: 5, max_pool: 4, max_m: 3, denses: 2 },
        ] {
            let s = b.to_string();
            assert_eq!(s.parse::<Budget>().unwrap(), b, "{s}");
        }
        assert!("c1d2".parse::<Budget>().is_err());
        assert!("c0d1k1p1m1f1".parse::<Budget>().is_err());
        assert!("c1d1k1p1m1f1x".parse::<Budget>().is_err());
    }

    #[test]
    fn budgets_vary_the_topology() {
        // a minimal budget must actually produce minimal networks
        let tiny = Budget { convs: 1, max_d: 2, max_kh: 1, max_pool: 1, max_m: 1, denses: 1 };
        let mut rng = Xoshiro256::new(7);
        let (net, _) = random_network(&mut rng, 1, &tiny);
        for l in &net.layers {
            assert!(l.d <= 4, "dense caps at 2·max_d, conv at max_d");
            assert_eq!(l.m, 1);
            if l.kind == LayerKind::Conv {
                assert_eq!(l.kh, 1);
                assert_eq!(l.pool, 1);
            }
        }
    }

    #[test]
    fn gen_case_skips_are_not_universal() {
        // the corpus runner needs a healthy acceptance rate
        let b = Budget::default();
        let accepted = (0..32u64).filter(|&s| gen_case(prop::case_seed(s), &b).is_some()).count();
        assert!(accepted >= 8, "only {accepted}/32 seeds accepted");
    }
}
