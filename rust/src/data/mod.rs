//! Synthetic traffic-sign workload generator (Rust side).
//!
//! Mirrors the *recipe* of `python/compile/data.py` (43 classes keyed by
//! shape × hue × glyph, randomized pose/brightness/noise) with the crate's
//! own PRNG.  The exact training/calibration images cross the language
//! boundary via `calib.bin`; this generator provides unbounded extra load
//! for the serving examples and benchmarks.

use crate::tensor::{FeatureMap, Shape};
use crate::util::rng::Xoshiro256;

pub const NUM_CLASSES: usize = 43;
pub const IMG: usize = 48;

const SHAPES: usize = 4;
const GLYPHS: usize = 6;

/// Per-class style (shape, hue, glyph) — deterministic, same table as the
/// Python generator.
pub fn class_style(cls: usize) -> (usize, f32, usize) {
    let shape = cls % SHAPES;
    let glyph = (cls / SHAPES) % GLYPHS;
    let hue = ((cls as f64 * 0.618_033_988_7) % 1.0) as f32;
    (shape, hue, glyph)
}

fn hsv_to_rgb(h: f32, s: f32, v: f32) -> [f32; 3] {
    let i = ((h * 6.0) as usize) % 6;
    let f = h * 6.0 - (h * 6.0).floor();
    let (p, q, t) = (v * (1.0 - s), v * (1.0 - f * s), v * (1.0 - (1.0 - f) * s));
    match i {
        0 => [v, t, p],
        1 => [q, v, p],
        2 => [p, v, t],
        3 => [p, q, v],
        4 => [t, p, v],
        _ => [v, p, q],
    }
}

fn shape_mask(shape: usize, yy: f32, xx: f32, r: f32) -> bool {
    match shape {
        0 => yy * yy + xx * xx <= r * r,
        1 => yy <= r * 0.8 && yy >= -r + xx.abs() * 1.7,
        2 => yy.abs() <= r * 0.85 && xx.abs() <= r * 0.85,
        _ => yy.abs() + xx.abs() <= r * 1.1,
    }
}

fn glyph_mask(glyph: usize, yy: f32, xx: f32, r: f32) -> bool {
    let g = r * 0.45;
    match glyph {
        0 => yy.abs() <= g * 0.35 && xx.abs() <= g,
        1 => {
            (yy.abs() <= g * 0.3 && xx.abs() <= g) || (xx.abs() <= g * 0.3 && yy.abs() <= g)
        }
        2 => {
            let dy = (yy - g * 0.5).abs().min((yy + g * 0.5).abs());
            let dx = (xx - g * 0.5).abs().min((xx + g * 0.5).abs());
            dy * dy + dx * dx <= (g * 0.35) * (g * 0.35)
        }
        3 => (yy - xx.abs() * 0.7).abs() <= g * 0.3 && xx.abs() <= g,
        4 => {
            let rr = (yy * yy + xx * xx).sqrt();
            rr >= g * 0.55 && rr <= g
        }
        _ => (yy - xx).abs() <= g * 0.3,
    }
}

/// Render one int8 sample at activation binary point `f_input`.
pub fn make_sample(rng: &mut Xoshiro256, cls: usize, f_input: i32) -> FeatureMap {
    let cy = IMG as f32 / 2.0 + rng.f32_range(-4.0, 4.0);
    let cx = IMG as f32 / 2.0 + rng.f32_range(-4.0, 4.0);
    let r = IMG as f32 * rng.f32_range(0.30, 0.42);
    let bright = rng.f32_range(0.6, 1.0);
    let (shape, hue, glyph) = class_style(cls);
    let bg: [f32; 3] = [
        rng.f32_range(0.05, 0.35),
        rng.f32_range(0.05, 0.35),
        rng.f32_range(0.05, 0.35),
    ];
    let sign_col = hsv_to_rgb(hue, 0.85, bright);
    let glyph_col = hsv_to_rgb((hue + 0.5) % 1.0, 0.2, (bright + 0.3).min(1.0));

    let scale = (1i32 << f_input) as f32;
    let mut fm = FeatureMap::zeros(Shape::new(IMG, IMG, 3));
    for y in 0..IMG {
        for x in 0..IMG {
            let (yy, xx) = (y as f32 - cy, x as f32 - cx);
            let base = if glyph_mask(glyph, yy, xx, r) && shape_mask(shape, yy, xx, r) {
                glyph_col
            } else if shape_mask(shape, yy, xx, r) {
                sign_col
            } else {
                bg
            };
            for c in 0..3 {
                let v = (base[c] + rng.normal() as f32 * 0.04).clamp(0.0, 1.0);
                fm.set(y, x, c, ((v * scale).round() as i32).clamp(-128, 127) as i8);
            }
        }
    }
    fm
}

/// An endless request generator for load testing.
pub struct LoadGen {
    rng: Xoshiro256,
    pub f_input: i32,
    next_cls: usize,
}

impl LoadGen {
    pub fn new(seed: u64, f_input: i32) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            f_input,
            next_cls: 0,
        }
    }

    /// Produce the next (image, label) pair, classes round-robin.
    pub fn next_sample(&mut self) -> (FeatureMap, usize) {
        let cls = self.next_cls;
        self.next_cls = (self.next_cls + 1) % NUM_CLASSES;
        (make_sample(&mut self.rng, cls, self.f_input), cls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shape_and_range() {
        let mut rng = Xoshiro256::new(1);
        let fm = make_sample(&mut rng, 7, 7);
        assert_eq!(fm.shape, Shape::new(48, 48, 3));
        assert!(fm.data.iter().all(|&v| v >= 0)); // inputs in [0,1] at Q0.7
    }

    #[test]
    fn styles_distinct_across_classes() {
        let styles: std::collections::HashSet<_> = (0..NUM_CLASSES)
            .map(|c| {
                let (s, h, g) = class_style(c);
                (s, (h * 1000.0) as i32, g)
            })
            .collect();
        assert_eq!(styles.len(), NUM_CLASSES);
    }

    #[test]
    fn loadgen_round_robins_classes() {
        let mut lg = LoadGen::new(3, 7);
        let labels: Vec<usize> = (0..NUM_CLASSES).map(|_| lg.next_sample().1).collect();
        let mut sorted = labels.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..NUM_CLASSES).collect::<Vec<_>>());
    }

    #[test]
    fn different_classes_render_differently() {
        let mut r1 = Xoshiro256::new(5);
        let mut r2 = Xoshiro256::new(5);
        let a = make_sample(&mut r1, 0, 7);
        let b = make_sample(&mut r2, 21, 7);
        let diff = a
            .data
            .iter()
            .zip(&b.data)
            .filter(|(x, y)| x != y)
            .count();
        assert!(diff > 100, "classes 0 and 21 too similar: {diff} px differ");
    }
}
