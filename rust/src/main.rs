//! BinArray CLI — leader entrypoint.
//!
//! ```text
//! binarray info                         # artifacts + network summary
//! binarray serve  [--config 1,8,2] [--workers N] [--frames N] [--mode fast|accurate]
//!                 [--route batch|shard|auto] [--shard N] [--shard-min-len L] [--deep-queue Q]
//!                 [--deadline-ms D] [--tight-slack-us T] [--lease-slack-us H]
//!                 [--class interactive|standard|bulk] [--slo-ms S] [--arbitration slo|oldest]
//!                 [--listen ADDR] [--listen-secs N]   # TCP wire front-end instead of calib replay
//!                 [--models N]                        # wire mode: serve N registry models (slot 0 + synthetic)
//! binarray perf   [--m M]               # Table III analytical model
//! binarray area                         # Table IV resource model
//! binarray listing                      # compiled CNN processing program
//! binarray verify                       # golden model vs golden.bin + simulator
//! binarray analyze [--widths N]         # static verifier report, all paper configs
//! ```
//!
//! Argument parsing is hand-rolled (the build is fully offline; no clap).

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use binarray::artifacts::{CalibBatch, GoldenLogits, QuantNetwork};
use binarray::binarray::{ArrayConfig, BinArraySystem, PAPER_CONFIGS};
use binarray::coordinator::{
    Arbitration, BatchPolicy, ClassSpec, ClassTable, Coordinator, CoordinatorConfig, InferRequest,
    Mode, ModelRegistry, RoutePolicy, ServiceClass, WireServer,
};
use binarray::tensor::Shape;
use binarray::{area, golden, isa, nn, perf};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> Result<Self> {
        let mut flags = std::collections::HashMap::new();
        let mut it = rest.iter();
        while let Some(k) = it.next() {
            let Some(key) = k.strip_prefix("--") else {
                bail!("unexpected argument '{k}' (expected --flag value)");
            };
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), v.clone());
        }
        Ok(Self { flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: {v}")),
        }
    }

    fn config(&self, default: ArrayConfig) -> Result<ArrayConfig> {
        match self.flags.get("config") {
            None => Ok(default),
            Some(s) => parse_config(s),
        }
    }
}

fn parse_config(s: &str) -> Result<ArrayConfig> {
    let parts: Vec<usize> = s
        .trim_matches(|c| c == '[' || c == ']')
        .split(',')
        .map(|p| p.trim().parse())
        .collect::<Result<_, _>>()
        .with_context(|| format!("config '{s}' must be N_SA,D_arch,M_arch"))?;
    if parts.len() != 3 {
        bail!("config '{s}' must have three fields");
    }
    Ok(ArrayConfig::new(parts[0], parts[1], parts[2]))
}

fn load_net() -> Result<QuantNetwork> {
    let dir = binarray::artifacts::default_dir();
    QuantNetwork::load(&dir.join("cnn_a.weights.bin")).with_context(|| {
        format!(
            "loading artifacts from {} — run `make artifacts` first",
            dir.display()
        )
    })
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(argv.get(1..).unwrap_or(&[]))?;

    match cmd {
        "info" => info(),
        "serve" => serve(&args),
        "perf" => perf_cmd(&args),
        "area" => area_cmd(),
        "listing" => listing(),
        "verify" => verify(),
        "analyze" => analyze(&args),
        "asm" => asm(&args),
        "disasm" => disasm(&args),
        _ => {
            println!(
                "usage: binarray <info|serve|perf|area|listing|verify|analyze|asm|disasm> [--flags]\n\
                 see `rust/src/main.rs` docs for details"
            );
            Ok(())
        }
    }
}

/// Assemble a CNN-processing-program text file to a machine-code image
/// (one little-endian u32 per instruction — the IMEM format of Fig. 10).
fn asm(args: &Args) -> Result<()> {
    let src: String = args.get("in", String::new())?;
    if src.is_empty() {
        bail!("asm needs --in <file.s> (and optional --out <file.bin>)");
    }
    let text = std::fs::read_to_string(&src)?;
    let mut words = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.split(';').next().unwrap_or("").trim().is_empty() {
            continue;
        }
        let ins = isa::Instr::assemble(line)
            .map_err(|e| anyhow::anyhow!("{src}:{}: {e}", ln + 1))?;
        words.push(ins.encode());
    }
    let out: String = args.get("out", format!("{src}.bin"))?;
    let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
    std::fs::write(&out, bytes)?;
    println!("assembled {} instructions → {out}", words.len());
    Ok(())
}

/// Disassemble a machine-code image back to text.
fn disasm(args: &Args) -> Result<()> {
    let src: String = args.get("in", String::new())?;
    if src.is_empty() {
        bail!("disasm needs --in <file.bin>");
    }
    let bytes = std::fs::read(&src)?;
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        let w = u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        let ins = isa::Instr::decode(w)
            .map_err(|e| anyhow::anyhow!("word {i} ({w:#010x}): {e}"))?;
        println!("{i:3}: {}", ins.disassemble());
    }
    Ok(())
}

fn info() -> Result<()> {
    let net = load_net()?;
    println!("BinArray reproduction — network: CNN-A ({} layers)", net.layers.len());
    println!("  f_input = Q0.{}", net.f_input);
    for (i, l) in net.layers.iter().enumerate() {
        println!(
            "  layer {i}: {:?} d={} m={} n_c={} shift={} pool={} relu={}",
            l.kind,
            l.d,
            l.m,
            l.n_c(),
            l.shift,
            l.pool,
            l.relu
        );
    }
    let prog = isa::compile_network(&net);
    println!(
        "  program: {} instructions, fbuf {} words, weights {} plane-bits",
        prog.instrs.len(),
        prog.fbuf_words,
        prog.wgt_words
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    // --route picks the dispatch policy: `batch` (whole-frame batching,
    // throughput), `shard` (scatter every frame's row tiles over leased
    // cards, latency) or `auto` (route per request from frame size,
    // queue depth and deadline slack).  --shard N caps a frame's lease
    // at N cards and, when --route is not given, implies `shard`.
    // --deadline-ms D stamps every submitted frame with a deadline D ms
    // out (0 = best effort); --tight-slack-us is `auto`'s urgency
    // threshold; --lease-slack-us bounds the lease-width hysteresis.
    let cards: usize = args.get("shard", 0)?;
    let route_default = if cards > 0 { "shard" } else { "batch" };
    let route_name: String = args.get("route", route_default.to_string())?;
    let route = match route_name.as_str() {
        "batch" => RoutePolicy::BatchOnly,
        "shard" => RoutePolicy::ShardOnly,
        "auto" => RoutePolicy::Adaptive {
            shard_min_len: args.get("shard-min-len", 4096)?,
            deep_queue: args.get("deep-queue", 8)?,
            tight_slack: Duration::from_micros(args.get("tight-slack-us", 1000u64)?),
        },
        other => bail!("--route {other}: expected batch|shard|auto"),
    };
    let deadline_ms: u64 = args.get("deadline-ms", 0)?;
    // --class names the service class every frame is submitted under:
    // its SLO (overridable via --slo-ms) becomes the deadline, its
    // admission budget and the capacity model may *refuse* infeasible
    // work up front, and --arbitration picks how freed cards arbitrate
    // between lanes (SLO-aware by default; `oldest` is the blind
    // pre-SLO rule, kept for comparison).
    let service: ServiceClass = args.get("class", ServiceClass::Standard)?;
    let slo_ms: u64 = args.get("slo-ms", 0)?;
    let mut classes = ClassTable::default();
    if slo_ms > 0 {
        let spec = ClassSpec {
            slo: Some(Duration::from_millis(slo_ms)),
            ..*classes.spec(service)
        };
        classes = classes.with(service, spec);
    }
    let arbitration = match args.get::<String>("arbitration", "slo".into())?.as_str() {
        "slo" => Arbitration::SloAware,
        "oldest" => Arbitration::OldestFirst,
        other => bail!("--arbitration {other}: expected slo|oldest"),
    };
    let cfg = CoordinatorConfig {
        array: args.config(ArrayConfig::new(1, 8, 2))?,
        // the pool must cover the requested lease width
        workers: args.get("workers", 2)?.max(cards),
        policy: BatchPolicy {
            max_batch: args.get("batch", 8)?,
            max_delay: Duration::from_millis(args.get("delay-ms", 2)?),
        },
        route,
        max_shard_cards: cards,
        lease_slack: Duration::from_micros(args.get("lease-slack-us", 0u64)?),
        classes,
        arbitration,
    };
    // --listen flips serve into the TCP wire front-end: instead of
    // replaying the calibration batch in-process, the coordinator sits
    // behind `coordinator::wire` and real clients (`loadgen`, the wire
    // test suites) stream frames over the socket.
    let listen: String = args.get("listen", String::new())?;
    if !listen.is_empty() {
        return serve_wire(args, cfg, &listen);
    }
    let net = load_net()?;
    let frames: usize = args.get("frames", 64)?;
    let mode = match args.get::<String>("mode", "accurate".into())?.as_str() {
        "fast" => Mode::HighThroughput,
        _ => Mode::HighAccuracy,
    };
    let dir = binarray::artifacts::default_dir();
    let calib = CalibBatch::load(&dir.join("calib.bin"))?;

    println!(
        "serving {frames} frames on BinArray{} × {} workers, mode {mode:?}, route {route_name}{}{}, class {}{}",
        cfg.array.label(),
        cfg.workers,
        if cards > 0 {
            format!(" (≤{cards}-card leases)")
        } else {
            String::new()
        },
        if deadline_ms > 0 {
            format!(", {deadline_ms} ms deadlines")
        } else {
            String::new()
        },
        service.label(),
        match cfg.classes.spec(service).slo {
            Some(s) => format!(" (SLO {s:?})"),
            None => String::new(),
        }
    );
    let coord = Coordinator::start(cfg, net)?;
    let mut rxs = Vec::new();
    let mut labels = Vec::new();
    for i in 0..frames {
        let idx = i % calib.n;
        let deadline =
            (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms));
        rxs.push(coord.submit(
            InferRequest::new(calib.image(idx).to_vec())
                .mode(mode)
                .deadline(deadline)
                .service(service),
        ));
        labels.push(calib.labels[idx]);
    }
    let mut correct = 0u64;
    let mut answered = 0u64;
    let mut shed = 0u64;
    let mut refused = 0u64;
    for (rx, label) in rxs.into_iter().zip(labels) {
        match rx.recv()? {
            Ok(reply) => {
                answered += 1;
                if reply.class as i32 == label {
                    correct += 1;
                }
            }
            // expired frames are shed by design under --deadline-ms /
            // --slo-ms, and admission may refuse provably-infeasible
            // work up front; anything else is a real serving fault
            Err(e) if e.is_deadline() => shed += 1,
            Err(e) if e.is_refused() => refused += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let m = coord.shutdown();
    println!("{}", m.summary());
    if shed > 0 {
        println!("shed {shed} frames past their deadline/SLO (answered {answered})");
    }
    if refused > 0 {
        println!(
            "refused {refused} frames at admission (SLO provably unmeetable or class budget full)"
        );
    }
    println!(
        "top-1 vs labels: {:.2}% ({}/{} answered frames)",
        if answered > 0 {
            100.0 * correct as f64 / answered as f64
        } else {
            0.0
        },
        correct,
        answered
    );
    Ok(())
}

/// `serve --listen ADDR`: run the coordinator behind the TCP wire
/// front-end for `--listen-secs` seconds (default 30), then drain the
/// wire server, shut the coordinator down and print the merged summary
/// (wire counters included).
fn serve_wire(args: &Args, cfg: CoordinatorConfig, listen: &str) -> Result<()> {
    // Built artifacts when present, the synthetic CNN-A stand-in
    // otherwise — the loopback smoke path must run on a bare checkout.
    let net = binarray::artifacts::cnn_a_or_synthetic(2);
    let dims = binarray::isa::compiler::infer_input_dims(&net);
    let shape = Shape::new(dims.1, dims.0, dims.2);
    let secs: u64 = args.get("listen-secs", 30)?;
    // --models N serves N models from one registry: slot 0 is CNN-A
    // under the --config array (what v1 frames keep hitting), slots
    // 1..N are synthetic stand-ins on a [1,32,2] array for v2 clients
    // (`loadgen --models`) to split traffic across.
    let n_models: usize = args.get("models", 1)?;
    let registry = std::sync::Arc::new(ModelRegistry::new(cfg.workers.max(1)));
    registry.register("cnn-a", cfg.array, net, 0)?;
    for i in 1..n_models {
        let mut rng = binarray::util::rng::Xoshiro256::new(0xB14B + i as u64);
        let extra = binarray::artifacts::synthetic_cnn_a(&mut rng, 4);
        registry.register(&format!("synth-{i}"), ArrayConfig::new(1, 32, 2), extra, 0)?;
    }
    let coord = Coordinator::with_registry(cfg, std::sync::Arc::clone(&registry))?;
    let wire = WireServer::start(listen, coord.handle(), std::sync::Arc::clone(&coord.metrics))?;
    println!(
        "wire: listening on {} — frames are {}x{}x{} ({} bytes), draining after {secs}s",
        wire.local_addr(),
        shape.h,
        shape.w,
        shape.c,
        shape.len(),
    );
    for (id, name) in registry.names() {
        println!("wire: model {} = {name}", id.0);
    }
    std::thread::sleep(Duration::from_secs(secs));
    // Drain order matters: the wire server first (answer in-flight
    // requests while workers are still alive), the coordinator second.
    wire.shutdown();
    let m = coord.shutdown();
    println!("{}", m.summary());
    Ok(())
}

fn perf_cmd(args: &Args) -> Result<()> {
    let m_cnn_a: usize = args.get("m", 2)?;
    println!("Table III (analytical model, 400 MHz) — fps");
    println!("{:<8} {:>3} {:>10} {:>10} {:>10} {:>10} {:>8}", "CNN", "M", "[1,8,2]", "[1,32,2]", "[4,32,4]", "[16,32,4]", "CPU");
    let nets: [(&str, nn::Network, usize, bool); 5] = [
        ("-A", nn::cnn_a(), m_cnn_a, false),
        ("-B1", nn::cnn_b1(), 4, true),
        ("-B2", nn::cnn_b2(), 4, true),
        ("-B1", nn::cnn_b1(), 6, true),
        ("-B2", nn::cnn_b2(), 6, true),
    ];
    for (name, net, m, offload) in nets {
        print!("{name:<8} {m:>3}");
        for cfg in PAPER_CONFIGS {
            print!(" {:>10.1}", perf::fps(&net, cfg, m, offload));
        }
        println!(" {:>8.1}", perf::cpu_fps(&net));
    }
    Ok(())
}

fn area_cmd() -> Result<()> {
    println!("Table IV (resource model, XC7Z045) — % utilization");
    println!(
        "{:<12} {:>9} {:>9} {:>9} {:>9}",
        "", "[1,8,2]", "[1,32,2]", "[4,32,4]", "[16,32,4]"
    );
    let rows: [(&str, Box<dyn Fn(ArrayConfig) -> f64>); 5] = [
        ("LUT", Box::new(|c| area::logic(c).utilization().lut)),
        ("FF", Box::new(|c| area::logic(c).utilization().ff)),
        (
            "BRAM CNN-A",
            Box::new(|c| {
                area::resources(c, &nn::cnn_a(), 2).utilization().bram
            }),
        ),
        (
            "BRAM CNN-B",
            Box::new(|c| {
                area::resources(c, &nn::cnn_b2(), 4).utilization().bram
            }),
        ),
        ("DSP", Box::new(|c| area::logic(c).utilization().dsp)),
    ];
    for (name, f) in rows {
        print!("{name:<12}");
        for cfg in PAPER_CONFIGS {
            print!(" {:>9.2}", f(cfg));
        }
        println!();
    }
    Ok(())
}

fn listing() -> Result<()> {
    let net = load_net()?;
    println!("{}", isa::compile_network(&net).listing());
    Ok(())
}

/// `binarray analyze`: run the static verifier over every paper config
/// and print the per-layer range/cycle report.  CNN-A is loaded from
/// built artifacts when present, the synthetic stand-in otherwise, each
/// with the config's native M.  `verify_model` internally covers every
/// accuracy mode (0..=max_m) and every shard width up to `--widths`
/// (default 4, i.e. widths 1/2/3/4 — a superset of the CI 1/2/4
/// matrix).  Exits nonzero on the first unproved plan, so CI can gate
/// on it directly.
fn analyze(args: &Args) -> Result<()> {
    let max_cards: usize = args.get("widths", 4)?;
    println!(
        "static analyzer — MULW({}-bit) range proof + schedule/ISA/cycle lint",
        binarray::fixp::MULW
    );
    for cfg in PAPER_CONFIGS {
        let net = binarray::artifacts::cnn_a_or_synthetic(cfg.m_arch);
        let prog = isa::compile_network(&net);
        let plan = binarray::binarray::plan::ExecutionPlan::new(cfg, &net, &prog);
        let report = binarray::analysis::verify_model(&net, &prog, &plan, max_cards)
            .map_err(|e| anyhow::anyhow!("config {}: UNPROVED — {e}", cfg.label()))?;
        println!(
            "\nconfig {} — CNN-A (M = {}), modes 0..={}:",
            cfg.label(),
            cfg.m_arch,
            plan.max_m
        );
        print!("{report}");
    }
    println!("\nall paper configs proved");
    Ok(())
}

fn verify() -> Result<()> {
    let dir = binarray::artifacts::default_dir();
    let net = load_net()?;
    let calib = CalibBatch::load(&dir.join("calib.bin"))?;
    let golden_ref = GoldenLogits::load(&dir.join("golden.bin"))?;
    let shape = Shape::new(calib.h, calib.w, calib.c);

    // 1. Rust golden model vs numpy oracle logits: must be bit-exact.
    let mut exact = 0;
    for i in 0..golden_ref.n {
        let logits = golden::forward(&net, calib.image(i), shape, None);
        if logits.as_slice() == golden_ref.row(i) {
            exact += 1;
        }
    }
    println!(
        "golden model vs numpy oracle: {exact}/{} bit-exact",
        golden_ref.n
    );
    if exact != golden_ref.n {
        bail!("golden model mismatch");
    }

    // 2. Cycle-accurate simulator vs golden model on a few frames.
    let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net.clone())?;
    for i in 0..8.min(calib.n) {
        let (logits, _) = sys.run_frame(calib.image(i))?;
        let want = golden::forward(&net, calib.image(i), shape, None);
        if logits != want {
            bail!("simulator mismatch on frame {i}");
        }
    }
    println!("simulator vs golden model: 8/8 bit-exact");
    Ok(())
}
