//! Readers for the Python-side AOT outputs (see `python/compile/aot.py`).
//!
//! The compile path exports three little-endian flat binaries consumed by
//! the request-path layer:
//!
//! * `cnn_a.weights.bin` — **BAW1**: per-layer sign planes, quantized α
//!   scaling factors and biases of the binary-approximated network;
//! * `calib.bin` — **BAC1**: the int8 calibration batch (NHWC images at
//!   the input binary point) plus int32 labels;
//! * `golden.bin` — **BAG1**: int8 logits of the numpy oracle on the
//!   calibration batch (the cross-check target for [`crate::golden`]).
//!
//! Layouts are defined by `aot.py`'s `write_weights` / `write_calib` /
//! `write_golden` and mirrored exactly here (magic word, header, payload).
//!
//! When the artifacts have not been built (the Python toolchain is not on
//! the request path), [`synthetic_cnn_a`] provides a CNN-A-shaped network
//! with random planes so benches and integration tests can still exercise
//! the full simulator stack.

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::{read_i32, read_i32_vec, read_i8_vec, read_u32};

/// Magic word of the BAW1 weight format (`"BAW1"` little-endian).
pub const MAGIC_WEIGHTS: u32 = 0x3157_4142;
/// Magic word of the BAC1 calibration format.
pub const MAGIC_CALIB: u32 = 0x3143_4142;
/// Magic word of the BAG1 golden-logits format.
pub const MAGIC_GOLDEN: u32 = 0x3147_4142;

/// Directory the AOT artifacts are written to (`make artifacts`).
///
/// Resolution order: `$BINARRAY_ARTIFACTS`, else `<repo>/artifacts`
/// next to this package (the Python side's `--out ../artifacts` default).
pub fn default_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("BINARRAY_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("artifacts"))
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Kind of an accelerated layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Dense,
}

/// One quantized, binary-approximated layer.
///
/// `planes` stores the ±1 sign tensors in `(d, m, n_c)` order — for conv
/// layers `n_c = kh·kw·c` in the AGU's `(ky, kx, c)` walk order, for dense
/// layers `n_c` is the flat input length (stored in `kh`, with
/// `kw = c = 0`, matching the BAW1 dim packing).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantLayer {
    pub kind: LayerKind,
    /// Sign planes, ±1 each, `d * m * n_c` entries.
    pub planes: Vec<i8>,
    /// Quantized α scaling factors, `d * m` entries (fixed point `f_alpha`).
    pub alpha_q: Vec<i8>,
    /// Quantized biases, `d` entries (accumulator scale).
    pub bias_q: Vec<i32>,
    /// Output channels / neurons.
    pub d: usize,
    /// Binary approximation levels.
    pub m: usize,
    /// Kernel height (conv) or flat input length (dense).
    pub kh: usize,
    /// Kernel width (conv; 0 for dense).
    pub kw: usize,
    /// Input channels (conv; 0 for dense).
    pub c: usize,
    /// Fractional bits of the α fixed-point format.
    pub f_alpha: i32,
    /// Binary point of the input activations.
    pub f_in: i32,
    /// Binary point of the output activations.
    pub f_out: i32,
    /// QS right-shift aligning accumulator to output binary point.
    pub shift: u32,
    pub relu: bool,
    /// N_p downsampling factor (1 = AMU bypassed).
    pub pool: usize,
    pub stride: usize,
}

impl QuantLayer {
    /// Dot-product length of one output: `kh·kw·c` (conv) or the flat
    /// input length (dense).
    #[inline]
    pub fn n_c(&self) -> usize {
        match self.kind {
            LayerKind::Conv => self.kh * self.kw * self.c,
            LayerKind::Dense => self.kh,
        }
    }

    /// α scaling factor of output channel `d`, binary level `m`.
    #[inline]
    pub fn alpha(&self, d: usize, m: usize) -> i8 {
        self.alpha_q[d * self.m + m]
    }

    /// Sign-plane element `i` of output channel `d`, binary level `m`.
    #[inline]
    pub fn plane(&self, d: usize, m: usize, i: usize) -> i8 {
        self.planes[(d * self.m + m) * self.n_c() + i]
    }

    fn validate(&self, idx: usize) -> Result<()> {
        let n_c = self.n_c();
        if self.planes.len() != self.d * self.m * n_c {
            bail!(
                "layer {idx}: {} plane entries, want d*m*n_c = {}",
                self.planes.len(),
                self.d * self.m * n_c
            );
        }
        if self.alpha_q.len() != self.d * self.m {
            bail!("layer {idx}: {} alpha entries, want {}", self.alpha_q.len(), self.d * self.m);
        }
        if self.bias_q.len() != self.d {
            bail!("layer {idx}: {} bias entries, want {}", self.bias_q.len(), self.d);
        }
        Ok(())
    }
}

/// A layer's sign planes packed one bit per ±1 weight — the weight side
/// of the bit-packed popcount kernel ([`crate::kernel`]).
///
/// Layout is bitplane-major: plane `(d, m)` occupies `stride` consecutive
/// `u64` words (`stride = plane_stride(n_c)`, padded up to the kernel's
/// SIMD lane multiple), with bit `i` set iff sign element `i` is `+1`.
/// All padding bits — the tail past `n_c` in the last logical word and
/// the alignment words after it — are guaranteed zero (`tail_mask` is
/// applied at pack time), which is what lets the kernel's popcount
/// identity run with no edge handling on the dot path.  The scalar
/// `planes: Vec<i8>` on [`QuantLayer`] stays untouched as the golden
/// reference; this view is built once per layer at plan construction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedPlanes {
    d: usize,
    m: usize,
    n_c: usize,
    stride: usize,
    tail_mask: u64,
    bits: Vec<u64>,
}

impl PackedPlanes {
    /// Pack `layer.planes` (±1 signs in `(d, m, n_c)` order) into the
    /// bitplane-major `u64` layout.
    pub fn pack(layer: &QuantLayer) -> Self {
        let n_c = layer.n_c();
        let stride = crate::kernel::plane_stride(n_c);
        let words = n_c.div_ceil(64);
        let tail_mask = match n_c % 64 {
            0 => u64::MAX,
            r => (1u64 << r) - 1,
        };
        let mut bits = vec![0u64; layer.d * layer.m * stride];
        for p in 0..layer.d * layer.m {
            let plane = &layer.planes[p * n_c..(p + 1) * n_c];
            let dst = &mut bits[p * stride..p * stride + words];
            for (i, &s) in plane.iter().enumerate() {
                if s > 0 {
                    dst[i / 64] |= 1u64 << (i % 64);
                }
            }
            // `s > 0` can never set a bit past n_c, but the mask makes
            // the zero-padding contract explicit and machine-checked.
            if let Some(last) = dst.last_mut() {
                *last &= tail_mask;
            }
        }
        Self { d: layer.d, m: layer.m, n_c, stride, tail_mask, bits }
    }

    /// Packed words of plane `(d, m)` — exactly [`Self::stride`] words.
    #[inline]
    pub fn plane(&self, d: usize, m: usize) -> &[u64] {
        let at = (d * self.m + m) * self.stride;
        &self.bits[at..at + self.stride]
    }

    /// Dot length the planes were packed for.
    pub fn n_c(&self) -> usize {
        self.n_c
    }

    /// Words per plane (`plane_stride(n_c)`).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Valid-bit mask of the last logical word of each plane.
    pub fn tail_mask(&self) -> u64 {
        self.tail_mask
    }

    /// Do these packed planes describe `layer`'s geometry?
    pub fn matches(&self, layer: &QuantLayer) -> bool {
        self.d == layer.d && self.m == layer.m && self.n_c == layer.n_c()
    }
}

/// A full quantized network (the BAW1 payload).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantNetwork {
    /// Binary point of the int8 input images.
    pub f_input: u32,
    pub layers: Vec<QuantLayer>,
}

impl QuantNetwork {
    /// Largest M over all layers — the network's approximation depth.
    pub fn max_m(&self) -> usize {
        self.layers.iter().map(|l| l.m).max().unwrap_or(1)
    }

    /// Read a BAW1 weight file.
    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let magic = read_u32(&mut r)?;
        if magic != MAGIC_WEIGHTS {
            bail!("{}: bad magic {magic:#010x} (want BAW1)", path.display());
        }
        let n_layers = read_u32(&mut r)? as usize;
        let f_input = read_u32(&mut r)?;
        if n_layers == 0 || n_layers > 1024 {
            bail!("{}: implausible layer count {n_layers}", path.display());
        }
        let mut layers = Vec::with_capacity(n_layers);
        for idx in 0..n_layers {
            let layer = Self::read_layer(&mut r)
                .with_context(|| format!("{}: layer {idx}", path.display()))?;
            layer.validate(idx)?;
            layers.push(layer);
        }
        Ok(Self { f_input, layers })
    }

    fn read_layer<R: Read>(r: &mut R) -> Result<QuantLayer> {
        let kind = match read_u32(r)? {
            0 => LayerKind::Conv,
            1 => LayerKind::Dense,
            k => bail!("unknown layer kind {k}"),
        };
        // dims: (d, m, kh, kw, c) for conv; (d, m, nin, 0, 0) for dense.
        let d = read_u32(r)? as usize;
        let m = read_u32(r)? as usize;
        let kh = read_u32(r)? as usize;
        let kw = read_u32(r)? as usize;
        let c = read_u32(r)? as usize;
        let f_alpha = read_i32(r)?;
        let f_in = read_i32(r)?;
        let f_out = read_i32(r)?;
        let shift = read_i32(r)? as u32;
        let relu = read_u32(r)? != 0;
        let pool = read_u32(r)? as usize;
        let stride = read_u32(r)? as usize;
        let n_c = match kind {
            LayerKind::Conv => kh * kw * c,
            LayerKind::Dense => kh,
        };
        let planes = read_i8_vec(r, d * m * n_c)?;
        let alpha_q = read_i8_vec(r, d * m)?;
        let bias_q = read_i32_vec(r, d)?;
        Ok(QuantLayer {
            kind,
            planes,
            alpha_q,
            bias_q,
            d,
            m,
            kh,
            kw,
            c,
            f_alpha,
            f_in,
            f_out,
            shift,
            relu,
            pool,
            stride,
        })
    }
}

/// The int8 calibration batch (BAC1).
#[derive(Clone, Debug)]
pub struct CalibBatch {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Binary point of the images.
    pub f_input: i32,
    images: Vec<i8>,
    pub labels: Vec<i32>,
}

impl CalibBatch {
    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let magic = read_u32(&mut r)?;
        if magic != MAGIC_CALIB {
            bail!("{}: bad magic {magic:#010x} (want BAC1)", path.display());
        }
        let n = read_u32(&mut r)? as usize;
        let h = read_u32(&mut r)? as usize;
        let w = read_u32(&mut r)? as usize;
        let c = read_u32(&mut r)? as usize;
        let f_input = read_u32(&mut r)? as i32;
        let images = read_i8_vec(&mut r, n * h * w * c)?;
        let labels = read_i32_vec(&mut r, n)?;
        Ok(Self {
            n,
            h,
            w,
            c,
            f_input,
            images,
            labels,
        })
    }

    /// Image `i` as a flat row-major HWC slice.
    pub fn image(&self, i: usize) -> &[i8] {
        let len = self.h * self.w * self.c;
        &self.images[i * len..(i + 1) * len]
    }
}

/// The numpy oracle's int8 logits on the calibration batch (BAG1).
#[derive(Clone, Debug)]
pub struct GoldenLogits {
    pub n: usize,
    pub k: usize,
    data: Vec<i8>,
}

impl GoldenLogits {
    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let magic = read_u32(&mut r)?;
        if magic != MAGIC_GOLDEN {
            bail!("{}: bad magic {magic:#010x} (want BAG1)", path.display());
        }
        let n = read_u32(&mut r)? as usize;
        let k = read_u32(&mut r)? as usize;
        let data = read_i8_vec(&mut r, n * k)?;
        Ok(Self { n, k, data })
    }

    /// Logits of frame `i`.
    pub fn row(&self, i: usize) -> &[i8] {
        &self.data[i * self.k..(i + 1) * self.k]
    }
}

/// Build a CNN-A-shaped [`QuantNetwork`] with deterministic random planes.
///
/// This is the synthetic stand-in used by benches and integration tests
/// when the real AOT artifacts have not been built — same topology,
/// quantization geometry and value ranges as the trained network, random
/// weights.  The crate's test-support factory delegates here so all
/// layers of the stack exercise the same shape.
pub fn synthetic_cnn_a(rng: &mut crate::util::rng::Xoshiro256, m: usize) -> QuantNetwork {
    use crate::util::prop;
    type Rng = crate::util::rng::Xoshiro256;
    let conv = |rng: &mut Rng, d: usize, kh: usize, kw: usize, c: usize, pool: usize, shift: u32| {
        QuantLayer {
            kind: LayerKind::Conv,
            planes: prop::sign_vec(rng, d * m * kh * kw * c),
            alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
            bias_q: (0..d).map(|_| rng.range_i64(-300, 300) as i32).collect(),
            d,
            m,
            kh,
            kw,
            c,
            f_alpha: 5,
            f_in: 7,
            f_out: 6,
            shift,
            relu: true,
            pool,
            stride: 1,
        }
    };
    let dense = |rng: &mut Rng, d: usize, nin: usize, relu: bool, shift: u32| QuantLayer {
        kind: LayerKind::Dense,
        planes: prop::sign_vec(rng, d * m * nin),
        alpha_q: (0..d * m).map(|_| rng.range_i64(1, 80) as i8).collect(),
        bias_q: (0..d).map(|_| rng.range_i64(-300, 300) as i32).collect(),
        d,
        m,
        kh: nin,
        kw: 0,
        c: 0,
        f_alpha: 5,
        f_in: 6,
        f_out: 6,
        shift,
        relu,
        pool: 1,
        stride: 1,
    };
    QuantNetwork {
        f_input: 7,
        layers: vec![
            conv(rng, 5, 7, 7, 3, 2, 9),
            conv(rng, 150, 4, 4, 5, 6, 10),
            dense(rng, 340, 1350, true, 11),
            dense(rng, 490, 340, true, 10),
            dense(rng, 43, 490, false, 9),
        ],
    }
}

/// The single CNN-A loading path for servers, benches and examples: the
/// trained AOT artifact from [`default_dir`] when `make artifacts` has
/// been run, else the deterministic [`synthetic_cnn_a`] stand-in with
/// approximation depth `m` (seeded so every caller gets the same
/// network).  Previously `main.rs` and the serving example each carried
/// their own copy of this fallback.
pub fn cnn_a_or_synthetic(m: usize) -> QuantNetwork {
    QuantNetwork::load(&default_dir().join("cnn_a.weights.bin")).unwrap_or_else(|_| {
        synthetic_cnn_a(&mut crate::util::rng::Xoshiro256::new(0xB14A), m)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    /// Serialize a network in the BAW1 layout (test-only writer mirroring
    /// `aot.py::write_weights`).
    fn write_baw1(net: &QuantNetwork) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC_WEIGHTS.to_le_bytes());
        out.extend_from_slice(&(net.layers.len() as u32).to_le_bytes());
        out.extend_from_slice(&net.f_input.to_le_bytes());
        for l in &net.layers {
            let kind = match l.kind {
                LayerKind::Conv => 0u32,
                LayerKind::Dense => 1,
            };
            out.extend_from_slice(&kind.to_le_bytes());
            for v in [l.d, l.m, l.kh, l.kw, l.c] {
                out.extend_from_slice(&(v as u32).to_le_bytes());
            }
            for v in [l.f_alpha, l.f_in, l.f_out, l.shift as i32] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            for v in [u32::from(l.relu), l.pool as u32, l.stride as u32] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend(l.planes.iter().map(|&b| b as u8));
            out.extend(l.alpha_q.iter().map(|&b| b as u8));
            for b in &l.bias_q {
                out.extend_from_slice(&b.to_le_bytes());
            }
        }
        out
    }

    fn tmp(name: &str, bytes: &[u8]) -> PathBuf {
        let p = std::env::temp_dir().join(format!("binarray-test-{}-{name}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn baw1_roundtrip() {
        let mut rng = Xoshiro256::new(7);
        let net = synthetic_cnn_a(&mut rng, 3);
        let path = tmp("w.bin", &write_baw1(&net));
        let back = QuantNetwork::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, net);
        assert_eq!(back.max_m(), 3);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.bin", &[0u8; 16]);
        let err = QuantNetwork::load(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(format!("{err:#}").contains("bad magic"), "{err:#}");
    }

    #[test]
    fn truncated_file_is_an_error_not_a_panic() {
        let mut rng = Xoshiro256::new(8);
        let net = synthetic_cnn_a(&mut rng, 2);
        let mut bytes = write_baw1(&net);
        bytes.truncate(bytes.len() / 2);
        let path = tmp("trunc.bin", &bytes);
        assert!(QuantNetwork::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn calib_roundtrip() {
        let (n, h, w, c) = (3usize, 4usize, 4usize, 2usize);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC_CALIB.to_le_bytes());
        for v in [n, h, w, c, 7] {
            bytes.extend_from_slice(&(v as u32).to_le_bytes());
        }
        let images: Vec<i8> = (0..n * h * w * c).map(|i| (i % 251) as i8).collect();
        bytes.extend(images.iter().map(|&b| b as u8));
        for lbl in [0i32, 5, 42] {
            bytes.extend_from_slice(&lbl.to_le_bytes());
        }
        let path = tmp("c.bin", &bytes);
        let calib = CalibBatch::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!((calib.n, calib.h, calib.w, calib.c), (n, h, w, c));
        assert_eq!(calib.f_input, 7);
        assert_eq!(calib.labels, vec![0, 5, 42]);
        assert_eq!(calib.image(1), &images[h * w * c..2 * h * w * c]);
    }

    #[test]
    fn golden_roundtrip() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC_GOLDEN.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend([1u8, 2, 3, 0xFF, 0xFE, 0x80]);
        let path = tmp("g.bin", &bytes);
        let g = GoldenLogits::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!((g.n, g.k), (2, 3));
        assert_eq!(g.row(0), &[1, 2, 3]);
        assert_eq!(g.row(1), &[-1, -2, -128]);
    }

    #[test]
    fn packed_planes_mirror_scalar_planes_bit_for_bit() {
        let mut rng = Xoshiro256::new(10);
        let net = synthetic_cnn_a(&mut rng, 3);
        for l in &net.layers {
            let pk = PackedPlanes::pack(l);
            assert!(pk.matches(l));
            assert_eq!(pk.n_c(), l.n_c());
            assert_eq!(pk.stride(), crate::kernel::plane_stride(l.n_c()));
            for d in 0..l.d {
                for m in 0..l.m {
                    let plane = pk.plane(d, m);
                    assert_eq!(plane.len(), pk.stride());
                    for i in 0..l.n_c() {
                        let bit = (plane[i / 64] >> (i % 64)) & 1;
                        assert_eq!(bit == 1, l.plane(d, m, i) > 0, "d={d} m={m} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_planes_padding_is_zero() {
        let mut rng = Xoshiro256::new(11);
        let net = synthetic_cnn_a(&mut rng, 2);
        for l in &net.layers {
            let pk = PackedPlanes::pack(l);
            let n_c = l.n_c();
            let words = n_c.div_ceil(64);
            if n_c % 64 != 0 {
                assert_eq!(pk.tail_mask(), (1u64 << (n_c % 64)) - 1);
            } else {
                assert_eq!(pk.tail_mask(), u64::MAX);
            }
            for d in 0..l.d {
                for m in 0..l.m {
                    let plane = pk.plane(d, m);
                    assert_eq!(plane[words - 1] & !pk.tail_mask(), 0);
                    for &w in &plane[words..] {
                        assert_eq!(w, 0);
                    }
                }
            }
        }
    }

    #[test]
    fn packed_planes_reject_foreign_layers() {
        let mut rng = Xoshiro256::new(12);
        let net = synthetic_cnn_a(&mut rng, 2);
        let pk = PackedPlanes::pack(&net.layers[0]);
        assert!(!pk.matches(&net.layers[1]));
    }

    #[test]
    fn layer_accessors_index_correctly() {
        let mut rng = Xoshiro256::new(9);
        let net = synthetic_cnn_a(&mut rng, 2);
        let l = &net.layers[0];
        assert_eq!(l.n_c(), 7 * 7 * 3);
        assert_eq!(l.alpha(0, 0), l.alpha_q[0]);
        assert_eq!(l.alpha(2, 1), l.alpha_q[2 * 2 + 1]);
        assert_eq!(l.plane(1, 0, 5), l.planes[l.m * l.n_c() + 5]);
        let d = &net.layers[2];
        assert_eq!(d.n_c(), 1350);
    }
}
