//! Row-major feature-map tensors and shape algebra.
//!
//! The feature buffers of the paper store activations in row-major
//! `(H, W, C)` order (§IV-A "the buffer is organized in row-major order");
//! the ODG converts the SA's channel-first output stream back to this
//! layout.  This module provides the host-side equivalents used by the
//! golden model, the simulator test benches, and the coordinator.

/// Shape of a feature map: height, width, channels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c }
    }

    pub fn len(&self) -> usize {
        self.h * self.w * self.c
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row-major linear address of `(y, x, ch)` — the FBUF addressing rule.
    #[inline]
    pub fn addr(&self, y: usize, x: usize, ch: usize) -> usize {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        (y * self.w + x) * self.c + ch
    }

    /// Output shape of a valid convolution with `k`×`k` kernel, stride `s`.
    pub fn conv_out(&self, kh: usize, kw: usize, s: usize, d_out: usize) -> Shape {
        Shape::new((self.h - kh) / s + 1, (self.w - kw) / s + 1, d_out)
    }

    /// Output shape after an `Np`×`Np` downsampling pool.
    pub fn pool_out(&self, np: usize) -> Shape {
        Shape::new(self.h / np, self.w / np, self.c)
    }
}

/// An int8 feature map (one image / one layer's activations).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FeatureMap {
    pub shape: Shape,
    pub data: Vec<i8>,
}

impl FeatureMap {
    pub fn zeros(shape: Shape) -> Self {
        Self {
            data: vec![0; shape.len()],
            shape,
        }
    }

    pub fn from_vec(shape: Shape, data: Vec<i8>) -> Self {
        assert_eq!(data.len(), shape.len(), "shape/data mismatch");
        Self { shape, data }
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> i8 {
        self.data[self.shape.addr(y, x, ch)]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: i8) {
        let a = self.shape.addr(y, x, ch);
        self.data[a] = v;
    }

    /// Flatten to the dense-layer input vector (row-major, matching the
    /// python model's `_flatten_features`).
    pub fn flatten(&self) -> &[i8] {
        &self.data
    }

    /// Extract the `kh×kw×C` im2col patch anchored at `(y, x)` in
    /// `(ky, kx, c)` order — the AGU's walk order within a window.
    pub fn patch(&self, y: usize, x: usize, kh: usize, kw: usize, out: &mut Vec<i8>) {
        out.clear();
        for ky in 0..kh {
            for kx in 0..kw {
                let base = self.shape.addr(y + ky, x + kx, 0);
                out.extend_from_slice(&self.data[base..base + self.shape.c]);
            }
        }
    }

    /// Horizontal tile split: divide the width dimension into `n` near-equal
    /// tiles (the scatter/gather block's policy for N_SA > 1), returning
    /// per-tile column ranges that overlap by `halo` columns.
    pub fn tile_columns(&self, n: usize, halo: usize) -> Vec<(usize, usize)> {
        tile_ranges(self.shape.w, n, halo)
    }
}

impl FeatureMap {
    /// Borrow this map as a zero-copy read view.
    pub fn view(&self) -> FeatureMapView<'_> {
        FeatureMapView::new(self.shape, &self.data)
    }
}

/// A borrowed, read-only feature map — the zero-copy input side of the
/// plan/execute split.  Layer executors read the ping half of the feature
/// buffer through this view instead of copying it into a fresh
/// [`FeatureMap`].
#[derive(Clone, Copy, Debug)]
pub struct FeatureMapView<'a> {
    pub shape: Shape,
    pub data: &'a [i8],
}

impl<'a> FeatureMapView<'a> {
    pub fn new(shape: Shape, data: &'a [i8]) -> Self {
        assert_eq!(data.len(), shape.len(), "shape/data mismatch");
        Self { shape, data }
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> i8 {
        self.data[self.shape.addr(y, x, ch)]
    }

    /// Extract the `kh×kw×C` im2col patch anchored at `(y, x)` in
    /// `(ky, kx, c)` order — identical to [`FeatureMap::patch`].
    pub fn patch(&self, y: usize, x: usize, kh: usize, kw: usize, out: &mut Vec<i8>) {
        out.clear();
        for ky in 0..kh {
            for kx in 0..kw {
                let base = self.shape.addr(y + ky, x + kx, 0);
                out.extend_from_slice(&self.data[base..base + self.shape.c]);
            }
        }
    }
}

/// Factory handing out disjoint mutable tiles of one feature map — the
/// zero-copy *output* side of the plan/execute split.
///
/// The executor claims one `(rows × channels)` tile per scheduled work
/// unit; tiles of the same layer may then be written concurrently from
/// the host thread pool.  Soundness: the factory holds the unique `&mut`
/// borrow of the buffer for `'a`, and [`Self::claim_all`] verifies the
/// claimed regions are pairwise disjoint before any raw-pointer tile is
/// handed out (row-major interleaving means tiles are not contiguous
/// slices, so `split_at_mut` alone cannot express this partition).
#[derive(Debug)]
pub struct FeatureMapTiles<'a> {
    shape: Shape,
    ptr: *mut i8,
    len: usize,
    _buf: std::marker::PhantomData<&'a mut [i8]>,
}

impl<'a> FeatureMapTiles<'a> {
    pub fn new(shape: Shape, data: &'a mut [i8]) -> Self {
        assert_eq!(data.len(), shape.len(), "shape/data mismatch");
        Self {
            shape,
            len: data.len(),
            ptr: data.as_mut_ptr(),
            _buf: std::marker::PhantomData,
        }
    }

    /// Claim one mutable tile per `(rows, channels)` region, consuming
    /// the factory (one buffer, one set of claims — no way to hand out a
    /// second, aliasing set).
    ///
    /// Panics if any region exceeds the map bounds or overlaps another —
    /// two regions overlap only when both their row ranges *and* their
    /// channel ranges intersect.
    pub fn claim_all(
        self,
        claims: &[(std::ops::Range<usize>, std::ops::Range<usize>)],
    ) -> Vec<FeatureMapTileMut<'a>> {
        for (rows, chans) in claims {
            assert!(
                rows.end <= self.shape.h && chans.end <= self.shape.c,
                "tile claim ({rows:?}, {chans:?}) exceeds map {:?}",
                self.shape
            );
        }
        for (i, (r1, c1)) in claims.iter().enumerate() {
            for (r2, c2) in &claims[i + 1..] {
                let rows_meet = r1.start < r2.end && r2.start < r1.end;
                let chans_meet = c1.start < c2.end && c2.start < c1.end;
                assert!(
                    !(rows_meet && chans_meet),
                    "overlapping tile claims ({r1:?},{c1:?}) vs ({r2:?},{c2:?})"
                );
            }
        }
        claims
            .iter()
            .map(|(rows, chans)| FeatureMapTileMut {
                shape: self.shape,
                ptr: self.ptr,
                len: self.len,
                rows: rows.clone(),
                chans: chans.clone(),
                _buf: std::marker::PhantomData,
            })
            .collect()
    }
}

/// One claimed `(rows × channels)` output tile.
///
/// Writes land at the ODG's row-major `(y·W + x)·C + ch` addresses of the
/// *full* map; each tile may only touch its claimed region (checked with
/// a debug assertion on the claim and a release-mode bounds check on the
/// underlying buffer).  `Send` is sound because claims are verified
/// disjoint at construction.
#[derive(Debug)]
pub struct FeatureMapTileMut<'a> {
    shape: Shape,
    ptr: *mut i8,
    len: usize,
    rows: std::ops::Range<usize>,
    chans: std::ops::Range<usize>,
    _buf: std::marker::PhantomData<&'a mut [i8]>,
}

// SAFETY: tiles of one `FeatureMapTiles` write pairwise-disjoint regions
// (verified in `claim_all`) of a buffer exclusively borrowed for 'a.
unsafe impl Send for FeatureMapTileMut<'_> {}

impl FeatureMapTileMut<'_> {
    pub fn shape(&self) -> Shape {
        self.shape
    }

    /// Scatter `vals` to `(y, x, ch0..ch0+vals.len())` — the ODG write of
    /// one pooled output vector.
    ///
    /// The claim-containment checks are real (release-mode) asserts: they
    /// are what keeps an out-of-claim write from racing another thread's
    /// tile, and they cost two compares against a `vals.len()` memcpy.
    #[inline]
    pub fn write(&mut self, y: usize, x: usize, ch0: usize, vals: &[i8]) {
        assert!(
            self.rows.contains(&y) && x < self.shape.w,
            "write at ({y},{x}) outside claimed rows {:?}",
            self.rows
        );
        assert!(
            ch0 >= self.chans.start && ch0 + vals.len() <= self.chans.end,
            "write at channels {ch0}..{} outside claim {:?}",
            ch0 + vals.len(),
            self.chans
        );
        let base = self.shape.addr(y, x, ch0);
        assert!(base + vals.len() <= self.len, "tile write out of bounds");
        // SAFETY: in-bounds and inside the claimed region (checked above);
        // claims are pairwise disjoint, so no other tile aliases it.
        unsafe {
            std::ptr::copy_nonoverlapping(vals.as_ptr(), self.ptr.add(base), vals.len());
        }
    }
}

/// Copy the `(rows × W × chans)` region of a row-major map into a dense
/// block — the DMA payload of a cross-card output tile (rows outermost,
/// then columns, then channels, matching the map's own order).
pub fn extract_tile(
    shape: Shape,
    data: &[i8],
    rows: std::ops::Range<usize>,
    chans: std::ops::Range<usize>,
) -> Vec<i8> {
    assert_eq!(data.len(), shape.len(), "shape/data mismatch");
    assert!(
        rows.end <= shape.h && chans.end <= shape.c,
        "tile ({rows:?}, {chans:?}) exceeds map {shape:?}"
    );
    if chans == (0..shape.c) {
        // full-channel tiles are contiguous rows: one memcpy
        let a = (rows.start * shape.w) * shape.c;
        let b = (rows.end * shape.w) * shape.c;
        return data[a..b].to_vec();
    }
    let cw = chans.len();
    let mut out = Vec::with_capacity(rows.len() * shape.w * cw);
    for y in rows {
        for x in 0..shape.w {
            let a = shape.addr(y, x, chans.start);
            out.extend_from_slice(&data[a..a + cw]);
        }
    }
    out
}

/// Inverse of [`extract_tile`]: stitch a dense tile block back into the
/// full map — the gather step of cross-card sharding.
pub fn scatter_tile(
    shape: Shape,
    data: &mut [i8],
    rows: std::ops::Range<usize>,
    chans: std::ops::Range<usize>,
    tile: &[i8],
) {
    assert_eq!(data.len(), shape.len(), "shape/data mismatch");
    assert!(
        rows.end <= shape.h && chans.end <= shape.c,
        "tile ({rows:?}, {chans:?}) exceeds map {shape:?}"
    );
    assert_eq!(tile.len(), rows.len() * shape.w * chans.len(), "tile size");
    if chans == (0..shape.c) {
        let a = (rows.start * shape.w) * shape.c;
        data[a..a + tile.len()].copy_from_slice(tile);
        return;
    }
    let cw = chans.len();
    let mut src = 0usize;
    for y in rows {
        for x in 0..shape.w {
            let a = shape.addr(y, x, chans.start);
            data[a..a + cw].copy_from_slice(&tile[src..src + cw]);
            src += cw;
        }
    }
}

/// Split `len` into `n` near-equal ranges with `halo` overlap on each seam.
pub fn tile_ranges(len: usize, n: usize, halo: usize) -> Vec<(usize, usize)> {
    assert!(n >= 1 && n <= len, "cannot split {len} into {n} tiles");
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for i in 0..n {
        let w = base + usize::from(i < rem);
        let lo = start.saturating_sub(halo);
        let hi = (start + w + halo).min(len);
        out.push((lo, hi));
        start += w;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Xoshiro256};

    #[test]
    fn addr_is_row_major() {
        let s = Shape::new(4, 5, 3);
        assert_eq!(s.addr(0, 0, 0), 0);
        assert_eq!(s.addr(0, 0, 2), 2);
        assert_eq!(s.addr(0, 1, 0), 3);
        assert_eq!(s.addr(1, 0, 0), 15);
        assert_eq!(s.addr(3, 4, 2), 4 * 5 * 3 - 1);
    }

    #[test]
    fn conv_pool_shapes_cnn_a() {
        // CNN-A walk: 48 → conv7 → 42 → pool2 → 21 → conv4 → 18 → pool6 → 3
        let s = Shape::new(48, 48, 3);
        let c1 = s.conv_out(7, 7, 1, 5);
        assert_eq!((c1.h, c1.w, c1.c), (42, 42, 5));
        let p1 = c1.pool_out(2);
        assert_eq!((p1.h, p1.w), (21, 21));
        let c2 = p1.conv_out(4, 4, 1, 150);
        assert_eq!((c2.h, c2.w, c2.c), (18, 18, 150));
        let p2 = c2.pool_out(6);
        assert_eq!(p2.len(), 1350);
    }

    #[test]
    fn patch_order_matches_reference() {
        // 3x3x2 map, 2x2 patch at (1,0): rows (1,0),(1,1),(2,0),(2,1)
        let mut fm = FeatureMap::zeros(Shape::new(3, 3, 2));
        for y in 0..3 {
            for x in 0..3 {
                for c in 0..2 {
                    fm.set(y, x, c, (y * 9 + x * 3 + c) as i8);
                }
            }
        }
        let mut p = Vec::new();
        fm.patch(1, 0, 2, 2, &mut p);
        assert_eq!(p, vec![9, 10, 12, 13, 18, 19, 21, 22]);
    }

    #[test]
    fn patch_covers_whole_kernel() {
        prop::check(100, "patch length = kh*kw*C", |rng| {
            let h = 3 + rng.below(10) as usize;
            let w = 3 + rng.below(10) as usize;
            let c = 1 + rng.below(4) as usize;
            let kh = 1 + rng.below(3.min(h as u64)) as usize;
            let kw = 1 + rng.below(3.min(w as u64)) as usize;
            let fm = FeatureMap::zeros(Shape::new(h, w, c));
            let y = rng.below((h - kh + 1) as u64) as usize;
            let x = rng.below((w - kw + 1) as u64) as usize;
            let mut p = Vec::new();
            fm.patch(y, x, kh, kw, &mut p);
            assert_eq!(p.len(), kh * kw * c);
        });
    }

    #[test]
    fn view_patch_matches_owned_patch() {
        let mut rng = Xoshiro256::new(11);
        let fm = FeatureMap::from_vec(Shape::new(6, 7, 3), prop::i8_vec(&mut rng, 6 * 7 * 3));
        let (mut a, mut b) = (Vec::new(), Vec::new());
        fm.patch(2, 3, 3, 2, &mut a);
        fm.view().patch(2, 3, 3, 2, &mut b);
        assert_eq!(a, b);
        assert_eq!(fm.get(4, 1, 2), fm.view().get(4, 1, 2));
    }

    #[test]
    fn tile_writes_land_at_odg_addresses() {
        let shape = Shape::new(4, 3, 5);
        let mut buf = vec![0i8; shape.len()];
        let mut ts = FeatureMapTiles::new(shape, &mut buf)
            .claim_all(&[(0..2, 0..5), (2..4, 0..2), (2..4, 2..5)]);
        ts[0].write(1, 2, 0, &[1, 2, 3, 4, 5]);
        ts[1].write(3, 0, 0, &[7, 8]);
        ts[2].write(3, 0, 2, &[9]);
        drop(ts);
        assert_eq!(&buf[shape.addr(1, 2, 0)..shape.addr(1, 2, 0) + 5], &[1, 2, 3, 4, 5]);
        assert_eq!(buf[shape.addr(3, 0, 0)], 7);
        assert_eq!(buf[shape.addr(3, 0, 1)], 8);
        assert_eq!(buf[shape.addr(3, 0, 2)], 9);
    }

    #[test]
    #[should_panic(expected = "overlapping tile claims")]
    fn overlapping_claims_rejected() {
        let shape = Shape::new(4, 4, 4);
        let mut buf = vec![0i8; shape.len()];
        let _ = FeatureMapTiles::new(shape, &mut buf).claim_all(&[(0..3, 0..2), (2..4, 1..4)]);
    }

    #[test]
    fn disjoint_row_or_channel_claims_allowed() {
        let shape = Shape::new(4, 4, 4);
        let mut buf = vec![0i8; shape.len()];
        // same rows, disjoint channels; same channels, disjoint rows
        let ts = FeatureMapTiles::new(shape, &mut buf)
            .claim_all(&[(0..4, 0..2), (0..2, 2..4), (2..4, 2..4)]);
        assert_eq!(ts.len(), 3);
    }

    #[test]
    fn extract_scatter_roundtrip() {
        prop::check(100, "scatter(extract(t)) == identity on the region", |rng| {
            let h = 1 + rng.below(6) as usize;
            let w = 1 + rng.below(6) as usize;
            let c = 1 + rng.below(5) as usize;
            let shape = Shape::new(h, w, c);
            let src = prop::i8_vec(rng, shape.len());
            let r0 = rng.below(h as u64) as usize;
            let r1 = r0 + 1 + rng.below((h - r0) as u64) as usize;
            let c0 = rng.below(c as u64) as usize;
            let c1 = c0 + 1 + rng.below((c - c0) as u64) as usize;
            let tile = extract_tile(shape, &src, r0..r1, c0..c1);
            assert_eq!(tile.len(), (r1 - r0) * w * (c1 - c0));
            // scatter into a fresh buffer: region matches src, rest is 0
            let mut dst = vec![0i8; shape.len()];
            scatter_tile(shape, &mut dst, r0..r1, c0..c1, &tile);
            for y in 0..h {
                for x in 0..w {
                    for ch in 0..c {
                        let a = shape.addr(y, x, ch);
                        let inside = (r0..r1).contains(&y) && (c0..c1).contains(&ch);
                        assert_eq!(dst[a], if inside { src[a] } else { 0 });
                    }
                }
            }
        });
    }

    #[test]
    fn full_channel_tile_is_contiguous_fast_path() {
        let mut rng = Xoshiro256::new(7);
        let shape = Shape::new(5, 4, 3);
        let src = prop::i8_vec(&mut rng, shape.len());
        let tile = extract_tile(shape, &src, 1..4, 0..3);
        assert_eq!(tile, src[shape.addr(1, 0, 0)..shape.addr(3, 3, 2) + 1].to_vec());
        let mut dst = vec![0i8; shape.len()];
        scatter_tile(shape, &mut dst, 1..4, 0..3, &tile);
        assert_eq!(&dst[shape.addr(1, 0, 0)..shape.addr(3, 3, 2) + 1], &tile[..]);
    }

    #[test]
    fn tiles_cover_and_order() {
        prop::check(200, "tiles cover [0,len) in order", |rng| {
            let len = 2 + rng.below(100) as usize;
            let n = 1 + rng.below(len.min(8) as u64) as usize;
            let halo = rng.below(3) as usize;
            let tiles = tile_ranges(len, n, halo);
            assert_eq!(tiles.len(), n);
            assert_eq!(tiles[0].0, 0);
            assert_eq!(tiles[n - 1].1, len);
            // Non-halo cores must be contiguous and disjoint.
            let mut covered = vec![false; len];
            let mut rng2 = Xoshiro256::new(0);
            let _ = &mut rng2;
            let base = len / n;
            let rem = len % n;
            let mut start = 0;
            for i in 0..n {
                let w = base + usize::from(i < rem);
                for k in start..start + w {
                    assert!(!covered[k]);
                    covered[k] = true;
                }
                // each core must fall inside its (halo-extended) tile
                assert!(tiles[i].0 <= start && start + w <= tiles[i].1);
                start += w;
            }
            assert!(covered.iter().all(|&b| b));
        });
    }
}
