//! Analytical performance model (paper §IV-E, Eqs. 14–18) and the §V-B4
//! energy model.
//!
//! Assumptions exactly as the paper's three paradigms: one accumulation
//! per PE per clock (α-multiplies overlap), tiling in width/height only,
//! and no pipeline stalls for feature loading.
//!
//! Note on Eq. 18 as printed: the paper's formula
//! `N_cc = W_I·H_I·C_I·W_B·H_I·N_pass / N_T` mixes input and output
//! dimensions (and repeats `H_I` where the kernel height `H_B` is
//! intended).  We implement the dimensionally consistent reading —
//! windows (U·V) × window length (W_B·H_B·C_I) × passes / tiles — and
//! validate it against the cycle-accurate simulator the same way the
//! paper validates against VHDL (bench `model_verification`).

pub mod energy;

use crate::binarray::ArrayConfig;
use crate::nn::{Layer, Network};

/// Throughput model outputs for one layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerPerf {
    /// Clock cycles (Eq. 18, corrected form).
    pub cycles: f64,
    /// Channel passes N_pass (Eq. 17).
    pub n_pass: f64,
    /// Input tiles N_T (Eq. 16).
    pub n_t: f64,
    /// Logical SAs N_LSA (Eq. 15).
    pub n_lsa: f64,
}

/// Eq. 14: output feature dims {U, V, D}.
pub fn output_dims(l: &Layer) -> (usize, usize, usize) {
    l.out_dims()
}

/// Analytical cycles for one layer on `cfg` with `m` binary levels.
///
/// Depth-wise layers get `D_arch = 1` per §V-A3 ("using only a single PE
/// per PA"), eliminating output-channel parallelism.
pub fn layer_cycles(l: &Layer, cfg: ArrayConfig, m: usize) -> LayerPerf {
    let (u, v, d) = l.out_dims();
    let d_arch = if l.is_depthwise() { 1 } else { cfg.d_arch };

    // Eq. 15: N_LSA = N_SA / ceil(M / M_arch)
    let m_groups = (m as f64 / cfg.m_arch as f64).ceil();
    let n_lsa = cfg.n_sa as f64 / m_groups;

    // Eqs. 16+17 unified as work units: a layer needs
    // ⌈D/D_arch⌉ channel passes × ⌈M/M_arch⌉ level groups, spread over
    // N_SA physical arrays.  (The paper's Eq. 17 writes this as
    // ceil(max(1, D/(D_arch·N_LSA))) — the max(1,·) floor loses the
    // level-group passes when D underfills the array; our simulator and
    // the corrected form agree, see bench model_verification.)
    let d_passes = (d as f64 / d_arch as f64).ceil();
    let work_units = d_passes * m_groups;
    let n_pass = (work_units / cfg.n_sa as f64).max(1.0).ceil();

    // Eq. 16: tile the input only when the work units underfill the
    // arrays; tile dims must stay > 1.
    let mut n_t = (cfg.n_sa as f64 / work_units).floor().max(1.0);
    let (w_i, h_i) = match *l {
        Layer::Conv { w_in, h_in, .. } | Layer::DepthwiseConv { w_in, h_in, .. } => {
            (w_in as f64, h_in as f64)
        }
        _ => (1.0, 1.0),
    };
    while n_t > 1.0 && (w_i / n_t <= 1.0 || h_i / n_t <= 1.0) {
        n_t -= 1.0;
    }

    // Eq. 18 (corrected): windows × window length × passes / tiles.  The
    // per-window stream cost is max(N_c, D_arch) — the serialized DSP
    // bound for very short windows (depth-wise layers).
    let windows = (u * v) as f64;
    let n_c = l.n_c().max(d_arch) as f64;
    let cycles = windows * n_c * n_pass / n_t;

    LayerPerf {
        cycles,
        n_pass,
        n_t,
        n_lsa,
    }
}

/// Analytical cycles for a full network at approximation depth `m`.
///
/// `offload_tail`: per §V-B3, MobileNet's global-average-pool and final
/// dense layer run on the CPU; when true those layers cost zero
/// accelerator cycles (the CPU overlaps them with the next frame).
pub fn network_cycles(net: &Network, cfg: ArrayConfig, m: usize, offload_tail: bool) -> f64 {
    let n = net.layers.len();
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            if offload_tail {
                let is_tail = matches!(l, Layer::GlobalAvgPool { .. })
                    || (matches!(l, Layer::Dense { .. }) && i == n - 1);
                if is_tail {
                    return 0.0;
                }
            }
            layer_cycles(l, cfg, m).cycles
        })
        .sum()
}

/// Frames per second at the 400 MHz BinArray clock (Table III).
pub fn fps(net: &Network, cfg: ArrayConfig, m: usize, offload_tail: bool) -> f64 {
    crate::binarray::CLOCK_HZ / network_cycles(net, cfg, m, offload_tail)
}

/// The paper's hypothetical 1-GOPS CPU baseline: all MACs at 1e9 MAC/s,
/// everything else free (§V-B3).
pub fn cpu_fps(net: &Network) -> f64 {
    1.0e9 / net.macs() as f64
}

/// Published comparison points quoted in Table III.
pub mod published {
    /// Google EdgeTPU on MobileNetV1 224 (Table III, [2]).
    pub const EDGE_TPU_CNN_B2_FPS: f64 = 416.7;
    /// Eyeriss v2 on MobileNetV1 128 α=0.5 (Table III, [13]).
    pub const EYERISS_V2_CNN_B1_FPS: f64 = 1282.1;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn;

    #[test]
    fn eq14_output_dims() {
        let l = Layer::Conv {
            w_in: 48,
            h_in: 48,
            c_in: 3,
            kh: 7,
            kw: 7,
            d_out: 5,
            stride: 1,
            pad: 0,
            pool: 2,
        };
        assert_eq!(output_dims(&l), (42, 42, 5));
    }

    #[test]
    fn eq15_to_17_cnn_a_layer2() {
        // CNN-A conv2: D=150 on [1,8,2], M=2 → N_LSA=1, N_pass=19, N_T=1
        let net = nn::cnn_a();
        let p = layer_cycles(&net.layers[1], ArrayConfig::new(1, 8, 2), 2);
        assert_eq!(p.n_lsa, 1.0);
        assert_eq!(p.n_pass, 19.0);
        assert_eq!(p.n_t, 1.0);
        // windows 18·18, N_c = 80
        assert_eq!(p.cycles, (18 * 18 * 80 * 19) as f64);
    }

    #[test]
    fn high_accuracy_mode_halves_lsa() {
        let net = nn::cnn_a();
        let cfg = ArrayConfig::new(1, 8, 2);
        let m2 = layer_cycles(&net.layers[1], cfg, 2);
        let m4 = layer_cycles(&net.layers[1], cfg, 4);
        assert_eq!(m4.n_lsa, 0.5);
        assert_eq!(m4.cycles, 2.0 * m2.cycles);
    }

    #[test]
    fn tiling_only_when_underfilled() {
        // CNN-A conv1: D=5 ≤ D_arch → N_T = N_LSA on multi-SA configs
        let net = nn::cnn_a();
        let p = layer_cycles(&net.layers[0], ArrayConfig::new(4, 32, 2), 2);
        assert_eq!(p.n_pass, 1.0);
        assert_eq!(p.n_t, 4.0);
        let single = layer_cycles(&net.layers[0], ArrayConfig::new(1, 32, 2), 2);
        assert_eq!(single.n_t, 1.0);
        assert!((p.cycles - single.cycles / 4.0).abs() < 1e-9);
    }

    #[test]
    fn depthwise_loses_channel_parallelism() {
        let l = Layer::DepthwiseConv {
            w_in: 64,
            h_in: 64,
            c_in: 32,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        };
        let p8 = layer_cycles(&l, ArrayConfig::new(1, 8, 2), 2);
        let p32 = layer_cycles(&l, ArrayConfig::new(1, 32, 2), 2);
        // D_arch forced to 1 → same N_pass regardless of D_arch
        assert_eq!(p8.n_pass, p32.n_pass);
        assert_eq!(p8.n_pass, 32.0);
    }

    #[test]
    fn cpu_baseline_paper_values() {
        // Table III: CPU ≈ 20.6 fps on CNN-B1 (49 M MACs), 1.8 on CNN-B2
        let b1 = cpu_fps(&nn::cnn_b1());
        let b2 = cpu_fps(&nn::cnn_b2());
        assert!((15.0..27.0).contains(&b1), "CNN-B1 CPU fps {b1}");
        assert!((1.4..2.2).contains(&b2), "CNN-B2 CPU fps {b2}");
    }

    #[test]
    fn fps_ordering_matches_table3() {
        // Across configs, fps must increase monotonically, and the paper's
        // CNN-A observation must hold: [1,32,2] ≈ 2.3× [1,8,2], NOT 4×
        // (layer-1 underfill, §V-B3).
        let net = nn::cnn_a();
        let f8 = fps(&net, ArrayConfig::new(1, 8, 2), 2, false);
        let f32 = fps(&net, ArrayConfig::new(1, 32, 2), 2, false);
        assert!(f32 > f8);
        let ratio = f32 / f8;
        assert!(
            (1.5..3.2).contains(&ratio),
            "D_arch 4x should give ~2x fps, got {ratio}"
        );
    }

    #[test]
    fn mobilenet_fps_scales_with_n_sa() {
        let net = nn::cnn_b1();
        let f1 = fps(&net, ArrayConfig::new(4, 32, 4), 4, true);
        let f4 = fps(&net, ArrayConfig::new(16, 32, 4), 4, true);
        assert!(f4 > 2.0 * f1, "N_SA 4→16 should scale >2x: {f1} vs {f4}");
    }

    #[test]
    fn m6_slower_than_m4() {
        // Table III: M=6 rows are slower than M=4 rows on the same config
        let net = nn::cnn_b2();
        let cfg = ArrayConfig::new(4, 32, 4);
        assert!(fps(&net, cfg, 4, true) > fps(&net, cfg, 6, true));
    }
}
