//! Energy model (paper §V-B4).
//!
//! The paper's argument: a 32-bit off-chip SDRAM read costs ≈100× an
//! internal SRAM read, and a 32-bit multiplication ≈100× an 8-bit
//! addition (both from Sze et al. [14]).  BinArray keeps weights and
//! features in BRAM and replaces almost all multiplications with 8-bit
//! additions, so memory and arithmetic energy are each ~100× lower than
//! the hypothetical CPU; with a 10× safety margin the paper claims ≥10×
//! energy efficiency.  This module implements that accounting.

use crate::nn::{Layer, Network};

/// Relative energy units (normalized to one 8-bit addition = 1).
/// Values follow the Sze et al. ratios the paper cites.
#[derive(Clone, Copy, Debug)]
pub struct EnergyCosts {
    /// 8-bit add (the PE operation).
    pub add8: f64,
    /// 32-bit multiply (CPU MAC's multiplier).
    pub mul32: f64,
    /// Internal SRAM/BRAM 32-bit read.
    pub sram_read: f64,
    /// External SDRAM 32-bit read.
    pub sdram_read: f64,
    /// DSP multiply-add (α scaling, 8×28 bit).
    pub dsp_madd: f64,
}

impl Default for EnergyCosts {
    fn default() -> Self {
        Self {
            add8: 1.0,
            mul32: 100.0,  // ≈100× an 8-bit add (§V-B4)
            sram_read: 1.0,
            sdram_read: 100.0, // ≈100× internal SRAM (§V-B4, [14])
            dsp_madd: 25.0,    // narrow multiply: between add8 and mul32
        }
    }
}

/// Energy estimate (relative units) for one inference.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyEstimate {
    pub arithmetic: f64,
    pub memory: f64,
}

impl EnergyEstimate {
    pub fn total(&self) -> f64 {
        self.arithmetic + self.memory
    }
}

/// BinArray energy: per MAC-equivalent, one 8-bit add (PE) + amortized α
/// DSP multiply-adds; all feature/weight traffic from BRAM.  Weights are
/// 1-bit so M plane-bits replace each 8–32-bit weight read.
pub fn binarray_energy(net: &Network, m: usize, costs: &EnergyCosts) -> EnergyEstimate {
    let mut e = EnergyEstimate::default();
    for l in &net.layers {
        let macs = l.macs() as f64;
        let (u, v, d) = l.out_dims();
        match l {
            Layer::GlobalAvgPool { .. } => {
                e.arithmetic += macs * costs.add8;
                e.memory += macs * costs.sram_read / 4.0;
            }
            _ => {
                // PE accumulations: M sign-adds per original MAC
                e.arithmetic += macs * m as f64 * costs.add8;
                // α cascade: M DSP multiply-adds per output value
                e.arithmetic += (u * v * d) as f64 * m as f64 * costs.dsp_madd;
                // features: each input feature read once per channel-pass
                // group from BRAM (8-bit → 1/4 of a 32-bit read)
                e.memory += macs * m as f64 * (costs.sram_read / 4.0) / 8.0;
                // weight bits: 1-bit reads, 1/32 of a 32-bit read
                e.memory += macs * m as f64 * (costs.sram_read / 32.0);
            }
        }
    }
    e
}

/// Hypothetical CPU energy: every MAC is a 32-bit multiply + 32-bit
/// accumulate, with operands fetched from external SDRAM (§V-B4 "assuming
/// only external data access and 32-bit multiplications").
pub fn cpu_energy(net: &Network, costs: &EnergyCosts) -> EnergyEstimate {
    let macs = net.macs() as f64;
    EnergyEstimate {
        arithmetic: macs * (costs.mul32 + 4.0 * costs.add8),
        memory: macs * 2.0 * costs.sdram_read, // weight + activation per MAC
    }
}

/// The paper's headline ratio: CPU energy / BinArray energy.
pub fn efficiency_ratio(net: &Network, m: usize) -> f64 {
    let costs = EnergyCosts::default();
    cpu_energy(net, &costs).total() / binarray_energy(net, m, &costs).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn;

    #[test]
    fn at_least_10x_claim_cnn_a() {
        // §V-B4: "at least 10× more energy efficient"
        let r = efficiency_ratio(&nn::cnn_a(), 2);
        assert!(r >= 10.0, "CNN-A M=2 ratio {r}");
    }

    #[test]
    fn at_least_10x_claim_mobilenets() {
        for (net, m) in [(nn::cnn_b1(), 4), (nn::cnn_b2(), 4), (nn::cnn_b2(), 6)] {
            let r = efficiency_ratio(&net, m);
            assert!(r >= 10.0, "{} M={m} ratio {r}", net.name);
        }
    }

    #[test]
    fn higher_m_costs_more_energy() {
        let net = nn::cnn_a();
        let c = EnergyCosts::default();
        let e2 = binarray_energy(&net, 2, &c).total();
        let e4 = binarray_energy(&net, 4, &c).total();
        assert!(e4 > e2 * 1.5 && e4 < e2 * 2.5);
    }

    #[test]
    fn memory_dominates_cpu_energy() {
        // the paper's point: external access is the CPU's energy sink
        let e = cpu_energy(&nn::cnn_b2(), &EnergyCosts::default());
        assert!(e.memory > e.arithmetic);
    }
}
