//! Open-loop load generator for the TCP wire front-end.
//!
//! ```text
//! loadgen --addr 127.0.0.1:7733 --dims 10x10x3 [--rps 200] [--secs 5]
//!         [--conns 4] [--mix 0.2,0.6,0.2] [--mode accurate|fast|mix]
//!         [--models 0:0.5,1:0.5] [--deadline-ms 0] [--seed 7]
//!         [--out BENCH_loadgen.json]
//! ```
//!
//! `--models id:weight,…` splits traffic across registry models by
//! weighted draw: model 0 is sent as plain v1 frames (the legacy wire
//! path stays exercised), every other id rides a v2 header.  Outcomes
//! are tallied per model and the accounting identity — submitted ==
//! completed + refused + shed + failed + draining + unknown-model —
//! is asserted per model at exit.
//!
//! **Open-loop** means arrivals follow a Poisson process whose schedule
//! is fixed *before* the run: every request has a scheduled send time
//! drawn from exponential inter-arrivals at `--rps`, and the sender
//! never waits for a response before sending the next frame.  A closed
//! loop (send → wait → send) would let a slow server throttle its own
//! load and hide every queueing delay; sustained-pressure numbers are
//! only honest open-loop.
//!
//! **Coordinated omission** is the twin trap: measuring latency from the
//! *actual* send instant forgives the generator for sending late when
//! the socket back-pressured — exactly the moments the server was
//! slowest.  Every latency here is measured from the request's
//! *scheduled* send time, so a stalled sender surfaces as tail latency
//! instead of silently vanishing from the histogram
//! (`LatencyStats`-backed p50/p99, per service class and global).
//!
//! One writer + one reader thread per connection; requests carry a
//! globally unique id the server echoes, which indexes the prebuilt
//! schedule — the reader never guesses what it is measuring.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use binarray::coordinator::{LatencyStats, Mode, ModelId, ServiceClass, WireClient, WireStatus};
use binarray::util::rng::Xoshiro256;

/// One scheduled request: everything is decided before the run starts.
struct Arrival {
    /// Scheduled send offset from the run start.
    at: Duration,
    /// Global sequence number — the wire id, echoed by the server.
    id: u64,
    mode: Mode,
    service: ServiceClass,
    /// Registry model this request names (0 = v1 frame, default model).
    model: u8,
}

/// Per-model outcome tally (wire v2 traffic splitting).
#[derive(Default, Clone, Copy)]
struct ModelTally {
    completed: u64,
    refused: u64,
    deadline_shed: u64,
    failed: u64,
    draining: u64,
    unknown: u64,
}

impl ModelTally {
    fn answered(&self) -> u64 {
        self.completed + self.refused + self.deadline_shed + self.failed + self.draining
            + self.unknown
    }
}

/// Per-class + global outcome ledger (one per reader thread, merged).
#[derive(Default)]
struct Ledger {
    completed: u64,
    refused: u64,
    deadline_shed: u64,
    failed: u64,
    draining: u64,
    bad_request: u64,
    /// Replies the run never saw (connection died early).
    lost: u64,
    /// v2 frames naming a model the registry does not serve.
    unknown_model: u64,
    latency: LatencyStats,
    class_latency: HashMap<usize, LatencyStats>,
    class_completed: [u64; 3],
    models: HashMap<u8, ModelTally>,
    model_latency: HashMap<u8, LatencyStats>,
}

impl Ledger {
    fn merge(&mut self, o: &Ledger) {
        self.completed += o.completed;
        self.refused += o.refused;
        self.deadline_shed += o.deadline_shed;
        self.failed += o.failed;
        self.draining += o.draining;
        self.bad_request += o.bad_request;
        self.lost += o.lost;
        self.unknown_model += o.unknown_model;
        self.latency.merge(&o.latency);
        for (k, v) in &o.class_latency {
            self.class_latency.entry(*k).or_default().merge(v);
        }
        for (a, b) in self.class_completed.iter_mut().zip(&o.class_completed) {
            *a += b;
        }
        for (m, t) in &o.models {
            let mine = self.models.entry(*m).or_default();
            mine.completed += t.completed;
            mine.refused += t.refused;
            mine.deadline_shed += t.deadline_shed;
            mine.failed += t.failed;
            mine.draining += t.draining;
            mine.unknown += t.unknown;
        }
        for (m, l) in &o.model_latency {
            self.model_latency.entry(*m).or_default().merge(l);
        }
    }
}

struct Flags {
    addr: String,
    dims: (u16, u16, u16),
    rps: f64,
    secs: f64,
    conns: usize,
    mix: [f64; 3],
    models: Vec<(u8, f64)>,
    mode: String,
    deadline_ms: u64,
    seed: u64,
    out: String,
}

fn parse_flags() -> Result<Flags> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut map = HashMap::new();
    let mut it = argv.iter();
    while let Some(k) = it.next() {
        let Some(key) = k.strip_prefix("--") else {
            bail!("unexpected argument '{k}' (expected --flag value)");
        };
        let v = it.next().with_context(|| format!("--{key} needs a value"))?;
        map.insert(key.to_string(), v.clone());
    }
    let get = |key: &str, default: &str| map.get(key).cloned().unwrap_or_else(|| default.into());
    let addr = get("addr", "");
    if addr.is_empty() {
        bail!("loadgen needs --addr HOST:PORT (and --dims HxWxC)");
    }
    let dims_s = get("dims", "");
    let parts: Vec<u16> = dims_s
        .split('x')
        .map(|p| p.parse())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("--dims '{dims_s}' must be HxWxC, e.g. 10x10x3"))?;
    if parts.len() != 3 || parts.iter().any(|&d| d == 0) {
        bail!("--dims '{dims_s}' must be three nonzero fields HxWxC");
    }
    let mix_s = get("mix", "0.2,0.6,0.2");
    let weights: Vec<f64> = mix_s
        .split(',')
        .map(|p| p.trim().parse())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("--mix '{mix_s}' must be interactive,standard,bulk weights"))?;
    if weights.len() != 3 || weights.iter().any(|w| *w < 0.0) || weights.iter().sum::<f64>() <= 0.0
    {
        bail!("--mix '{mix_s}' needs three non-negative weights with a positive sum");
    }
    let models_s = get("models", "0:1");
    let mut models: Vec<(u8, f64)> = Vec::new();
    for part in models_s.split(',') {
        let (id_s, w_s) = part
            .split_once(':')
            .with_context(|| format!("--models '{models_s}' must be id:weight,…"))?;
        let id: u8 = id_s.trim().parse().with_context(|| format!("--models id '{id_s}'"))?;
        let w: f64 = w_s.trim().parse().with_context(|| format!("--models weight '{w_s}'"))?;
        if w < 0.0 {
            bail!("--models '{models_s}' weights must be non-negative");
        }
        models.push((id, w));
    }
    if models.is_empty() || models.iter().map(|(_, w)| w).sum::<f64>() <= 0.0 {
        bail!("--models '{models_s}' needs at least one id with positive total weight");
    }
    Ok(Flags {
        addr,
        dims: (parts[0], parts[1], parts[2]),
        rps: get("rps", "100").parse().context("--rps")?,
        secs: get("secs", "5").parse().context("--secs")?,
        conns: get("conns", "4").parse().context("--conns")?,
        mix: [weights[0], weights[1], weights[2]],
        models,
        mode: get("mode", "accurate"),
        deadline_ms: get("deadline-ms", "0").parse().context("--deadline-ms")?,
        seed: get("seed", "7").parse().context("--seed")?,
        out: get("out", "BENCH_loadgen.json"),
    })
}

/// Draw the full Poisson arrival schedule up front: exponential
/// inter-arrivals at `rps`, class by weighted draw, mode per `--mode`.
fn build_schedule(f: &Flags) -> Vec<Arrival> {
    let mut rng = Xoshiro256::new(f.seed);
    let total: f64 = f.mix.iter().sum();
    let mut t = 0.0f64;
    let mut out = Vec::new();
    loop {
        // inverse-CDF exponential; 1 - f64() keeps the log argument > 0
        t += -(1.0 - rng.f64()).ln() / f.rps.max(1e-9);
        if t >= f.secs {
            break;
        }
        let mut pick = rng.f64() * total;
        let mut service = ServiceClass::Bulk;
        for (i, w) in f.mix.iter().enumerate() {
            if pick < *w {
                service = [ServiceClass::Interactive, ServiceClass::Standard, ServiceClass::Bulk]
                    [i];
                break;
            }
            pick -= w;
        }
        let mode = match f.mode.as_str() {
            "fast" => Mode::HighThroughput,
            "mix" => {
                if rng.below(2) == 0 {
                    Mode::HighAccuracy
                } else {
                    Mode::HighThroughput
                }
            }
            _ => Mode::HighAccuracy,
        };
        let mtotal: f64 = f.models.iter().map(|(_, w)| w).sum();
        let mut mpick = rng.f64() * mtotal;
        let mut model = f.models[f.models.len() - 1].0;
        for (id, w) in &f.models {
            if mpick < *w {
                model = *id;
                break;
            }
            mpick -= w;
        }
        out.push(Arrival {
            at: Duration::from_secs_f64(t),
            id: out.len() as u64,
            mode,
            service,
            model,
        });
    }
    out
}

fn percentile_us(l: &LatencyStats, p: f64) -> u64 {
    l.percentile(p).as_micros().min(u64::MAX as u128) as u64
}

fn main() {
    if let Err(e) = run() {
        eprintln!("loadgen error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let f = parse_flags()?;
    let schedule = Arc::new(build_schedule(&f));
    let submitted = schedule.len() as u64;
    if submitted == 0 {
        bail!("empty schedule — raise --rps or --secs");
    }
    // The reader indexes scheduled offsets, classes and models by the
    // echoed id.
    let by_id: Arc<Vec<(Duration, usize, u8)>> =
        Arc::new(schedule.iter().map(|a| (a.at, a.service.index(), a.model)).collect());
    // Per-model submitted counts, fixed by the schedule — the basis for
    // the per-model accounting identity at exit.
    let mut model_submitted: std::collections::BTreeMap<u8, u64> = Default::default();
    for a in schedule.iter() {
        *model_submitted.entry(a.model).or_default() += 1;
    }
    let image: Vec<i8> = {
        // deterministic pseudo-image; the server only checks geometry
        let mut rng = Xoshiro256::new(f.seed ^ 0x1A6E);
        let len = f.dims.0 as usize * f.dims.1 as usize * f.dims.2 as usize;
        (0..len).map(|_| (rng.below(256) as i64 - 128) as i8).collect()
    };
    println!(
        "loadgen: {} requests over {:.1}s ({:.0} rps Poisson) on {} conns → {} \
         (mix i/s/b {:?}, models {:?}, mode {}, deadline {} ms)",
        submitted, f.secs, f.rps, f.conns, f.addr, f.mix, f.models, f.mode, f.deadline_ms
    );

    let conns = f.conns.max(1);
    let deadline_us = f.deadline_ms * 1_000;
    let start = Instant::now();
    let mut total = Ledger::default();
    let mut send_lag = LatencyStats::default();
    std::thread::scope(|s| -> Result<()> {
        let mut readers = Vec::new();
        let mut writers = Vec::new();
        for conn in 0..conns {
            let mut writer = WireClient::connect(&f.addr)
                .with_context(|| format!("connecting to {}", f.addr))?;
            let mut reader = writer.try_clone()?;
            // round-robin slice of the global schedule, order preserved
            let mine: Vec<usize> =
                (0..schedule.len()).filter(|i| i % conns == conn).collect();
            let expect = mine.len();
            let sched = Arc::clone(&schedule);
            let ids = Arc::clone(&by_id);
            let img = image.clone();
            let dims = f.dims;
            writers.push(s.spawn(move || -> Result<LatencyStats> {
                let mut lag = LatencyStats::default();
                for i in mine {
                    let a = &sched[i];
                    // sleep to the *scheduled* instant; once behind, send
                    // immediately and let the lag show up in the stats —
                    // re-anchoring the schedule would be coordinated
                    // omission at the sender
                    let now = start.elapsed();
                    if a.at > now {
                        std::thread::sleep(a.at - now);
                    }
                    lag.record(start.elapsed().saturating_sub(a.at));
                    // model 0 goes as a plain v1 frame so the legacy
                    // wire path stays under load; the rest ride v2
                    if a.model == 0 {
                        writer.send(a.id, a.mode, a.service, deadline_us, dims, &img)?;
                    } else {
                        writer.send_to(
                            ModelId(a.model as u32),
                            a.id,
                            a.mode,
                            a.service,
                            deadline_us,
                            dims,
                            &img,
                        )?;
                    }
                }
                Ok(lag)
            }));
            readers.push(s.spawn(move || -> Ledger {
                let mut led = Ledger::default();
                for got in 0..expect {
                    let reply = match reader.recv() {
                        Ok(r) => r,
                        Err(_) => {
                            // connection died: everything unanswered is
                            // lost, and that is a run failure
                            led.lost += (expect - got) as u64;
                            break;
                        }
                    };
                    let Some(&(at, ci, model)) = ids.get(reply.id as usize) else {
                        // a reply id we never sent — protocol breakage
                        led.bad_request += 1;
                        continue;
                    };
                    match reply.status {
                        WireStatus::Ok => {
                            led.completed += 1;
                            led.class_completed[ci] += 1;
                            led.models.entry(model).or_default().completed += 1;
                            // send-time-based latency: now vs *scheduled*
                            let lat = start.elapsed().saturating_sub(at);
                            led.latency.record(lat);
                            led.class_latency.entry(ci).or_default().record(lat);
                            led.model_latency.entry(model).or_default().record(lat);
                        }
                        WireStatus::Refused => {
                            led.refused += 1;
                            led.models.entry(model).or_default().refused += 1;
                        }
                        WireStatus::Deadline => {
                            led.deadline_shed += 1;
                            led.models.entry(model).or_default().deadline_shed += 1;
                        }
                        WireStatus::Failed => {
                            led.failed += 1;
                            led.models.entry(model).or_default().failed += 1;
                        }
                        WireStatus::Draining => {
                            led.draining += 1;
                            led.models.entry(model).or_default().draining += 1;
                        }
                        WireStatus::BadRequest => led.bad_request += 1,
                        WireStatus::UnknownModel => {
                            led.unknown_model += 1;
                            led.models.entry(model).or_default().unknown += 1;
                        }
                    }
                }
                led
            }));
        }
        for w in writers {
            match w.join() {
                Ok(Ok(lag)) => send_lag.merge(&lag),
                Ok(Err(e)) => eprintln!("loadgen writer: {e:#}"),
                Err(_) => eprintln!("loadgen writer panicked"),
            }
        }
        for r in readers {
            if let Ok(led) = r.join() {
                total.merge(&led);
            }
        }
        Ok(())
    })?;
    let wall = start.elapsed();

    let answered = total.completed
        + total.refused
        + total.deadline_shed
        + total.failed
        + total.draining
        + total.unknown_model;
    println!(
        "loadgen: submitted {} | completed {} refused {} shed {} failed {} draining {} \
         unknown-model {} lost {} | wall {:.2}s ({:.1} completed/s)",
        submitted,
        total.completed,
        total.refused,
        total.deadline_shed,
        total.failed,
        total.draining,
        total.unknown_model,
        total.lost,
        wall.as_secs_f64(),
        total.completed as f64 / wall.as_secs_f64().max(1e-9),
    );
    println!(
        "latency (from scheduled send): p50 {:?} p99 {:?} mean {:?} | sender lag p99 {:?}",
        total.latency.percentile(50.0),
        total.latency.percentile(99.0),
        total.latency.mean(),
        send_lag.percentile(99.0),
    );
    for (i, name) in ["interactive", "standard", "bulk"].iter().enumerate() {
        if let Some(l) = total.class_latency.get(&i) {
            println!(
                "  {name}: {} completed, p50 {:?} p99 {:?}",
                total.class_completed[i],
                l.percentile(50.0),
                l.percentile(99.0)
            );
        }
    }
    for (id, sub) in &model_submitted {
        let t = total.models.get(id).copied().unwrap_or_default();
        let (p50, p99) = total
            .model_latency
            .get(id)
            .map_or((Duration::ZERO, Duration::ZERO), |l| {
                (l.percentile(50.0), l.percentile(99.0))
            });
        println!(
            "  model {id}: {sub} submitted, {} completed, {} refused, {} shed, {} unknown, \
             p50 {p50:?} p99 {p99:?}",
            t.completed, t.refused, t.deadline_shed, t.unknown
        );
    }

    if !f.out.is_empty() {
        let classes_json: Vec<String> = ["interactive", "standard", "bulk"]
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let l = total.class_latency.get(&i);
                format!(
                    "\"{name}\": {{\"completed\": {}, \"p50_us\": {}, \"p99_us\": {}}}",
                    total.class_completed[i],
                    l.map_or(0, |l| percentile_us(l, 50.0)),
                    l.map_or(0, |l| percentile_us(l, 99.0)),
                )
            })
            .collect();
        let models_json: Vec<String> = model_submitted
            .iter()
            .map(|(id, sub)| {
                let t = total.models.get(id).copied().unwrap_or_default();
                let l = total.model_latency.get(id);
                format!(
                    "\"{id}\": {{\"submitted\": {sub}, \"completed\": {}, \"refused\": {}, \
                     \"deadline_shed\": {}, \"failed\": {}, \"draining\": {}, \
                     \"unknown_model\": {}, \"p50_us\": {}, \"p99_us\": {}}}",
                    t.completed,
                    t.refused,
                    t.deadline_shed,
                    t.failed,
                    t.draining,
                    t.unknown,
                    l.map_or(0, |l| percentile_us(l, 50.0)),
                    l.map_or(0, |l| percentile_us(l, 99.0)),
                )
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"loadgen\",\n  \"addr\": \"{}\",\n  \"rps\": {},\n  \
             \"secs\": {},\n  \"conns\": {},\n  \"submitted\": {},\n  \"completed\": {},\n  \
             \"refused\": {},\n  \"deadline_shed\": {},\n  \"failed\": {},\n  \
             \"draining\": {},\n  \"lost\": {},\n  \"protocol_errors\": {},\n  \
             \"unknown_model\": {},\n  \
             \"completed_per_sec\": {:.3},\n  \"p50_us\": {},\n  \"p99_us\": {},\n  \
             \"mean_us\": {},\n  \"send_lag_p99_us\": {},\n  \"classes\": {{{}}},\n  \
             \"models\": {{{}}}\n}}\n",
            f.addr,
            f.rps,
            f.secs,
            conns,
            submitted,
            total.completed,
            total.refused,
            total.deadline_shed,
            total.failed,
            total.draining,
            total.lost,
            total.bad_request,
            total.unknown_model,
            total.completed as f64 / wall.as_secs_f64().max(1e-9),
            percentile_us(&total.latency, 50.0),
            percentile_us(&total.latency, 99.0),
            total.latency.mean().as_micros().min(u64::MAX as u128) as u64,
            percentile_us(&send_lag, 99.0),
            classes_json.join(", "),
            models_json.join(", "),
        );
        std::fs::write(&f.out, json).with_context(|| format!("writing {}", f.out))?;
        println!("wrote {}", f.out);
    }

    // The accounting identity must hold across the wire boundary:
    // every submitted request is answered exactly once, and nothing is
    // answered with a protocol error or lost to a dead connection.
    if answered != submitted || total.lost > 0 || total.bad_request > 0 || total.failed > 0 {
        bail!(
            "accounting violated: submitted {} != answered {} (lost {}, bad_request {}, failed {})",
            submitted,
            answered,
            total.lost,
            total.bad_request,
            total.failed
        );
    }
    // And the same identity must hold within every model's traffic
    // slice — a reply charged to the wrong model would balance globally
    // but not here.
    for (id, sub) in &model_submitted {
        let t = total.models.get(id).copied().unwrap_or_default();
        if t.answered() != *sub {
            bail!(
                "per-model accounting violated: model {id} submitted {sub} != answered {}",
                t.answered()
            );
        }
    }
    Ok(())
}
