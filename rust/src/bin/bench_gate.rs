//! CI perf gate over the `sim_hotpath` bench trajectory (ROADMAP item).
//!
//! The bench writes `BENCH_sim_hotpath.json` on every run; the repo
//! tracks one record per PR in `BENCH_trajectory.jsonl`.  This tool
//! compares the fresh record's host-side fps (`frames_per_sec_plan` —
//! the product path the coordinator serves through) against the last
//! tracked record and fails when it regressed by more than the
//! threshold, so a PR cannot silently lose the hot-path wins.
//!
//! ```text
//! bench_gate check  <fresh.json> <trajectory.jsonl> [threshold]
//!     exit 1 when fresh fps < (1 - threshold) × last recorded fps
//!     (threshold defaults to 0.20; missing baseline or fresh file ⇒ pass
//!      with a notice, so the gate bootstraps on a new trajectory)
//!
//! bench_gate record <fresh.json> <trajectory.jsonl> [label]
//!     append the fresh record as one trajectory line (run this once per
//!     PR, after `cargo bench --bench sim_hotpath`, and commit the file)
//!
//! bench_gate record-best <fresh.json> <trajectory.jsonl> [label]
//!     as `record`, but only when the fresh fps beats the last record —
//!     the CI rolling baseline uses this so a sequence of sub-threshold
//!     regressions cannot ratchet the floor downward run over run
//! ```
//!
//! No JSON dependency: the bench's writer is in-repo, so a key scan is
//! exact enough — and it keeps the gate runnable in the offline build.

use std::process::ExitCode;

/// Extract the first numeric value of a top-level `"key": <number>` pair.
/// Returns `None` for a missing key or a non-numeric value (e.g. `null`).
fn extract_f64(json: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\"");
    let at = json.find(&needle)? + needle.len();
    let rest = json[at..].trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Last non-empty line of a trajectory file's contents.
fn last_record(trajectory: &str) -> Option<&str> {
    trajectory.lines().map(str::trim).filter(|l| !l.is_empty()).last()
}

/// The gate decision: `Ok(notice)` to pass, `Err(reason)` to fail CI.
fn gate(prev: Option<f64>, fresh: f64, threshold: f64) -> Result<String, String> {
    let Some(prev) = prev else {
        return Ok(format!(
            "no baseline in trajectory — recording {fresh:.2} fps would seed it; pass"
        ));
    };
    if prev <= 0.0 {
        return Ok(format!("baseline {prev:.2} fps is degenerate; pass"));
    }
    let floor = prev * (1.0 - threshold);
    let delta = (fresh - prev) / prev * 100.0;
    if fresh < floor {
        Err(format!(
            "host-side fps regressed {delta:.1}%: {fresh:.2} < floor {floor:.2} \
             (baseline {prev:.2}, threshold {:.0}%)",
            threshold * 100.0
        ))
    } else {
        Ok(format!(
            "host-side fps {fresh:.2} vs baseline {prev:.2} ({delta:+.1}%, \
             floor {floor:.2}) — ok"
        ))
    }
}

const KEY: &str = "frames_per_sec_plan";

/// Host fps only compares like-for-like: records carry `host_threads` as
/// a cheap machine-class fingerprint, and the gate refuses to compare a
/// baseline from a different class (a dev workstation's fps floor would
/// spuriously fail every CI runner, and vice versa).  Missing fields
/// count as comparable so old records keep gating.
fn same_machine_class(prev: Option<f64>, fresh: Option<f64>) -> bool {
    match (prev, fresh) {
        (Some(a), Some(b)) => a == b,
        _ => true,
    }
}

fn run() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("check");
    let fresh_path = args.get(1).map(String::as_str).unwrap_or("BENCH_sim_hotpath.json");
    let traj_path = args.get(2).map(String::as_str).unwrap_or("../BENCH_trajectory.jsonl");
    match cmd {
        "check" => {
            let threshold: f64 = args
                .get(3)
                .map(|s| s.parse().map_err(|_| format!("bad threshold {s:?}")))
                .transpose()?
                .unwrap_or(0.20);
            let Ok(fresh) = std::fs::read_to_string(fresh_path) else {
                println!("bench_gate: no fresh record at {fresh_path} — nothing to gate");
                return Ok(());
            };
            let fresh_fps = extract_f64(&fresh, KEY)
                .ok_or_else(|| format!("{fresh_path} has no numeric {KEY:?}"))?;
            let traj = std::fs::read_to_string(traj_path).ok();
            let last = traj.as_deref().and_then(last_record);
            let prev = last.and_then(|l| extract_f64(l, KEY));
            let prev_threads = last.and_then(|l| extract_f64(l, "host_threads"));
            let fresh_threads = extract_f64(&fresh, "host_threads");
            if !same_machine_class(prev_threads, fresh_threads) {
                println!(
                    "bench_gate: baseline is from a different machine class (host_threads \
                     {prev_threads:?} vs {fresh_threads:?}) — skipping fps comparison"
                );
                return Ok(());
            }
            println!("bench_gate: {}", gate(prev, fresh_fps, threshold)?);
            Ok(())
        }
        "record" | "record-best" => {
            // keep the hand-rolled JSONL line well-formed for any label
            let label: String = args
                .get(3)
                .map(String::as_str)
                .unwrap_or("")
                .chars()
                .filter(|c| *c != '"' && *c != '\\' && !c.is_control())
                .collect();
            let fresh = std::fs::read_to_string(fresh_path)
                .map_err(|e| format!("read {fresh_path}: {e}"))?;
            let fps = extract_f64(&fresh, KEY)
                .ok_or_else(|| format!("{fresh_path} has no numeric {KEY:?}"))?;
            if cmd == "record-best" {
                let prev = std::fs::read_to_string(traj_path)
                    .ok()
                    .and_then(|t| last_record(&t).and_then(|l| extract_f64(l, KEY)));
                if let Some(prev) = prev {
                    if fps <= prev {
                        println!(
                            "bench_gate: {fps:.2} fps does not beat baseline {prev:.2} — \
                             keeping the existing record"
                        );
                        return Ok(());
                    }
                }
            }
            let legacy = extract_f64(&fresh, "frames_per_sec_legacy").unwrap_or(0.0);
            let speedup = extract_f64(&fresh, "plan_speedup").unwrap_or(0.0);
            let threads = extract_f64(&fresh, "host_threads").unwrap_or(0.0);
            let line = format!(
                "{{\"bench\": \"sim_hotpath\", \"label\": \"{label}\", \
                 \"host_threads\": {threads}, \"{KEY}\": {fps:.2}, \
                 \"frames_per_sec_legacy\": {legacy:.2}, \"plan_speedup\": {speedup:.2}}}\n"
            );
            let mut traj = std::fs::read_to_string(traj_path).unwrap_or_default();
            if !traj.is_empty() && !traj.ends_with('\n') {
                traj.push('\n');
            }
            traj.push_str(&line);
            std::fs::write(traj_path, traj).map_err(|e| format!("write {traj_path}: {e}"))?;
            println!("bench_gate: recorded {fps:.2} fps to {traj_path}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?} (use check|record|record-best)")),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("bench_gate: FAIL — {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "bench": "sim_hotpath",
  "host_threads": 8,
  "frames_per_sec_legacy": 12.31,
  "frames_per_sec_plan": 101.52,
  "plan_speedup": 8.25,
  "direct": [
    {"config": "[1,8,2]", "frames_per_sec": 55.10, "sim_cycles_per_frame": 812345}
  ]
}"#;

    #[test]
    fn extracts_numbers_by_key() {
        assert_eq!(extract_f64(SAMPLE, "frames_per_sec_plan"), Some(101.52));
        assert_eq!(extract_f64(SAMPLE, "frames_per_sec_legacy"), Some(12.31));
        assert_eq!(extract_f64(SAMPLE, "host_threads"), Some(8.0));
        assert_eq!(extract_f64(SAMPLE, "missing"), None);
        // null / non-numeric values are "no baseline", not a parse of 0
        let null_json = r#"{"frames_per_sec_plan": null}"#;
        assert_eq!(extract_f64(null_json, "frames_per_sec_plan"), None);
        assert_eq!(extract_f64(r#"{"a": -3.5e2}"#, "a"), Some(-350.0));
    }

    #[test]
    fn last_record_skips_blanks() {
        assert_eq!(last_record("a\nb\n\n"), Some("b"));
        assert_eq!(last_record("\n  \n"), None);
        assert_eq!(last_record(""), None);
    }

    #[test]
    fn gate_passes_without_baseline() {
        assert!(gate(None, 50.0, 0.2).is_ok());
        assert!(gate(Some(0.0), 50.0, 0.2).is_ok());
    }

    #[test]
    fn gate_fails_only_past_threshold() {
        // 20% threshold on a 100 fps baseline: floor is 80
        assert!(gate(Some(100.0), 81.0, 0.2).is_ok());
        assert!(gate(Some(100.0), 80.0, 0.2).is_ok());
        assert!(gate(Some(100.0), 79.9, 0.2).is_err());
        // improvements always pass
        assert!(gate(Some(100.0), 140.0, 0.2).is_ok());
    }

    #[test]
    fn machine_class_compares_only_when_both_known() {
        assert!(same_machine_class(Some(8.0), Some(8.0)));
        assert!(!same_machine_class(Some(8.0), Some(2.0)));
        assert!(same_machine_class(None, Some(2.0)));
        assert!(same_machine_class(Some(8.0), None));
        assert!(same_machine_class(None, None));
    }

    #[test]
    fn gate_reads_jsonl_record_shape() {
        let line = r#"{"bench": "sim_hotpath", "label": "pr2", "host_threads": 8, "frames_per_sec_plan": 90.00, "frames_per_sec_legacy": 12.00, "plan_speedup": 7.50}"#;
        let prev = last_record(line).and_then(|l| extract_f64(l, KEY));
        assert_eq!(prev, Some(90.0));
        assert!(gate(prev, 75.0, 0.2).is_ok());
        assert!(gate(prev, 71.9, 0.2).is_err());
    }
}
