//! CI perf gate over the `sim_hotpath` bench trajectory (ROADMAP item).
//!
//! The bench writes `BENCH_sim_hotpath.json` on every run; the repo
//! tracks one record per PR in `BENCH_trajectory.jsonl`.  This tool
//! compares the fresh record's host-side fps (`frames_per_sec_plan` —
//! the product path the coordinator serves through) against the last
//! tracked record *of the same machine class* and fails when it
//! regressed by more than the threshold, so a PR cannot silently lose
//! the hot-path wins (and a ledger mixing dev and CI records cannot
//! mute the gate).
//!
//! ```text
//! bench_gate check  <fresh.json> <trajectory.jsonl> [threshold]
//!     exit 0: compared and passed
//!     exit 1: fresh fps < (1 - threshold) × last recorded fps
//!     exit 2: nothing to compare — the trajectory has no numeric
//!             baseline (or no fresh record exists); CI should surface
//!             this as "gate did not run", not as a pass
//!     exit 3: comparison skipped — the baseline is from a different
//!             machine class (host_threads fingerprint mismatch)
//!     (threshold defaults to 0.20)
//!
//! bench_gate record <fresh.json> <trajectory.jsonl> [label]
//!     append the fresh record as one trajectory line (run this once per
//!     PR, after `cargo bench --bench sim_hotpath`, and commit the file)
//!
//! bench_gate record-best <fresh.json> <trajectory.jsonl> [label]
//!     as `record`, but only when the fresh fps beats the last record of
//!     the same machine class — the CI rolling baseline uses this so a
//!     sequence of sub-threshold regressions cannot ratchet the floor
//!     downward run over run
//!
//! bench_gate record-if-missing <fresh.json> <trajectory.jsonl> [label]
//!     as `record`, but only when the trajectory holds NO numeric record
//!     for this machine class — CI uses this to seed the numeric
//!     baseline the first time it runs on a runner class (the tracked
//!     seed line carries no fps on purpose)
//!
//! bench_gate record-prekernel <fresh.json> <trajectory.jsonl> [label]
//!     as `record-if-missing`, but the recorded fps is the scalar-kernel
//!     A/B leg (`frames_per_sec_plan_scalar`) written under the gate
//!     key with `"kernel": "scalar"` — CI runs this before
//!     `record-best`, so the first armed run on a runner class lands
//!     the pre-kernel floor and the packed kernel's record must then
//!     beat it to replace it
//! ```
//!
//! No JSON dependency: the bench's writer is in-repo, so a key scan is
//! exact enough — and it keeps the gate runnable in the offline build.

use std::process::ExitCode;

/// Exit code for "nothing to compare" (empty/seed-only ledger).
const EXIT_NO_BASELINE: u8 = 2;
/// Exit code for "comparison skipped: machine-class mismatch".
const EXIT_CLASS_SKIP: u8 = 3;

/// Extract the first numeric value of a top-level `"key": <number>` pair.
/// Returns `None` for a missing key or a non-numeric value (e.g. `null`).
fn extract_f64(json: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\"");
    let at = json.find(&needle)? + needle.len();
    let rest = json[at..].trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// How a gate invocation ended (other than outright failure).
enum Outcome {
    /// Compared against a baseline and passed.
    Pass(String),
    /// Nothing to compare: no numeric baseline (or no fresh record).
    NoBaseline(String),
    /// Comparison skipped: baseline is from another machine class.
    ClassSkip(String),
}

/// The gate decision: `Ok(notice)` to pass, `Err(reason)` to fail CI.
fn gate(prev: f64, fresh: f64, threshold: f64) -> Result<String, String> {
    let floor = prev * (1.0 - threshold);
    let delta = (fresh - prev) / prev * 100.0;
    if fresh < floor {
        Err(format!(
            "host-side fps regressed {delta:.1}%: {fresh:.2} < floor {floor:.2} \
             (baseline {prev:.2}, threshold {:.0}%)",
            threshold * 100.0
        ))
    } else {
        Ok(format!(
            "host-side fps {fresh:.2} vs baseline {prev:.2} ({delta:+.1}%, \
             floor {floor:.2}) — ok"
        ))
    }
}

const KEY: &str = "frames_per_sec_plan";
/// The scalar-kernel leg of the bench's kernel A/B — the pre-kernel
/// floor `record-prekernel` writes under [`KEY`].
const SCALAR_KEY: &str = "frames_per_sec_plan_scalar";

/// Host fps only compares like-for-like: records carry `host_threads` as
/// a cheap machine-class fingerprint, and the gate refuses to compare a
/// baseline from a different class (a dev workstation's fps floor would
/// spuriously fail every CI runner, and vice versa).  Missing fields
/// count as comparable so old records keep gating.
fn same_machine_class(prev: Option<f64>, fresh: Option<f64>) -> bool {
    match (prev, fresh) {
        (Some(a), Some(b)) => a == b,
        _ => true,
    }
}

/// The most recent trajectory line holding a numeric record comparable
/// to a fresh record with the given machine-class fingerprint.  `check`
/// and `record-if-missing` share this scan: a mixed-class ledger (dev
/// records interleaved with CI seeds) must neither mute the gate nor
/// block reseeding — the gate compares against the last record *of its
/// own class*, wherever it sits in the file.
fn last_class_record<'t>(trajectory: &'t str, fresh_threads: Option<f64>) -> Option<&'t str> {
    trajectory
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .filter(|l| {
            extract_f64(l, KEY).is_some()
                && same_machine_class(extract_f64(l, "host_threads"), fresh_threads)
        })
        .last()
}

/// Does the trajectory already hold a numeric record comparable to a
/// fresh record with the given machine-class fingerprint?
fn has_class_record(trajectory: &str, fresh_threads: Option<f64>) -> bool {
    last_class_record(trajectory, fresh_threads).is_some()
}

/// Build the one-line JSONL record for the trajectory ledger.  The fps
/// value is read from `fps_key` in the fresh bench record but always
/// written under the gate key ([`KEY`]), so a scalar pre-kernel floor
/// gates later packed records like any other baseline; `kernel` names
/// which dot-product kernel produced the recorded fps.
fn record_line(fresh: &str, label: &str, fps_key: &str, kernel: &str) -> Result<String, String> {
    // keep the hand-rolled JSONL line well-formed for any label
    let label: String = label
        .chars()
        .filter(|c| *c != '"' && *c != '\\' && !c.is_control())
        .collect();
    let fps = extract_f64(fresh, fps_key)
        .ok_or_else(|| format!("fresh record has no numeric {fps_key:?}"))?;
    let legacy = extract_f64(fresh, "frames_per_sec_legacy").unwrap_or(0.0);
    let speedup = extract_f64(fresh, "plan_speedup").unwrap_or(0.0);
    let threads = extract_f64(fresh, "host_threads").unwrap_or(0.0);
    let kernel_speedup = extract_f64(fresh, "kernel_speedup").unwrap_or(0.0);
    // end-to-end TCP rate from the bench's wire section; 0.0 for records
    // predating the wire front-end
    let wire_fps = extract_f64(fresh, "wire_frames_per_sec").unwrap_or(0.0);
    Ok(format!(
        "{{\"bench\": \"sim_hotpath\", \"label\": \"{label}\", \
         \"kernel\": \"{kernel}\", \"host_threads\": {threads}, \
         \"{KEY}\": {fps:.2}, \"frames_per_sec_legacy\": {legacy:.2}, \
         \"plan_speedup\": {speedup:.2}, \"kernel_speedup\": {kernel_speedup:.2}, \
         \"wire_fps\": {wire_fps:.2}}}\n"
    ))
}

/// Append the fresh record as one trajectory line.
fn append_record(
    fresh: &str,
    traj_path: &str,
    label: &str,
    fps_key: &str,
    kernel: &str,
) -> Result<String, String> {
    let line = record_line(fresh, label, fps_key, kernel)?;
    let fps = extract_f64(&line, KEY).expect("record_line always writes the gate key");
    let mut traj = std::fs::read_to_string(traj_path).unwrap_or_default();
    if !traj.is_empty() && !traj.ends_with('\n') {
        traj.push('\n');
    }
    traj.push_str(&line);
    std::fs::write(traj_path, traj).map_err(|e| format!("write {traj_path}: {e}"))?;
    Ok(format!("recorded {fps:.2} fps ({kernel} kernel) to {traj_path}"))
}

fn run() -> Result<Outcome, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("check");
    let fresh_path = args.get(1).map(String::as_str).unwrap_or("BENCH_sim_hotpath.json");
    let traj_path = args.get(2).map(String::as_str).unwrap_or("../BENCH_trajectory.jsonl");
    match cmd {
        "check" => {
            let threshold: f64 = args
                .get(3)
                .map(|s| s.parse().map_err(|_| format!("bad threshold {s:?}")))
                .transpose()?
                .unwrap_or(0.20);
            let Ok(fresh) = std::fs::read_to_string(fresh_path) else {
                return Ok(Outcome::NoBaseline(format!(
                    "no fresh record at {fresh_path} — nothing to gate"
                )));
            };
            let fresh_fps = extract_f64(&fresh, KEY)
                .ok_or_else(|| format!("{fresh_path} has no numeric {KEY:?}"))?;
            let fresh_threads = extract_f64(&fresh, "host_threads");
            let traj = std::fs::read_to_string(traj_path).ok();
            // Compare against the last record of *this* machine class —
            // a mixed-class ledger must not mute the gate just because
            // its final line came from a different machine.
            let matching = traj
                .as_deref()
                .and_then(|t| last_class_record(t, fresh_threads));
            let Some(line) = matching else {
                let any_numeric = traj
                    .as_deref()
                    .is_some_and(|t| t.lines().any(|l| extract_f64(l, KEY).is_some()));
                if any_numeric {
                    return Ok(Outcome::ClassSkip(format!(
                        "every numeric baseline in {traj_path} is from a different \
                         machine class (fresh host_threads {fresh_threads:?}) — \
                         fps comparison skipped"
                    )));
                }
                return Ok(Outcome::NoBaseline(format!(
                    "trajectory {traj_path} has no numeric {KEY} baseline — \
                     seed it with `bench_gate record` on this machine class \
                     ({fresh_fps:.2} fps would become the floor)"
                )));
            };
            let prev = extract_f64(line, KEY).expect("matching record is numeric");
            if prev <= 0.0 {
                return Ok(Outcome::NoBaseline(format!(
                    "baseline {prev:.2} fps is degenerate — nothing to compare"
                )));
            }
            gate(prev, fresh_fps, threshold).map(Outcome::Pass)
        }
        "record" | "record-best" | "record-if-missing" | "record-prekernel" => {
            let label = args.get(3).map(String::as_str).unwrap_or("");
            let fresh = std::fs::read_to_string(fresh_path)
                .map_err(|e| format!("read {fresh_path}: {e}"))?;
            // the pre-kernel floor records the scalar A/B leg under the
            // gate key; everything else records the product (packed) path
            let (fps_key, kernel) = if cmd == "record-prekernel" {
                (SCALAR_KEY, "scalar")
            } else {
                (KEY, "packed")
            };
            let Some(fps) = extract_f64(&fresh, fps_key) else {
                // `record-prekernel` runs against whatever bench record a
                // runner produced — a record predating the kernel A/B has
                // no scalar leg, and "can't seed a floor" is the SKIP
                // outcome (exit 2), not a gate failure that reddens CI
                if cmd == "record-prekernel" {
                    return Ok(Outcome::NoBaseline(format!(
                        "{fresh_path} has no numeric {fps_key:?} — \
                         pre-kernel floor not recorded"
                    )));
                }
                return Err(format!("{fresh_path} has no numeric {fps_key:?}"));
            };
            let traj = std::fs::read_to_string(traj_path).ok();
            let fresh_threads = extract_f64(&fresh, "host_threads");
            if cmd == "record-best" {
                // like `check`, compare within the machine class — a
                // foreign-class record must neither block nor admit a
                // rolling-baseline update
                let prev = traj
                    .as_deref()
                    .and_then(|t| last_class_record(t, fresh_threads))
                    .and_then(|l| extract_f64(l, KEY));
                if let Some(prev) = prev {
                    if fps <= prev {
                        return Ok(Outcome::Pass(format!(
                            "{fps:.2} fps does not beat baseline {prev:.2} — \
                             keeping the existing record"
                        )));
                    }
                }
            }
            if cmd == "record-if-missing" || cmd == "record-prekernel" {
                if traj
                    .as_deref()
                    .is_some_and(|t| has_class_record(t, fresh_threads))
                {
                    return Ok(Outcome::Pass(format!(
                        "{traj_path} already holds a numeric baseline for this \
                         machine class — not recording"
                    )));
                }
            }
            append_record(&fresh, traj_path, label, fps_key, kernel).map(Outcome::Pass)
        }
        other => Err(format!(
            "unknown command {other:?} \
             (use check|record|record-best|record-if-missing|record-prekernel)"
        )),
    }
}

/// The loud multi-line form of the "no baseline" outcome.  A quiet
/// one-liner let a seed-only trajectory pass every CI run while the
/// numeric gate silently proved nothing; the banner makes the unarmed
/// state impossible to misread in a log, and CI mirrors it into the
/// job summary.  The first line keeps the stable
/// `bench_gate: SKIP (no baseline, exit 2)` prefix scripts match on.
fn unarmed_banner(msg: &str) -> String {
    format!(
        "bench_gate: SKIP (no baseline, exit {EXIT_NO_BASELINE}) — {msg}\n\
         bench_gate: ==========================================================\n\
         bench_gate: ==  PERF GATE UNARMED — this run verified NOTHING about ==\n\
         bench_gate: ==  performance: the trajectory has no numeric baseline ==\n\
         bench_gate: ==  to compare against. Seed one with `bench_gate       ==\n\
         bench_gate: ==  record` on a runner-class machine to arm the gate.  ==\n\
         bench_gate: ==========================================================\n"
    )
}

fn main() -> ExitCode {
    match run() {
        Ok(Outcome::Pass(msg)) => {
            println!("bench_gate: {msg}");
            ExitCode::SUCCESS
        }
        Ok(Outcome::NoBaseline(msg)) => {
            print!("{}", unarmed_banner(&msg));
            ExitCode::from(EXIT_NO_BASELINE)
        }
        Ok(Outcome::ClassSkip(msg)) => {
            println!("bench_gate: SKIP (machine class, exit {EXIT_CLASS_SKIP}) — {msg}");
            ExitCode::from(EXIT_CLASS_SKIP)
        }
        Err(e) => {
            eprintln!("bench_gate: FAIL — {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_banner_is_loud_but_keeps_the_stable_prefix() {
        let b = unarmed_banner("trajectory has no numeric baseline");
        let first = b.lines().next().unwrap();
        assert!(
            first.starts_with("bench_gate: SKIP (no baseline, exit 2)"),
            "{first}"
        );
        assert!(first.contains("no numeric baseline"));
        assert!(b.contains("PERF GATE UNARMED"));
        assert!(b.lines().count() >= 5, "banner must be hard to miss:\n{b}");
    }

    const SAMPLE: &str = r#"{
  "bench": "sim_hotpath",
  "host_threads": 8,
  "frames_per_sec_legacy": 12.31,
  "frames_per_sec_plan": 101.52,
  "plan_speedup": 8.25,
  "direct": [
    {"config": "[1,8,2]", "frames_per_sec": 55.10, "sim_cycles_per_frame": 812345}
  ]
}"#;

    #[test]
    fn extracts_numbers_by_key() {
        assert_eq!(extract_f64(SAMPLE, "frames_per_sec_plan"), Some(101.52));
        assert_eq!(extract_f64(SAMPLE, "frames_per_sec_legacy"), Some(12.31));
        assert_eq!(extract_f64(SAMPLE, "host_threads"), Some(8.0));
        assert_eq!(extract_f64(SAMPLE, "missing"), None);
        // null / non-numeric values are "no baseline", not a parse of 0
        let null_json = r#"{"frames_per_sec_plan": null}"#;
        assert_eq!(extract_f64(null_json, "frames_per_sec_plan"), None);
        assert_eq!(extract_f64(r#"{"a": -3.5e2}"#, "a"), Some(-350.0));
    }

    #[test]
    fn class_scan_skips_blanks_and_non_records() {
        let t = "\n  \n{\"frames_per_sec_plan\": 10.0}\n\n";
        let l = last_class_record(t, Some(8.0)).expect("numeric line found");
        assert_eq!(extract_f64(l, KEY), Some(10.0));
        assert!(last_class_record("\n  \n", Some(8.0)).is_none());
        assert!(last_class_record("", None).is_none());
        assert!(last_class_record("plain text\n", None).is_none());
    }

    #[test]
    fn gate_fails_only_past_threshold() {
        // 20% threshold on a 100 fps baseline: floor is 80
        assert!(gate(100.0, 81.0, 0.2).is_ok());
        assert!(gate(100.0, 80.0, 0.2).is_ok());
        assert!(gate(100.0, 79.9, 0.2).is_err());
        // improvements always pass
        assert!(gate(100.0, 140.0, 0.2).is_ok());
    }

    #[test]
    fn machine_class_compares_only_when_both_known() {
        assert!(same_machine_class(Some(8.0), Some(8.0)));
        assert!(!same_machine_class(Some(8.0), Some(2.0)));
        assert!(same_machine_class(None, Some(2.0)));
        assert!(same_machine_class(Some(8.0), None));
        assert!(same_machine_class(None, None));
    }

    #[test]
    fn gate_reads_jsonl_record_shape() {
        let line = r#"{"bench": "sim_hotpath", "label": "pr2", "host_threads": 8, "frames_per_sec_plan": 90.00, "frames_per_sec_legacy": 12.00, "plan_speedup": 7.50}"#;
        let prev = last_class_record(line, Some(8.0))
            .and_then(|l| extract_f64(l, KEY))
            .unwrap();
        assert_eq!(prev, 90.0);
        assert!(gate(prev, 75.0, 0.2).is_ok());
        assert!(gate(prev, 71.9, 0.2).is_err());
    }

    #[test]
    fn record_line_reads_its_key_and_stamps_the_kernel() {
        let fresh = r#"{"host_threads": 8, "frames_per_sec_legacy": 12.00, "frames_per_sec_plan": 100.00, "plan_speedup": 8.00, "frames_per_sec_plan_scalar": 40.00, "kernel_speedup": 2.50}"#;
        let packed = record_line(fresh, "pr6", KEY, "packed").unwrap();
        assert_eq!(extract_f64(&packed, KEY), Some(100.0));
        assert_eq!(extract_f64(&packed, "kernel_speedup"), Some(2.5));
        assert!(packed.contains("\"kernel\": \"packed\""));
        // the scalar floor is written under the gate key…
        let scalar = record_line(fresh, "pre", SCALAR_KEY, "scalar").unwrap();
        assert_eq!(extract_f64(&scalar, KEY), Some(40.0));
        assert!(scalar.contains("\"kernel\": \"scalar\""));
        // …so later packed records gate against it like any baseline
        let prev = last_class_record(&scalar, Some(8.0))
            .and_then(|l| extract_f64(l, KEY))
            .unwrap();
        assert!(gate(prev, 100.0, 0.2).is_ok());
        assert!(gate(prev, 31.9, 0.2).is_err());
    }

    #[test]
    fn record_line_carries_wire_fps_and_defaults_it_to_zero() {
        let with_wire = r#"{"host_threads": 8, "frames_per_sec_plan": 100.00, "wire_frames_per_sec": 61.25}"#;
        let line = record_line(with_wire, "pr7", KEY, "packed").unwrap();
        assert_eq!(extract_f64(&line, "wire_fps"), Some(61.25));
        // records predating the wire front-end stamp 0.0, not a parse error
        let pre_wire = r#"{"host_threads": 8, "frames_per_sec_plan": 100.00}"#;
        let line = record_line(pre_wire, "pr6", KEY, "packed").unwrap();
        assert_eq!(extract_f64(&line, "wire_fps"), Some(0.0));
    }

    #[test]
    fn record_line_requires_its_fps_key() {
        // a pre-A/B bench record has no scalar leg: record-prekernel
        // must refuse rather than fabricate a floor
        let old = r#"{"host_threads": 2, "frames_per_sec_plan": 50.00}"#;
        assert!(record_line(old, "x", SCALAR_KEY, "scalar").is_err());
        assert!(record_line(old, "x", KEY, "packed").is_ok());
    }

    #[test]
    fn check_scans_past_other_class_records() {
        // mixed-class ledger: seed line, a CI record (2 threads), then a
        // dev record (8 threads).  A 2-thread runner must gate against
        // ITS class's record, not class-skip on the trailing dev line.
        let ledger = concat!(
            "{\"bench\": \"sim_hotpath\", \"label\": \"seed\", \"note\": \"no fps\"}\n",
            "{\"bench\": \"sim_hotpath\", \"label\": \"ci\", \"host_threads\": 2, \"frames_per_sec_plan\": 40.00}\n",
            "{\"bench\": \"sim_hotpath\", \"label\": \"dev\", \"host_threads\": 8, \"frames_per_sec_plan\": 400.00}\n",
        );
        let ci = last_class_record(ledger, Some(2.0)).expect("ci record found");
        assert_eq!(extract_f64(ci, KEY), Some(40.0));
        let dev = last_class_record(ledger, Some(8.0)).expect("dev record found");
        assert_eq!(extract_f64(dev, KEY), Some(400.0));
        // a class nothing matches gets no record at all
        assert!(last_class_record(ledger, Some(16.0)).is_none());
    }

    #[test]
    fn class_record_scan_sees_through_seed_lines() {
        // the tracked seed line has no fps — it must NOT count as a
        // numeric baseline
        let seed_only = r#"{"bench": "sim_hotpath", "label": "seed", "note": "no numeric baseline"}"#;
        assert!(!has_class_record(seed_only, Some(2.0)));
        // a numeric record of the same class counts…
        let with_ci = format!(
            "{seed_only}\n{{\"bench\": \"sim_hotpath\", \"label\": \"ci\", \"host_threads\": 2, \"frames_per_sec_plan\": 40.00}}\n"
        );
        assert!(has_class_record(&with_ci, Some(2.0)));
        // …but a different class does not
        assert!(!has_class_record(&with_ci, Some(8.0)));
        // unknown fresh class compares with anything numeric
        assert!(has_class_record(&with_ci, None));
    }
}
