//! The BinArray system (paper §IV-D, Fig. 10): `N_SA` systolic arrays, a
//! global feature buffer, the control unit, and the scatter/gather block
//! that distributes work across arrays.
//!
//! # Plan/execute split
//!
//! Construction compiles the network once into an [`ExecutionPlan`]
//! (see [`super::plan`]): per layer and per accuracy mode, the work-unit
//! assignment over logical SAs, the sequential level-group count, the
//! ping-pong buffer bindings and the tile geometry.  The per-frame
//! [`FrameExecutor`] is then a thin walk over that plan:
//!
//! * the CU state machine still triggers each layer (instruction-cycle
//!   accounting is unchanged), but the layer callback only *looks up* its
//!   [`LayerPlan`] — no scheduling arithmetic on the frame path;
//! * layer inputs are zero-copy [`crate::tensor::FeatureMapView`]s over
//!   the ping half of the feature buffer, outputs are disjoint
//!   [`crate::tensor::FeatureMapTileMut`] claims on the pong half — the
//!   per-layer `to_vec`/`zeros` churn of the pre-plan executor is gone;
//! * a layer's logical-SA work units execute on scoped host threads (the
//!   simulated SAs really do run in parallel now), with one reusable
//!   im2col scratch arena per host worker;
//! * [`BinArraySystem::run_frames`] runs a cut batch back-to-back on one
//!   plan — the coordinator's worker loop entry point.
//!
//! Simulated-cycle accounting is untouched by all of this: layer
//! wall-clock is still the maximum cycle count over physical SAs plus the
//! CU's per-instruction cycles, and logits are byte-identical to
//! [`crate::golden::forward`] (asserted by tests and the hot-path bench).

use anyhow::{bail, Result};

use std::ops::Range;

use crate::artifacts::{PackedPlanes, QuantLayer, QuantNetwork};
use crate::isa::{compile_network, Program};
use crate::kernel::KernelKind;
use crate::tensor::{extract_tile, FeatureMapTileMut, FeatureMapTiles, FeatureMapView, Shape};

use super::cu::ControlUnit;
use super::plan::{CardShard, ExecutionPlan, LayerPlan, ModePlan, WorkUnit};
use super::sa::{SaEngine, SimStats, TileScratch};
use super::ArrayConfig;

/// Per-frame execution report.
#[derive(Clone, Debug, Default)]
pub struct FrameStats {
    /// Wall-clock cycles of the frame (CU + max-over-SA layer cycles).
    pub cycles: u64,
    /// Per-layer wall cycles.
    pub layer_cycles: Vec<u64>,
    /// Aggregated per-SA work statistics (sum over layers).
    pub sa_stats: Vec<SimStats>,
    /// CU instruction cycles.
    pub instr_cycles: u64,
}

impl FrameStats {
    /// Seconds at the BinArray clock (400 MHz).
    pub fn seconds(&self) -> f64 {
        self.cycles as f64 / super::CLOCK_HZ
    }

    /// Frames per second at the BinArray clock.
    pub fn fps(&self) -> f64 {
        1.0 / self.seconds()
    }
}

/// Reusable per-frame execution state: the ping-pong feature buffer, the
/// parked CU and the host worker scratch arenas.  Owns everything
/// `run_frame` mutates, so consecutive frames of a batch share all
/// allocations — and a batch can run one executor per *frame lane* (the
/// multi-threaded frame pipeline of [`BinArraySystem::run_frames`]).
pub struct FrameExecutor {
    engine: SaEngine,
    cu: ControlUnit,
    /// Global/local feature buffer (ping-pong halves per the compiler).
    fbuf: Vec<i8>,
    /// One im2col/staging arena per intra-layer host worker.
    scratch: Vec<TileScratch>,
}

impl FrameExecutor {
    fn new(cfg: ArrayConfig, prog: &Program, scratch_width: usize, kernel: KernelKind) -> Self {
        let mut cu = ControlUnit::new();
        // Park at the entry HLT so every frame — first included, on any
        // lane — has the identical steady-state instruction-cycle cost.
        cu.park_at(prog.entry);
        Self {
            engine: SaEngine::with_kernel(cfg.d_arch, cfg.m_arch, kernel),
            cu,
            fbuf: vec![0; prog.fbuf_words],
            scratch: vec![TileScratch::default(); scratch_width.max(1)],
        }
    }

    /// Execute one frame of `mode`'s plan.  The thin per-frame walk: DMA
    /// the image in, let the CU trigger each layer against its
    /// precomputed [`LayerPlan`], read the logits out.  `intra_threads`
    /// is the scoped-thread width for a layer's logical-SA groups (1 =
    /// fully sequential).
    #[allow(clippy::too_many_arguments)]
    fn run_frame(
        &mut self,
        net: &QuantNetwork,
        prog: &Program,
        mode: &ModePlan,
        packed: &[PackedPlanes],
        n_sa: usize,
        image: &[i8],
        intra_threads: usize,
    ) -> Result<(Vec<i8>, FrameStats)> {
        let first = mode.layers.first().expect("non-empty plan");
        if image.len() != first.in_len {
            bail!("image len {} != {}", image.len(), first.in_len);
        }
        // DMA: CPU loads the frame into the first layer's input region.
        self.fbuf[first.in_base..first.in_base + first.in_len].copy_from_slice(image);

        let mut stats = FrameStats {
            sa_stats: vec![SimStats::default(); n_sa],
            ..Default::default()
        };

        let host_threads = intra_threads.max(1);
        if self.scratch.len() < host_threads {
            self.scratch.resize(host_threads, TileScratch::default());
        }

        // Borrow-splitting: the CU callback needs the executor's fields.
        let engine = self.engine;
        let fbuf = &mut self.fbuf;
        let scratch = &mut self.scratch;
        let layer_cycles = &mut stats.layer_cycles;
        let sa_stats = &mut stats.sa_stats;

        let cu_run = self.cu.run_frame(prog, |lr| {
            let li = lr.layer_id as usize;
            let lp = &mode.layers[li];
            let layer = &net.layers[li];
            let wall = exec_layer(
                engine,
                lp,
                layer,
                &packed[li],
                fbuf,
                scratch,
                host_threads,
                sa_stats,
                n_sa,
            );
            layer_cycles.push(wall);
            wall
        });

        stats.instr_cycles = cu_run.instr_cycles;
        stats.cycles = cu_run.total_cycles();

        // Logits live at the last layer's output region.
        let last = mode.layers.last().expect("non-empty plan");
        let logits = self.fbuf[last.out_base..last.out_base + last.out_len].to_vec();
        Ok((logits, stats))
    }
}

/// Run one layer of the plan: claim the zero-copy views over the two
/// feature-buffer halves, execute the work units (threaded across logical
/// SA groups when the plan has host parallelism to exploit), and account
/// cycles exactly as the sequential executor did — per-group stats land
/// on the group's first physical SA, layer wall-clock is the max over
/// groups.
#[allow(clippy::too_many_arguments)]
fn exec_layer(
    engine: SaEngine,
    lp: &LayerPlan,
    layer: &QuantLayer,
    packed: &PackedPlanes,
    fbuf: &mut [i8],
    scratch: &mut [TileScratch],
    host_threads: usize,
    sa_stats: &mut [SimStats],
    n_sa: usize,
) -> u64 {
    let half = fbuf.len() / 2;
    // Ping-pong split: input and output regions live in opposite halves,
    // so one `split_at_mut` yields a shared input view and an exclusive
    // output region with no copying.
    let (input, out): (&[i8], &mut [i8]) = if lp.in_base < half {
        let (ping, pong) = fbuf.split_at_mut(half);
        (
            &ping[lp.in_base..lp.in_base + lp.in_len],
            &mut pong[lp.out_base - half..lp.out_base - half + lp.out_len],
        )
    } else {
        let (ping, pong) = fbuf.split_at_mut(half);
        (
            &pong[lp.in_base - half..lp.in_base - half + lp.in_len],
            &mut ping[lp.out_base..lp.out_base + lp.out_len],
        )
    };
    let in_view = FeatureMapView::new(lp.in_shape, input);
    let groups = claim_groups(lp.out_shape, out, lp.claims(), &lp.assignments);

    // (`host_par` skips spawning entirely for layers too small to pay it)
    let n_workers = if lp.host_par { host_threads } else { 1 };
    let mut wall = 0u64;
    for (g, s) in run_groups(engine, lp, layer, packed, in_view, groups, scratch, n_workers) {
        sa_stats[g % n_sa].add(s);
        wall = wall.max(s.cycles);
    }
    wall
}

/// Claim one disjoint output tile per work unit and bind it to its unit,
/// grouped by logical SA (idle groups skipped) — the shared assembly of
/// the whole-layer and shard walks.  Claims are precomputed plan-side;
/// `claim_all`'s disjointness check is the release-mode gate backing the
/// tiles' `Send`.
fn claim_groups<'t, 'u>(
    out_shape: Shape,
    out: &'t mut [i8],
    claims: &[(Range<usize>, Range<usize>)],
    assignments: &'u [Vec<WorkUnit>],
) -> Vec<(usize, Vec<(&'u WorkUnit, FeatureMapTileMut<'t>)>)> {
    let mut flat = FeatureMapTiles::new(out_shape, out).claim_all(claims).into_iter();
    let mut groups = Vec::new();
    for (g, units) in assignments.iter().enumerate() {
        if units.is_empty() {
            continue;
        }
        let items: Vec<_> = units
            .iter()
            .map(|u| (u, flat.next().expect("claim per unit")))
            .collect();
        groups.push((g, items));
    }
    groups
}

/// Execute `(logical-SA id, claimed items)` groups on up to `n_workers`
/// scoped host threads (1 = fully sequential), returning per-group stats.
/// Shared by the in-card layer executor and the cross-card shard entry —
/// both walks parallelize over the same axis, a card's logical SAs.
/// (The `scratch.len()` bound keeps the worker/arena zip total — an
/// arena per spawned worker is a structural invariant.)
#[allow(clippy::too_many_arguments)]
fn run_groups(
    engine: SaEngine,
    lp: &LayerPlan,
    layer: &QuantLayer,
    packed: &PackedPlanes,
    in_view: FeatureMapView<'_>,
    groups: Vec<(usize, Vec<(&WorkUnit, FeatureMapTileMut<'_>)>)>,
    scratch: &mut [TileScratch],
    n_workers: usize,
) -> Vec<(usize, SimStats)> {
    let n_workers = n_workers.max(1).min(groups.len().max(1)).min(scratch.len());
    if n_workers <= 1 {
        let scr = &mut scratch[0];
        return groups
            .into_iter()
            .map(|(g, mut items)| {
                (g, run_units(engine, lp, layer, packed, in_view, &mut items, scr))
            })
            .collect();
    }
    // Round-robin the groups over the host workers; each worker owns its
    // scratch arena for the scope's duration.
    let mut chunks: Vec<Vec<(usize, Vec<(&WorkUnit, FeatureMapTileMut<'_>)>)>> =
        (0..n_workers).map(|_| Vec::new()).collect();
    for (i, item) in groups.into_iter().enumerate() {
        chunks[i % n_workers].push(item);
    }
    let mut out = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .into_iter()
            .zip(scratch.iter_mut())
            .map(|(chunk, scr)| {
                scope.spawn(move || {
                    chunk
                        .into_iter()
                        .map(|(g, mut items)| {
                            (g, run_units(engine, lp, layer, packed, in_view, &mut items, scr))
                        })
                        .collect::<Vec<(usize, SimStats)>>()
                })
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("SA worker panicked"));
        }
    });
    out
}

/// Execute one logical SA's work units sequentially (the hardware's view:
/// a logical SA is one or more physical SAs working one unit at a time).
fn run_units(
    engine: SaEngine,
    lp: &LayerPlan,
    layer: &QuantLayer,
    packed: &PackedPlanes,
    input: FeatureMapView<'_>,
    items: &mut [(&WorkUnit, FeatureMapTileMut<'_>)],
    scratch: &mut TileScratch,
) -> SimStats {
    let mut s = SimStats::default();
    for (u, tile) in items.iter_mut() {
        engine.run_unit(
            layer,
            Some(packed),
            input,
            u.rows.clone(),
            u.d.clone(),
            lp.m_run,
            lp.seq_m,
            tile,
            scratch,
            &mut s,
        );
    }
    s
}

/// One gathered output tile of a card's shard: the claim region plus its
/// dense data block (see [`crate::tensor::extract_tile`] for the layout).
#[derive(Clone, Debug)]
pub struct ShardTile {
    pub rows: Range<usize>,
    pub chans: Range<usize>,
    pub data: Vec<i8>,
}

/// Result of [`BinArraySystem::run_shard`]: this card's output tiles for
/// the layer (claim order) plus its cycle accounting.
#[derive(Clone, Debug, Default)]
pub struct ShardRun {
    pub tiles: Vec<ShardTile>,
    /// Card wall cycles for the layer — max over the card's logical-SA
    /// groups, exactly like a whole layer's wall is max over groups.
    pub wall: u64,
    /// Aggregate work statistics of the card on this layer.
    pub stats: SimStats,
}

/// The complete accelerator instance.
pub struct BinArraySystem {
    pub cfg: ArrayConfig,
    pub net: QuantNetwork,
    pub prog: Program,
    /// Precomputed per-mode schedules (the "plan" half).
    pub plan: ExecutionPlan,
    /// Per-frame execution lanes (the "execute" half).  Lane 0 serves the
    /// latency path (single frame, intra-layer threading); batches spread
    /// frames over up to `host_threads` lanes, each sequential inside.
    execs: Vec<FrameExecutor>,
    host_threads: usize,
    /// Host dot-product kernel used by every lane's engine (see
    /// [`crate::kernel`]).
    kernel: KernelKind,
    /// Input dims inferred by the compiler.
    pub input_shape: Shape,
    /// Runtime accuracy mode: number of binary levels to evaluate
    /// (`None` = all — high accuracy; `Some(m)` truncates — §IV-D).
    pub m_run: Option<usize>,
}

impl BinArraySystem {
    pub fn new(cfg: ArrayConfig, net: QuantNetwork) -> Result<Self> {
        let host_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::with_host_threads(cfg, net, host_threads)
    }

    /// As [`Self::new`] with an explicit host thread-pool width (`1` =
    /// fully sequential execution; logits are identical either way).
    pub fn with_host_threads(
        cfg: ArrayConfig,
        net: QuantNetwork,
        host_threads: usize,
    ) -> Result<Self> {
        if net.layers.is_empty() {
            bail!("empty network");
        }
        let host_threads = host_threads.max(1);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(cfg, &net, &prog);
        let kernel = KernelKind::from_env();
        Ok(Self {
            cfg,
            execs: vec![FrameExecutor::new(cfg, &prog, host_threads, kernel)],
            host_threads,
            kernel,
            input_shape: plan.input_shape,
            plan,
            prog,
            net,
            m_run: None,
        })
    }

    /// Build from already-compiled parts — the model-registry path,
    /// where the program and plan are compiled once at registration and
    /// shared by every card that serves the model.  Identical to
    /// [`Self::new`] modulo skipping the compile.
    pub fn from_parts(
        cfg: ArrayConfig,
        net: QuantNetwork,
        prog: Program,
        plan: ExecutionPlan,
    ) -> Result<Self> {
        if net.layers.is_empty() {
            bail!("empty network");
        }
        let host_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let kernel = KernelKind::from_env();
        Ok(Self {
            cfg,
            execs: vec![FrameExecutor::new(cfg, &prog, host_threads, kernel)],
            host_threads,
            kernel,
            input_shape: plan.input_shape,
            plan,
            prog,
            net,
            m_run: None,
        })
    }

    /// Change the host thread-pool width (simulation-speed knob only —
    /// simulated cycles and logits are unaffected).
    pub fn set_host_threads(&mut self, n: usize) {
        self.host_threads = n.max(1);
    }

    /// Select the host dot-product kernel for every execution lane
    /// (simulation-speed knob only — simulated cycles and logits are
    /// unaffected; see [`crate::kernel`]).  Defaults to the
    /// `BINARRAY_KERNEL` process override, else the packed kernel.
    pub fn set_kernel(&mut self, kernel: KernelKind) {
        self.kernel = kernel;
        for exec in &mut self.execs {
            exec.engine.kernel = kernel;
        }
    }

    /// Run one frame: load `image` (int8, row-major HWC), execute the CNN
    /// processing program, return (logits, stats).
    pub fn run_frame(&mut self, image: &[i8]) -> Result<(Vec<i8>, FrameStats)> {
        let mut frames = self.run_frames(&[image])?;
        Ok(frames.pop().expect("one frame in, one frame out"))
    }

    /// Run a batch of frames on the precomputed plan — the coordinator's
    /// per-batch entry point.  One mode lookup and zero per-frame setup.
    ///
    /// A single frame runs on lane 0 with intra-layer threading (lowest
    /// latency).  A batch becomes a *frame pipeline*: frames interleave
    /// over up to `host_threads` executor lanes, each lane sequential
    /// inside — frame-grain parallelism has no tile-imbalance loss, so
    /// batch throughput scales with cores.  Lane assignment is invisible
    /// in the results: every lane's CU is parked in steady state, and
    /// simulated cycle accounting is per frame by construction.
    pub fn run_frames(&mut self, images: &[&[i8]]) -> Result<Vec<(Vec<i8>, FrameStats)>> {
        let mode = self.plan.mode(self.m_run);
        let packed = self.plan.packed.as_slice();
        let lanes = self.host_threads.min(images.len());
        if lanes <= 1 {
            let exec = &mut self.execs[0];
            let mut out = Vec::with_capacity(images.len());
            for &image in images {
                out.push(exec.run_frame(
                    &self.net,
                    &self.prog,
                    mode,
                    packed,
                    self.cfg.n_sa,
                    image,
                    self.host_threads,
                )?);
            }
            return Ok(out);
        }

        while self.execs.len() < lanes {
            self.execs.push(FrameExecutor::new(self.cfg, &self.prog, 1, self.kernel));
        }
        let net = &self.net;
        let prog = &self.prog;
        let n_sa = self.cfg.n_sa;
        let mut slots: Vec<Option<(Vec<i8>, FrameStats)>> =
            images.iter().map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        std::thread::scope(|scope| {
            let handles: Vec<_> = self.execs[..lanes]
                .iter_mut()
                .enumerate()
                .map(|(lane, exec)| {
                    scope.spawn(move || {
                        let mut res = Vec::new();
                        for (i, &image) in
                            images.iter().enumerate().skip(lane).step_by(lanes)
                        {
                            res.push((i, exec.run_frame(net, prog, mode, packed, n_sa, image, 1)));
                        }
                        res
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("frame lane panicked") {
                    match r {
                        Ok(v) => slots[i] = Some(v),
                        Err(e) => {
                            first_err.get_or_insert(e);
                        }
                    }
                }
            }
        });
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(slots
            .into_iter()
            .map(|s| s.expect("every frame index covered by a lane"))
            .collect())
    }

    /// Execute one layer's cross-card shard — the worker-card half of the
    /// coordinator's scatter/gather path.
    ///
    /// `input` is the layer's *full* input region (every card sees the
    /// whole ping half — the scatter duplicates inputs, not outputs, so
    /// convolution halos need no special casing); `shard` is this card's
    /// sub-schedule from a [`super::plan::ShardPlan`].  The card computes
    /// its disjoint output tiles in its own feature buffer and returns
    /// them as owned [`ShardTile`] blocks for the coordinator to stitch
    /// into the frame's pong half.  Uses the current [`Self::set_mode`]
    /// accuracy mode, like `run_frames`.
    pub fn run_shard(
        &mut self,
        layer_idx: usize,
        input: &[i8],
        shard: &CardShard,
    ) -> Result<ShardRun> {
        let mode = self.plan.mode(self.m_run);
        let Some(lp) = mode.layers.get(layer_idx) else {
            bail!("layer {layer_idx} out of range ({} layers)", mode.layers.len());
        };
        if input.len() != lp.in_len {
            bail!("shard input len {} != {}", input.len(), lp.in_len);
        }
        let layer = &self.net.layers[lp.layer];
        let packed = &self.plan.packed[lp.layer];
        let host_threads = self.host_threads;
        let exec = &mut self.execs[0];
        let engine = exec.engine;
        let in_view = FeatureMapView::new(lp.in_shape, input);

        let mut run = ShardRun::default();
        {
            // Stage the card's tiles in its own feature buffer's out
            // region (the same ping-pong address the unsharded path
            // writes), then lift them out as owned blocks.
            let out = &mut exec.fbuf[lp.out_base..lp.out_base + lp.out_len];
            let groups = claim_groups(lp.out_shape, out, shard.claims(), &shard.assignments);
            // Same intra-card threading as the unsharded layer walk: the
            // card's logical-SA groups spread over the host pool.
            let n_workers = if lp.host_par { host_threads } else { 1 };
            let results = run_groups(
                engine,
                lp,
                layer,
                packed,
                in_view,
                groups,
                &mut exec.scratch,
                n_workers,
            );
            for (_, s) in results {
                run.wall = run.wall.max(s.cycles);
                run.stats.add(s);
            }
        }
        let out = &exec.fbuf[lp.out_base..lp.out_base + lp.out_len];
        run.tiles = shard
            .claims()
            .iter()
            .map(|(rows, chans)| ShardTile {
                rows: rows.clone(),
                chans: chans.clone(),
                data: extract_tile(lp.out_shape, out, rows.clone(), chans.clone()),
            })
            .collect();
        Ok(run)
    }

    /// Switch runtime accuracy mode (§IV-D): `None` = high accuracy (all
    /// M levels), `Some(m)` = evaluate only the first `m` levels.  O(1):
    /// every mode's schedule is precomputed in the [`ExecutionPlan`].
    pub fn set_mode(&mut self, m_run: Option<usize>) {
        self.m_run = m_run;
    }

    /// Execute one full frame over `cards`, sharded per `shards` — the
    /// orchestrator's scatter/gather data path without the coordinator
    /// threads: per layer, every claiming card runs its sub-schedule over
    /// the layer's full input region and the host stitches the returned
    /// tiles into a ping-pong feature buffer.  All cards must be built
    /// from the same network and config as the plan behind `shards`; the
    /// cards' accuracy mode is set to `m_run` here.  Returns the logits
    /// and the sharded frame's critical path (sum over layers of the
    /// slowest card's wall cycles).
    ///
    /// This is the reference data path the sharded arms of the
    /// differential racer ([`crate::verify`]) and the exactness suites
    /// drive; the threaded orchestrator in `coordinator::server` must be
    /// output-identical to it.
    pub fn run_frame_sharded(
        cards: &mut [BinArraySystem],
        shards: &super::plan::ShardPlan,
        image: &[i8],
        m_run: Option<usize>,
    ) -> Result<(Vec<i8>, u64)> {
        use crate::tensor::scatter_tile;
        let Some(first_card) = cards.first() else {
            bail!("run_frame_sharded needs at least one card");
        };
        let plan = first_card.plan.clone();
        for c in cards.iter_mut() {
            c.set_mode(m_run);
        }
        let mode = plan.mode(m_run);
        let Some(first) = mode.layers.first() else {
            bail!("plan has no layers");
        };
        if image.len() != first.in_len {
            bail!("image len {} != {}", image.len(), first.in_len);
        }
        let mut fbuf = vec![0i8; plan.fbuf_words];
        fbuf[first.in_base..first.in_base + first.in_len].copy_from_slice(image);
        let mut critical = 0u64;
        for (li, lp) in mode.layers.iter().enumerate() {
            let input = fbuf[lp.in_base..lp.in_base + lp.in_len].to_vec();
            let mut wall = 0u64;
            let mut tiles = Vec::new();
            for (ci, shard) in shards.mode(m_run)[li].cards.iter().enumerate() {
                if shard.n_units() == 0 {
                    continue;
                }
                let run = cards[ci].run_shard(li, &input, shard)?;
                wall = wall.max(run.wall);
                tiles.extend(run.tiles);
            }
            let out = &mut fbuf[lp.out_base..lp.out_base + lp.out_len];
            for t in tiles {
                scatter_tile(lp.out_shape, out, t.rows, t.chans, &t.data);
            }
            critical += wall;
        }
        let last = mode.layers.last().expect("checked non-empty");
        Ok((
            fbuf[last.out_base..last.out_base + last.out_len].to_vec(),
            critical,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::util::{prop, rng::Xoshiro256};

    fn image(rng: &mut Xoshiro256) -> Vec<i8> {
        prop::i8_vec(rng, 48 * 48 * 3)
    }

    #[test]
    fn frame_matches_golden_model() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net.clone()).unwrap();
        for _ in 0..3 {
            let img = image(&mut rng);
            let (logits, _) = sys.run_frame(&img).unwrap();
            let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
            assert_eq!(logits, want);
        }
    }

    #[test]
    fn all_paper_configs_same_outputs() {
        // Outputs must be invariant across [N_SA, D_arch, M_arch].
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let img = image(&mut rng);
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        for cfg in super::super::PAPER_CONFIGS {
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (logits, _) = sys.run_frame(&img).unwrap();
            assert_eq!(logits, want, "config {}", cfg.label());
        }
    }

    #[test]
    fn bigger_arrays_are_faster() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 2);
        let img = image(&mut rng);
        let mut cycles = Vec::new();
        for cfg in [
            ArrayConfig::new(1, 8, 2),
            ArrayConfig::new(1, 32, 2),
            ArrayConfig::new(4, 32, 4),
        ] {
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (_, stats) = sys.run_frame(&img).unwrap();
            cycles.push(stats.cycles);
        }
        assert!(cycles[0] > cycles[1], "{cycles:?}");
        assert!(cycles[1] >= cycles[2], "{cycles:?}");
    }

    #[test]
    fn mode_switch_trades_cycles_for_levels() {
        // M=4 net on M_arch=2 hardware: high-accuracy (2 passes) vs
        // high-throughput (1 pass) — §IV-D.
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 4);
        let img = image(&mut rng);
        let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net.clone()).unwrap();
        let (logits_full, s_full) = sys.run_frame(&img).unwrap();
        sys.set_mode(Some(2));
        let (logits_fast, s_fast) = sys.run_frame(&img).unwrap();
        assert!(s_full.cycles > s_fast.cycles * 3 / 2);
        // and the fast mode equals golden with m_run=2
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        assert_eq!(logits_fast, want);
        let want_full = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(logits_full, want_full);
    }

    #[test]
    fn frame_stats_accounting() {
        let mut rng = Xoshiro256::new(5);
        let net = cnn_a_quant(&mut rng, 2);
        let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net).unwrap();
        let (_, stats) = sys.run_frame(&image(&mut rng)).unwrap();
        assert_eq!(stats.layer_cycles.len(), 5);
        let sum: u64 = stats.layer_cycles.iter().sum();
        assert_eq!(stats.cycles, sum + stats.instr_cycles);
        assert!(stats.fps() > 0.0);
        // CNN-A at [1,8,2] should land in the Eq.-18 ballpark (~0.8 M cc)
        assert!(
            (700_000..1_100_000).contains(&stats.cycles),
            "cycles {}",
            stats.cycles
        );
    }

    #[test]
    fn multi_sa_tiling_preserves_outputs() {
        let mut rng = Xoshiro256::new(6);
        let net = cnn_a_quant(&mut rng, 2);
        let img = image(&mut rng);
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        // N_SA=16 with D_arch=8 → layer 0 (D=5) tiles across many SAs
        let mut sys = BinArraySystem::new(ArrayConfig::new(16, 8, 2), net).unwrap();
        let (logits, stats) = sys.run_frame(&img).unwrap();
        assert_eq!(logits, want);
        // tiling must cut layer-0 wall cycles vs a single SA
        assert!(stats.layer_cycles[0] < 42 * 42 * 147 / 2);
    }

    #[test]
    fn run_frames_equals_per_frame_runs() {
        let mut rng = Xoshiro256::new(7);
        let net = cnn_a_quant(&mut rng, 2);
        let imgs: Vec<Vec<i8>> = (0..3).map(|_| image(&mut rng)).collect();
        let refs: Vec<&[i8]> = imgs.iter().map(Vec::as_slice).collect();
        let mut sys = BinArraySystem::new(ArrayConfig::new(4, 32, 4), net.clone()).unwrap();
        let batch = sys.run_frames(&refs).unwrap();
        assert_eq!(batch.len(), 3);
        let mut one_by_one = BinArraySystem::new(ArrayConfig::new(4, 32, 4), net).unwrap();
        for (img, (logits, stats)) in imgs.iter().zip(&batch) {
            let (want_logits, want_stats) = one_by_one.run_frame(img).unwrap();
            assert_eq!(*logits, want_logits);
            assert_eq!(stats.cycles, want_stats.cycles);
        }
    }

    #[test]
    fn shard_path_layer_by_layer_matches_golden() {
        // Drive run_shard directly (no coordinator threads): scatter each
        // layer over N card systems, gather tiles into a host-held
        // ping-pong buffer, and check logits + latency accounting.
        use crate::binarray::plan::ShardPlan;
        let mut rng = Xoshiro256::new(9);
        let net = cnn_a_quant(&mut rng, 4);
        let img = image(&mut rng);
        let cfg = ArrayConfig::new(1, 8, 2);
        for (n_cards, m_run) in [(2usize, None), (3, Some(2))] {
            let mut cards: Vec<BinArraySystem> = (0..n_cards)
                .map(|_| BinArraySystem::with_host_threads(cfg, net.clone(), 1).unwrap())
                .collect();
            let plan = cards[0].plan.clone();
            let shards = ShardPlan::new(&plan, n_cards);
            let (logits, sharded_layer_sum) =
                BinArraySystem::run_frame_sharded(&mut cards, &shards, &img, m_run).unwrap();
            let want = golden::forward(&net, &img, Shape::new(48, 48, 3), m_run);
            assert_eq!(logits, want, "cards={n_cards} mode={m_run:?}");
            // latency: the sharded machine's layer walls must beat one card
            let mut one = BinArraySystem::with_host_threads(cfg, net.clone(), 1).unwrap();
            one.set_mode(m_run);
            let (_, stats) = one.run_frame(&img).unwrap();
            let unsharded_sum: u64 = stats.layer_cycles.iter().sum();
            assert!(
                sharded_layer_sum < unsharded_sum,
                "cards={n_cards}: sharded {sharded_layer_sum} !< {unsharded_sum}"
            );
        }
    }

    #[test]
    fn one_card_shard_cycles_match_unsharded() {
        use crate::binarray::plan::ShardPlan;
        let mut rng = Xoshiro256::new(10);
        let net = cnn_a_quant(&mut rng, 2);
        let img = image(&mut rng);
        let cfg = ArrayConfig::new(4, 32, 4);
        let mut card = BinArraySystem::with_host_threads(cfg, net.clone(), 1).unwrap();
        let shards = ShardPlan::new(&card.plan, 1);
        let n_claims = card.plan.mode(None).layers[0].claims().len();
        let mut reference = BinArraySystem::with_host_threads(cfg, net, 1).unwrap();
        let (_, stats) = reference.run_frame(&img).unwrap();
        // layer 0's input is the image itself; its shard wall must equal
        // the unsharded layer-0 wall exactly (same units, same groups)
        let run = card.run_shard(0, &img, &shards.mode(None)[0].cards[0]).unwrap();
        assert_eq!(run.wall, stats.layer_cycles[0]);
        assert_eq!(n_claims, run.tiles.len());
    }

    #[test]
    fn host_threading_never_changes_outputs_or_cycles() {
        let mut rng = Xoshiro256::new(8);
        let net = cnn_a_quant(&mut rng, 4);
        let img = image(&mut rng);
        let mut seq = BinArraySystem::with_host_threads(
            ArrayConfig::new(4, 32, 4),
            net.clone(),
            1,
        )
        .unwrap();
        let mut par =
            BinArraySystem::with_host_threads(ArrayConfig::new(4, 32, 4), net, 8).unwrap();
        let (l1, s1) = seq.run_frame(&img).unwrap();
        let (l2, s2) = par.run_frame(&img).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(s1.cycles, s2.cycles);
        assert_eq!(s1.sa_stats, s2.sa_stats);
    }
}
