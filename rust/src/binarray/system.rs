//! The BinArray system (paper §IV-D, Fig. 10): `N_SA` systolic arrays, a
//! global feature buffer, the control unit, and the scatter/gather block
//! that distributes work across arrays.
//!
//! Scheduling follows the paper's parallelism model (§IV-E):
//!
//! 1. level-group parallelism — `⌈M/M_arch⌉` groups spread over SAs
//!    (Eq. 15's logical SAs); leftover groups run sequentially;
//! 2. channel-pass parallelism — `⌈D/D_arch⌉` passes distributed over
//!    logical SAs (Eq. 17);
//! 3. input tiling — when channel passes underfill the logical SAs, the
//!    input is tiled along pooled-output rows (Eq. 16, width/height only,
//!    never depth — keeps convolutions atomic).
//!
//! Layer wall-clock = the maximum cycle count over physical SAs (they run
//! in parallel), plus the CU's per-instruction cycles.

use anyhow::{bail, Result};

use crate::artifacts::{LayerKind, QuantNetwork};
use crate::isa::{compile_network, Program};
use crate::tensor::{FeatureMap, Shape};

use super::cu::{ControlUnit, CuRun};
use super::sa::{SaEngine, SimStats};
use super::ArrayConfig;

/// Per-frame execution report.
#[derive(Clone, Debug, Default)]
pub struct FrameStats {
    /// Wall-clock cycles of the frame (CU + max-over-SA layer cycles).
    pub cycles: u64,
    /// Per-layer wall cycles.
    pub layer_cycles: Vec<u64>,
    /// Aggregated per-SA work statistics (sum over layers).
    pub sa_stats: Vec<SimStats>,
    /// CU instruction cycles.
    pub instr_cycles: u64,
}

impl FrameStats {
    /// Seconds at the BinArray clock (400 MHz).
    pub fn seconds(&self) -> f64 {
        self.cycles as f64 / super::CLOCK_HZ
    }

    /// Frames per second at the BinArray clock.
    pub fn fps(&self) -> f64 {
        1.0 / self.seconds()
    }
}

/// One unit of schedulable work for a layer.
#[derive(Clone, Debug, PartialEq, Eq)]
struct WorkUnit {
    /// Pooled-output row range (conv) — full range for dense.
    rows: std::ops::Range<usize>,
    /// Output-channel range.
    d: std::ops::Range<usize>,
}

/// The complete accelerator instance.
pub struct BinArraySystem {
    pub cfg: ArrayConfig,
    pub net: QuantNetwork,
    pub prog: Program,
    cu: ControlUnit,
    engine: SaEngine,
    /// Global/local feature buffer (ping-pong halves per the compiler).
    fbuf: Vec<i8>,
    /// Input dims inferred by the compiler.
    pub input_shape: Shape,
    /// Runtime accuracy mode: number of binary levels to evaluate
    /// (`None` = all — high accuracy; `Some(m)` truncates — §IV-D).
    pub m_run: Option<usize>,
}

impl BinArraySystem {
    pub fn new(cfg: ArrayConfig, net: QuantNetwork) -> Result<Self> {
        if net.layers.is_empty() {
            bail!("empty network");
        }
        let prog = compile_network(&net);
        let dims = crate::isa::compiler::infer_input_dims(&net);
        Ok(Self {
            cfg,
            engine: SaEngine::new(cfg.d_arch, cfg.m_arch),
            fbuf: vec![0; prog.fbuf_words],
            input_shape: Shape::new(dims.1, dims.0, dims.2),
            prog,
            net,
            cu: ControlUnit::new(),
            m_run: None,
        })
    }

    /// Run one frame: load `image` (int8, row-major HWC), execute the CNN
    /// processing program, return (logits, stats).
    pub fn run_frame(&mut self, image: &[i8]) -> Result<(Vec<i8>, FrameStats)> {
        let in_len = self.input_shape.len();
        if image.len() != in_len {
            bail!("image len {} != {}", image.len(), in_len);
        }
        // DMA: CPU loads the frame into the first layer's input region.
        let in_base = self.prog.bindings[0].in_base;
        self.fbuf[in_base..in_base + in_len].copy_from_slice(image);

        let mut stats = FrameStats {
            sa_stats: vec![SimStats::default(); self.cfg.n_sa],
            ..Default::default()
        };

        // Borrow-splitting: the CU callback needs &mut self fields.
        let net = &self.net;
        let bindings = &self.prog.bindings;
        let engine = self.engine;
        let cfg = self.cfg;
        let fbuf = &mut self.fbuf;
        let input_shape = self.input_shape;
        let m_run_mode = self.m_run;
        let layer_cycles = &mut stats.layer_cycles;
        let sa_stats = &mut stats.sa_stats;

        let cu_run: CuRun = self.cu.run_frame(&self.prog, |lr| {
            let li = lr.layer_id as usize;
            let layer = &net.layers[li];
            let b = &bindings[li];
            let m_run = m_run_mode.unwrap_or(layer.m).min(layer.m).max(1);

            let wall = match layer.kind {
                LayerKind::Conv => {
                    let in_shape = if li == 0 {
                        input_shape
                    } else {
                        Shape::new(b.in_dims.1, b.in_dims.0, b.in_dims.2)
                    };
                    let in_len = in_shape.len();
                    let input = FeatureMap::from_vec(
                        in_shape,
                        fbuf[b.in_base..b.in_base + in_len].to_vec(),
                    );
                    let out_shape = Shape::new(b.out_dims.1, b.out_dims.0, b.out_dims.2);
                    let mut out = FeatureMap::zeros(out_shape);
                    let (assignments, seq_m) =
                        Self::schedule_static(cfg, layer.d, out_shape.h, m_run);
                    let mut wall = 0u64;
                    for (g, units) in assignments.iter().enumerate() {
                        let mut s = SimStats::default();
                        for u in units {
                            engine.conv_tile(
                                layer,
                                &input,
                                u.rows.clone(),
                                u.d.clone(),
                                m_run,
                                seq_m,
                                &mut out,
                                &mut s,
                            );
                        }
                        // group g occupies physical SAs [g*gsz, ...); charge
                        // the group's work to its first physical SA.
                        sa_stats[g % cfg.n_sa].add(s);
                        wall = wall.max(s.cycles);
                    }
                    let out_len = out_shape.len();
                    fbuf[b.out_base..b.out_base + out_len].copy_from_slice(&out.data);
                    wall
                }
                LayerKind::Dense => {
                    let n_in = layer.n_c();
                    let input = fbuf[b.in_base..b.in_base + n_in].to_vec();
                    let mut out = vec![0i8; layer.d];
                    let (assignments, seq_m) = Self::schedule_static(cfg, layer.d, 1, m_run);
                    let mut wall = 0u64;
                    for (g, units) in assignments.iter().enumerate() {
                        let mut s = SimStats::default();
                        for u in units {
                            engine.dense_tile(
                                layer,
                                &input,
                                u.d.clone(),
                                m_run,
                                seq_m,
                                &mut out,
                                &mut s,
                            );
                        }
                        sa_stats[g % cfg.n_sa].add(s);
                        wall = wall.max(s.cycles);
                    }
                    fbuf[b.out_base..b.out_base + layer.d].copy_from_slice(&out);
                    wall
                }
            };
            layer_cycles.push(wall);
            wall
        });

        stats.instr_cycles = cu_run.instr_cycles;
        stats.cycles = cu_run.total_cycles();

        // Logits live at the last layer's output region.
        let last = bindings.last().unwrap();
        let k = net.layers.last().unwrap().d;
        let logits = self.fbuf[last.out_base..last.out_base + k].to_vec();
        Ok((logits, stats))
    }

    /// `schedule` without `&self` (for use inside the CU closure).
    fn schedule_static(
        cfg: ArrayConfig,
        d_out: usize,
        pooled_rows: usize,
        m_run: usize,
    ) -> (Vec<Vec<WorkUnit>>, u64) {
        // mirrors `schedule`; kept static for borrow reasons
        let tmp = BinArraySystemScheduler { cfg };
        tmp.schedule(d_out, pooled_rows, m_run)
    }

    /// Switch runtime accuracy mode (§IV-D): `None` = high accuracy (all
    /// M levels), `Some(m)` = evaluate only the first `m` levels.
    pub fn set_mode(&mut self, m_run: Option<usize>) {
        self.m_run = m_run;
    }
}

/// Scheduling policy, factored out so it is callable without borrowing the
/// whole system (and unit-testable in isolation).
struct BinArraySystemScheduler {
    cfg: ArrayConfig,
}

impl BinArraySystemScheduler {
    fn schedule(&self, d_out: usize, pooled_rows: usize, m_run: usize) -> (Vec<Vec<WorkUnit>>, u64) {
        let m_groups = m_run.div_ceil(self.cfg.m_arch);
        let n_lsa = (self.cfg.n_sa / m_groups).max(1);
        let seq_m = m_groups.div_ceil(self.cfg.n_sa.min(m_groups)) as u64;

        let d_passes = d_out.div_ceil(self.cfg.d_arch);
        let mut n_t = (n_lsa / d_passes).max(1);
        n_t = n_t.min(pooled_rows.max(1));
        while n_t > 1 && pooled_rows / n_t < 2 {
            n_t -= 1;
        }

        let mut assignments: Vec<Vec<WorkUnit>> = vec![Vec::new(); n_lsa];
        let row_tiles = crate::tensor::tile_ranges(pooled_rows.max(1), n_t, 0);
        let mut lsa = 0usize;
        for (r0, r1) in row_tiles {
            for dp in 0..d_passes {
                let d0 = dp * self.cfg.d_arch;
                let d1 = (d0 + self.cfg.d_arch).min(d_out);
                assignments[lsa].push(WorkUnit {
                    rows: r0..r1,
                    d: d0..d1,
                });
                lsa = (lsa + 1) % n_lsa;
            }
        }
        (assignments, seq_m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::util::{prop, rng::Xoshiro256};

    fn image(rng: &mut Xoshiro256) -> Vec<i8> {
        prop::i8_vec(rng, 48 * 48 * 3)
    }

    #[test]
    fn frame_matches_golden_model() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net.clone()).unwrap();
        for _ in 0..3 {
            let img = image(&mut rng);
            let (logits, _) = sys.run_frame(&img).unwrap();
            let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
            assert_eq!(logits, want);
        }
    }

    #[test]
    fn all_paper_configs_same_outputs() {
        // Outputs must be invariant across [N_SA, D_arch, M_arch].
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let img = image(&mut rng);
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        for cfg in super::super::PAPER_CONFIGS {
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (logits, _) = sys.run_frame(&img).unwrap();
            assert_eq!(logits, want, "config {}", cfg.label());
        }
    }

    #[test]
    fn bigger_arrays_are_faster() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 2);
        let img = image(&mut rng);
        let mut cycles = Vec::new();
        for cfg in [
            ArrayConfig::new(1, 8, 2),
            ArrayConfig::new(1, 32, 2),
            ArrayConfig::new(4, 32, 4),
        ] {
            let mut sys = BinArraySystem::new(cfg, net.clone()).unwrap();
            let (_, stats) = sys.run_frame(&img).unwrap();
            cycles.push(stats.cycles);
        }
        assert!(cycles[0] > cycles[1], "{cycles:?}");
        assert!(cycles[1] >= cycles[2], "{cycles:?}");
    }

    #[test]
    fn mode_switch_trades_cycles_for_levels() {
        // M=4 net on M_arch=2 hardware: high-accuracy (2 passes) vs
        // high-throughput (1 pass) — §IV-D.
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 4);
        let img = image(&mut rng);
        let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net.clone()).unwrap();
        let (logits_full, s_full) = sys.run_frame(&img).unwrap();
        sys.set_mode(Some(2));
        let (logits_fast, s_fast) = sys.run_frame(&img).unwrap();
        assert!(s_full.cycles > s_fast.cycles * 3 / 2);
        // and the fast mode equals golden with m_run=2
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        assert_eq!(logits_fast, want);
        let want_full = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(logits_full, want_full);
    }

    #[test]
    fn frame_stats_accounting() {
        let mut rng = Xoshiro256::new(5);
        let net = cnn_a_quant(&mut rng, 2);
        let mut sys = BinArraySystem::new(ArrayConfig::new(1, 8, 2), net).unwrap();
        let (_, stats) = sys.run_frame(&image(&mut rng)).unwrap();
        assert_eq!(stats.layer_cycles.len(), 5);
        let sum: u64 = stats.layer_cycles.iter().sum();
        assert_eq!(stats.cycles, sum + stats.instr_cycles);
        assert!(stats.fps() > 0.0);
        // CNN-A at [1,8,2] should land in the Eq.-18 ballpark (~0.8 M cc)
        assert!(
            (700_000..1_100_000).contains(&stats.cycles),
            "cycles {}",
            stats.cycles
        );
    }

    #[test]
    fn multi_sa_tiling_preserves_outputs() {
        let mut rng = Xoshiro256::new(6);
        let net = cnn_a_quant(&mut rng, 2);
        let img = image(&mut rng);
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        // N_SA=16 with D_arch=8 → layer 0 (D=5) tiles across many SAs
        let mut sys = BinArraySystem::new(ArrayConfig::new(16, 8, 2), net).unwrap();
        let (logits, stats) = sys.run_frame(&img).unwrap();
        assert_eq!(logits, want);
        // tiling must cut layer-0 wall cycles vs a single SA
        assert!(stats.layer_cycles[0] < 42 * 42 * 147 / 2);
    }
}
