//! Activation max-pooling unit + output data gatherer (paper §III-B,
//! Fig. 6, and §IV-A).
//!
//! The AMU receives the QS-quantized output stream of the SA in
//! *channel-first* order (all `D_arch` channels of one conv position,
//! then the next position) and performs fused ReLU + max-pooling with a
//! `D_arch`-deep shift register of running maxima seeded with 0
//! (Eq. 13: `y_0 = 0` makes the running max implement ReLU for free).
//!
//! The ODG assigns row-major feature-buffer addresses to the pooled
//! values, converting the channel-first stream back to `(y, x, c)` layout.

/// Streaming AMU for one pass of `d_arch` channels.
#[derive(Clone, Debug)]
pub struct Amu {
    /// Shift register of intermediate maxima, one per channel.
    sreg: Vec<i8>,
    /// Convolutions seen in the current pooling window.
    seen: usize,
    /// Total convs per pooling window (N_p²; 1 = pooling bypassed).
    np2: usize,
    relu_only: bool,
}

impl Amu {
    /// `np`: pooling factor N_p (≤ 1 = bypass, pure ReLU).  `relu`:
    /// whether the activation applies (dense layers bypass the AMU
    /// entirely).
    ///
    /// `np = 0` is clamped to the bypass geometry: a zero pooling
    /// factor would make `np2 = 0`, a window that *never* completes —
    /// `push`/`push_then` would swallow every value without emitting
    /// and the layer would silently produce nothing (upstream pooled
    /// row/column math divides by `np.max(1)`, so the degenerate case
    /// must behave identically here).
    pub fn new(d_arch: usize, np: usize, relu: bool) -> Self {
        Self {
            sreg: vec![0; d_arch],
            seen: 0,
            np2: np.max(1) * np.max(1),
            relu_only: !relu,
        }
    }

    /// Push the `d_arch` outputs of one conv position (channel-first).
    /// Returns `Some(pooled)` when the pooling window completes.
    pub fn push(&mut self, values: &[i8]) -> Option<Vec<i8>> {
        debug_assert_eq!(values.len(), self.sreg.len());
        debug_assert!(!self.relu_only, "use push_raw for non-activated layers");
        for (m, &v) in self.sreg.iter_mut().zip(values) {
            *m = (*m).max(v); // running max against y_0 = 0 ⇒ ReLU
        }
        self.seen += 1;
        if self.seen == self.np2 {
            let out = std::mem::replace(&mut self.sreg, vec![0; values.len()]);
            self.seen = 0;
            Some(out)
        } else {
            None
        }
    }

    /// Allocation-free variant of [`Self::push`] for the simulator hot
    /// path: when the pooling window completes, `emit` is called with the
    /// pooled vector borrowed from the shift register, which is then
    /// zero-reset in place (no per-window `Vec` churn).
    pub fn push_then<F: FnOnce(&[i8])>(&mut self, values: &[i8], emit: F) {
        debug_assert_eq!(values.len(), self.sreg.len());
        debug_assert!(!self.relu_only, "use push_raw for non-activated layers");
        for (m, &v) in self.sreg.iter_mut().zip(values) {
            *m = (*m).max(v);
        }
        self.seen += 1;
        if self.seen == self.np2 {
            emit(&self.sreg);
            self.sreg.fill(0);
            self.seen = 0;
        }
    }

    /// Bypass path (dense layers / layers without activation): values pass
    /// through unchanged.
    pub fn push_raw(&mut self, values: &[i8]) -> Vec<i8> {
        values.to_vec()
    }
}

/// Output data gatherer: converts the AMU's channel-first pooled stream to
/// row-major `(y, x, c)` addresses in the feature buffer.
#[derive(Clone, Copy, Debug)]
pub struct Odg {
    /// Output feature width (pooled) and channel count of the full layer.
    pub out_w: usize,
    pub out_c: usize,
    /// Base address of the output feature map.
    pub base: usize,
}

impl Odg {
    /// Address of pooled output `(y, x)`, channel `ch`.
    #[inline]
    pub fn addr(&self, y: usize, x: usize, ch: usize) -> usize {
        self.base + (y * self.out_w + x) * self.out_c + ch
    }

    /// Scatter one pooled vector (channels `ch0..ch0+len`) into the buffer.
    pub fn write(&self, buf: &mut [i8], y: usize, x: usize, ch0: usize, vals: &[i8]) {
        for (i, &v) in vals.iter().enumerate() {
            buf[self.addr(y, x, ch0 + i)] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pooling_window_max_and_relu() {
        let mut amu = Amu::new(2, 2, true);
        assert_eq!(amu.push(&[-5, 1]), None);
        assert_eq!(amu.push(&[3, -1]), None);
        assert_eq!(amu.push(&[-7, -9]), None);
        let out = amu.push(&[2, -2]).unwrap();
        assert_eq!(out, vec![3, 1]); // max over window, negatives → relu'd
    }

    #[test]
    fn all_negative_emits_zero() {
        let mut amu = Amu::new(3, 2, true);
        for _ in 0..3 {
            assert!(amu.push(&[-1, -2, -3]).is_none());
        }
        assert_eq!(amu.push(&[-4, -5, -6]).unwrap(), vec![0, 0, 0]);
    }

    #[test]
    fn shift_register_resets_between_windows() {
        let mut amu = Amu::new(1, 1, true); // np=1: emit every push
        assert_eq!(amu.push(&[100]).unwrap(), vec![100]);
        assert_eq!(amu.push(&[-100]).unwrap(), vec![0]); // no leak from 100
    }

    /// The degenerate pool-geometry boundary: `np = 0` must behave as
    /// the `np = 1` bypass, not as a window that never completes.  An
    /// unclamped `np2 = 0` makes `seen == np2` unreachable — every
    /// `push` returns `None`, `push_then` never calls `emit`, and a
    /// worker mid-layer loses the whole output stream with no panic to
    /// point at the cause.
    #[test]
    fn degenerate_pool_geometry_bypasses_instead_of_swallowing() {
        let mut zero = Amu::new(2, 0, true);
        let mut one = Amu::new(2, 1, true);
        for vals in [[7i8, -3], [-1, 5], [0, 0]] {
            let want = one.push(&vals);
            assert!(want.is_some(), "np=1 emits on every push");
            assert_eq!(zero.push(&vals), want, "np=0 behaves as the np=1 bypass");
            let mut got: Option<Vec<i8>> = None;
            let mut z2 = Amu::new(2, 0, true);
            z2.push_then(&vals, |pooled| got = Some(pooled.to_vec()));
            assert_eq!(got.as_deref(), Some(&[vals[0].max(0), vals[1].max(0)][..]));
        }
    }

    #[test]
    fn push_then_equals_push() {
        prop::check(50, "push_then == push", |rng| {
            let d = 1 + rng.below(6) as usize;
            let np = 1 + rng.below(3) as usize;
            let mut a = Amu::new(d, np, true);
            let mut b = Amu::new(d, np, true);
            for _ in 0..np * np * 3 {
                let vals = prop::i8_vec(rng, d);
                let want = a.push(&vals);
                let mut got: Option<Vec<i8>> = None;
                b.push_then(&vals, |pooled| got = Some(pooled.to_vec()));
                assert_eq!(got, want);
            }
        });
    }

    #[test]
    fn matches_naive_relu_maxpool() {
        prop::check(100, "streaming AMU == relu∘max", |rng| {
            let d = 1 + rng.below(8) as usize;
            let np = 1 + rng.below(3) as usize;
            let mut amu = Amu::new(d, np, true);
            let windows = 1 + rng.below(5) as usize;
            for _ in 0..windows {
                let vals: Vec<Vec<i8>> =
                    (0..np * np).map(|_| prop::i8_vec(rng, d)).collect();
                let mut out = None;
                for v in &vals {
                    out = amu.push(v);
                }
                let got = out.expect("window must complete");
                for ch in 0..d {
                    let want = vals.iter().map(|v| v[ch]).max().unwrap().max(0);
                    assert_eq!(got[ch], want);
                }
            }
        });
    }

    #[test]
    fn odg_row_major_addresses() {
        let odg = Odg {
            out_w: 4,
            out_c: 3,
            base: 100,
        };
        assert_eq!(odg.addr(0, 0, 0), 100);
        assert_eq!(odg.addr(0, 1, 0), 103);
        assert_eq!(odg.addr(1, 0, 2), 100 + 4 * 3 + 2);
        let mut buf = vec![0i8; 200];
        odg.write(&mut buf, 1, 2, 1, &[7, 8]);
        assert_eq!(buf[odg.addr(1, 2, 1)], 7);
        assert_eq!(buf[odg.addr(1, 2, 2)], 8);
    }
}
