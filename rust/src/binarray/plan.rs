//! Compile-time execution planning — the "plan" half of the plan/execute
//! split (mirroring FINN's static-dataflow idea: a fixed network compiles
//! to a fixed schedule that is executed once per frame, never re-derived).
//!
//! [`ExecutionPlan::new`] walks the compiled [`Program`] once per accuracy
//! mode and materializes, for every layer:
//!
//! * the work-unit assignment over logical SAs (Eqs. 15–17: level-group
//!   parallelism, channel-pass distribution, pooled-row input tiling);
//! * the sequential level-group count `seq_m` each physical SA performs;
//! * the ping-pong feature-buffer bindings and tile geometry the executor
//!   needs to claim zero-copy views.
//!
//! The per-frame executor ([`super::system`]) is then a thin walk over
//! this structure: no scheduling arithmetic, no shape inference and no
//! feature-map copies happen on the frame path.

use std::ops::Range;
use std::sync::Arc;

use crate::artifacts::{LayerKind, PackedPlanes, QuantNetwork};
use crate::isa::Program;
use crate::tensor::Shape;

use super::ArrayConfig;

/// One unit of schedulable work for a layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkUnit {
    /// Pooled-output row range (conv) — full range for dense.
    pub rows: Range<usize>,
    /// Output-channel range.
    pub d: Range<usize>,
}

/// Everything the executor needs to run one layer of one accuracy mode.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    /// Index into `QuantNetwork::layers`.
    pub layer: usize,
    pub kind: LayerKind,
    /// Feature-buffer base/length of the input region.
    pub in_base: usize,
    pub in_len: usize,
    /// Feature-buffer base/length of the output region.
    pub out_base: usize,
    pub out_len: usize,
    /// Input geometry (HWC; `(1, N_c, 1)` for dense).
    pub in_shape: Shape,
    /// Output geometry after pooling (HWC; `(1, 1, D)` for dense).
    pub out_shape: Shape,
    /// Effective binary levels this mode evaluates on this layer.
    pub m_run: usize,
    /// Sequential level-group passes per physical SA.
    pub seq_m: u64,
    /// Whether host-threading this layer pays for its thread spawns
    /// (decided once here from the layer's PE-op estimate, so the tiny
    /// tail dense layers don't spawn threads per frame).
    pub host_par: bool,
    /// Work units per logical SA (index = logical SA id; empty groups are
    /// legal and idle).
    pub assignments: Vec<Vec<WorkUnit>>,
    /// Tile claims of all units in group-major order, precomputed at plan
    /// build so the frame path allocates nothing to claim its views.
    claims: Vec<(Range<usize>, Range<usize>)>,
}

impl LayerPlan {
    /// Tile claims of all units in group-major order — the executor feeds
    /// these straight into [`crate::tensor::FeatureMapTiles::claim_all`].
    pub fn claims(&self) -> &[(Range<usize>, Range<usize>)] {
        &self.claims
    }
}

/// Group-major `(rows, channels)` claims of a layer's work units.
fn unit_claims(assignments: &[Vec<WorkUnit>]) -> Vec<(Range<usize>, Range<usize>)> {
    assignments
        .iter()
        .flat_map(|units| units.iter().map(|u| (u.rows.clone(), u.d.clone())))
        .collect()
}

/// The full per-frame schedule for one accuracy mode.
#[derive(Clone, Debug)]
pub struct ModePlan {
    /// The `m_run` this plan was built for (`None` = high accuracy).
    pub m_run: Option<usize>,
    pub layers: Vec<LayerPlan>,
}

/// Precomputed schedules for every runtime accuracy mode.
///
/// Index 0 is the high-accuracy plan (`set_mode(None)`); index `m` is the
/// truncated plan for `set_mode(Some(m))`, `1 ≤ m ≤ max_m`.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    pub cfg: ArrayConfig,
    pub input_shape: Shape,
    pub fbuf_words: usize,
    pub max_m: usize,
    /// Bit-packed sign planes, one entry per network layer in layer
    /// order — the weight view the popcount kernel ([`crate::kernel`])
    /// reads on the execute path.  Packed once here and shared by every
    /// clone of the plan; the scalar planes stay on the layer as the
    /// golden reference.
    pub packed: Arc<Vec<PackedPlanes>>,
    modes: Vec<ModePlan>,
}

impl ExecutionPlan {
    /// Build the plan for every accuracy mode of `net` on `cfg`.
    pub fn new(cfg: ArrayConfig, net: &QuantNetwork, prog: &Program) -> Self {
        let dims = crate::isa::compiler::infer_input_dims(net);
        let max_m = net.max_m();
        let mut modes = Vec::with_capacity(max_m + 1);
        modes.push(mode_plan(cfg, net, prog, None));
        for m in 1..=max_m {
            modes.push(mode_plan(cfg, net, prog, Some(m)));
        }
        let packed: Vec<PackedPlanes> = net.layers.iter().map(PackedPlanes::pack).collect();
        Self {
            cfg,
            input_shape: Shape::new(dims.1, dims.0, dims.2),
            fbuf_words: prog.fbuf_words,
            max_m,
            packed: Arc::new(packed),
            modes,
        }
    }

    /// The plan for a runtime mode; `Some(m)` clamps to `1..=max_m`
    /// (matching the executor's historical `m_run.min(layer.m).max(1)`).
    pub fn mode(&self, m_run: Option<usize>) -> &ModePlan {
        match m_run {
            None => &self.modes[0],
            Some(m) => &self.modes[m.clamp(1, self.max_m)],
        }
    }
}

fn mode_plan(
    cfg: ArrayConfig,
    net: &QuantNetwork,
    prog: &Program,
    m_run: Option<usize>,
) -> ModePlan {
    let layers = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let b = &prog.bindings[i];
            let eff = m_run.unwrap_or(l.m).min(l.m).max(1);
            let (in_shape, out_shape, in_len, out_len, pooled_rows) = match l.kind {
                LayerKind::Conv => {
                    let ins = Shape::new(b.in_dims.1, b.in_dims.0, b.in_dims.2);
                    let outs = Shape::new(b.out_dims.1, b.out_dims.0, b.out_dims.2);
                    (ins, outs, ins.len(), outs.len(), outs.h)
                }
                LayerKind::Dense => {
                    let n_in = l.n_c();
                    (
                        Shape::new(1, n_in, 1),
                        Shape::new(1, 1, l.d),
                        n_in,
                        l.d,
                        1,
                    )
                }
            };
            let (assignments, seq_m) = schedule(cfg, l.d, pooled_rows, eff);
            debug_assert_units_disjoint(&assignments);
            // ~200k i8 MACs is roughly where a layer's compute clears the
            // cost of spawning scoped worker threads on the latency path.
            let work_est = out_len as u64 * l.n_c() as u64 * eff as u64;
            LayerPlan {
                layer: i,
                kind: l.kind,
                in_base: b.in_base,
                in_len,
                out_base: b.out_base,
                out_len,
                in_shape,
                out_shape,
                m_run: eff,
                seq_m,
                host_par: work_est >= 200_000,
                claims: unit_claims(&assignments),
                assignments,
            }
        })
        .collect();
    ModePlan { m_run, layers }
}

/// One card's sub-schedule for one layer: the work units this card
/// executes, still organized by the layer's logical-SA groups (a card is
/// a full BinArray instance — its groups run in parallel on its SAs, so
/// per-card wall cycles stay `max` over groups exactly like a frame's).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CardShard {
    /// Work units per logical-SA group (same group count as the parent
    /// [`LayerPlan::assignments`]; groups may be empty on this card).
    pub assignments: Vec<Vec<WorkUnit>>,
    /// Group-major tile claims of this card's units — feed straight into
    /// [`crate::tensor::FeatureMapTiles::claim_all`].
    claims: Vec<(Range<usize>, Range<usize>)>,
}

impl CardShard {
    pub fn claims(&self) -> &[(Range<usize>, Range<usize>)] {
        &self.claims
    }

    /// Total work units on this card (0 = the card idles this layer).
    pub fn n_units(&self) -> usize {
        self.assignments.iter().map(Vec::len).sum()
    }
}

/// Per-card partition of one layer's schedule.
#[derive(Clone, Debug)]
pub struct LayerShards {
    pub cards: Vec<CardShard>,
}

/// Partition one layer's work units over `n_cards` cards.
///
/// Each unit's pooled-row range is cut into `min(n_cards, rows)` row
/// tiles ([`crate::tensor::tile_ranges`], no halo — pooled-output rows
/// are independent), and tile `j` of the `k`-th unit lands on card
/// `(k + j) % n_cards` — the rotation balances layers whose units are
/// too short to split (dense channel passes, single-row tiles).  Group
/// structure is preserved: a sub-unit stays in its parent's logical-SA
/// group, so `n_cards = 1` reproduces the parent schedule exactly and
/// the unsharded/sharded cycle accounting stays comparable.
pub fn shard_schedule(assignments: &[Vec<WorkUnit>], n_cards: usize) -> Vec<CardShard> {
    let n_cards = n_cards.max(1);
    let n_groups = assignments.len();
    let mut cards: Vec<CardShard> = (0..n_cards)
        .map(|_| CardShard {
            assignments: vec![Vec::new(); n_groups],
            claims: Vec::new(),
        })
        .collect();
    let mut k = 0usize;
    for (g, units) in assignments.iter().enumerate() {
        for u in units {
            let splits = n_cards.min(u.rows.len().max(1));
            for (j, (r0, r1)) in crate::tensor::tile_ranges(u.rows.len().max(1), splits, 0)
                .into_iter()
                .enumerate()
            {
                cards[(k + j) % n_cards].assignments[g].push(WorkUnit {
                    rows: u.rows.start + r0..u.rows.start + r1,
                    d: u.d.clone(),
                });
            }
            k += 1;
        }
    }
    for card in &mut cards {
        card.claims = unit_claims(&card.assignments);
    }
    cards
}

/// Cross-card scatter partition of a whole [`ExecutionPlan`]: per mode,
/// per layer, the per-card disjoint sub-schedules whose union is exactly
/// the layer's schedule.  Built once at coordinator start; the frame path
/// only indexes it.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub n_cards: usize,
    pub max_m: usize,
    /// Index 0 = high accuracy, `m` = truncated mode (as [`ExecutionPlan`]).
    modes: Vec<Vec<LayerShards>>,
}

impl ShardPlan {
    pub fn new(plan: &ExecutionPlan, n_cards: usize) -> Self {
        let n_cards = n_cards.max(1);
        let modes = (0..=plan.max_m)
            .map(|i| {
                let m_run = if i == 0 { None } else { Some(i) };
                plan.mode(m_run)
                    .layers
                    .iter()
                    .map(|lp| LayerShards {
                        cards: shard_schedule(&lp.assignments, n_cards),
                    })
                    .collect()
            })
            .collect();
        Self {
            n_cards,
            max_m: plan.max_m,
            modes,
        }
    }

    /// Per-layer shards of a runtime mode (same clamp as
    /// [`ExecutionPlan::mode`]).
    pub fn mode(&self, m_run: Option<usize>) -> &[LayerShards] {
        match m_run {
            None => &self.modes[0],
            Some(m) => &self.modes[m.clamp(1, self.max_m)],
        }
    }
}

/// [`ShardPlan`]s for every card count `1..=max_cards`, built once at
/// coordinator start.  Hybrid dispatch shards each frame over *however
/// many cards are currently free* — the width is only known at lease
/// time, so the router must be able to pick the matching partition in
/// O(1) instead of re-deriving it on the frame path.
#[derive(Clone, Debug)]
pub struct ShardPlanCache {
    /// Index `c - 1` holds the partition over `c` cards.
    plans: Vec<Arc<ShardPlan>>,
}

impl ShardPlanCache {
    /// Build the partition for every width up to `max_cards` (the worker
    /// pool size — a lease can never be wider than the pool).
    pub fn new(plan: &ExecutionPlan, max_cards: usize) -> Self {
        Self {
            plans: (1..=max_cards.max(1))
                .map(|c| Arc::new(ShardPlan::new(plan, c)))
                .collect(),
        }
    }

    /// Widest partition available (= the pool size the cache was built
    /// for).
    pub fn max_cards(&self) -> usize {
        self.plans.len()
    }

    /// The shared partition for `n` cards, clamped to `1..=max_cards`.
    pub fn cards(&self, n: usize) -> &Arc<ShardPlan> {
        &self.plans[n.clamp(1, self.plans.len()) - 1]
    }
}

/// Scheduling policy (paper §IV-E), factored out of the executor so it
/// runs exactly once per (config, network, mode) instead of once per
/// layer per frame:
///
/// 1. level-group parallelism — `⌈M/M_arch⌉` groups spread over SAs
///    (Eq. 15's logical SAs); leftover groups run sequentially (`seq_m`);
/// 2. channel-pass parallelism — `⌈D/D_arch⌉` passes distributed over
///    logical SAs (Eq. 17);
/// 3. input tiling — when channel passes underfill the logical SAs, the
///    input is tiled along pooled-output rows (Eq. 16, width/height only,
///    never depth — keeps convolutions atomic).
pub fn schedule(
    cfg: ArrayConfig,
    d_out: usize,
    pooled_rows: usize,
    m_run: usize,
) -> (Vec<Vec<WorkUnit>>, u64) {
    let m_groups = m_run.div_ceil(cfg.m_arch);
    let n_lsa = (cfg.n_sa / m_groups).max(1);
    let seq_m = m_groups.div_ceil(cfg.n_sa.min(m_groups)) as u64;

    let d_passes = d_out.div_ceil(cfg.d_arch);
    let mut n_t = (n_lsa / d_passes).max(1);
    n_t = n_t.min(pooled_rows.max(1));
    while n_t > 1 && pooled_rows / n_t < 2 {
        n_t -= 1;
    }

    let mut assignments: Vec<Vec<WorkUnit>> = vec![Vec::new(); n_lsa];
    let row_tiles = crate::tensor::tile_ranges(pooled_rows.max(1), n_t, 0);
    let mut lsa = 0usize;
    for (r0, r1) in row_tiles {
        for dp in 0..d_passes {
            let d0 = dp * cfg.d_arch;
            let d1 = (d0 + cfg.d_arch).min(d_out);
            assignments[lsa].push(WorkUnit {
                rows: r0..r1,
                d: d0..d1,
            });
            lsa = (lsa + 1) % n_lsa;
        }
    }
    (assignments, seq_m)
}

/// Every pair of units of one layer must differ in rows or in channels —
/// the invariant that makes handing each unit its own mutable output tile
/// sound (and lets units run on parallel host threads).
fn debug_assert_units_disjoint(assignments: &[Vec<WorkUnit>]) {
    if cfg!(debug_assertions) {
        let units: Vec<&WorkUnit> = assignments.iter().flatten().collect();
        for (i, a) in units.iter().enumerate() {
            for b in &units[i + 1..] {
                let rows_meet = a.rows.start < b.rows.end && b.rows.start < a.rows.end;
                let d_meet = a.d.start < b.d.end && b.d.start < a.d.end;
                assert!(
                    !(rows_meet && d_meet),
                    "scheduler produced overlapping units {a:?} / {b:?}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::compile_network;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::util::rng::Xoshiro256;

    fn cover(assignments: &[Vec<WorkUnit>], d_out: usize, rows: usize) {
        // every (row, channel) cell is covered by exactly one unit
        let mut seen = vec![0u8; d_out * rows];
        for u in assignments.iter().flatten() {
            for r in u.rows.clone() {
                for d in u.d.clone() {
                    seen[r * d_out + d] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&v| v == 1), "coverage {seen:?}");
    }

    #[test]
    fn schedule_covers_all_output_cells() {
        for (cfg, d, rows, m) in [
            (ArrayConfig::new(1, 8, 2), 5, 21, 2),
            (ArrayConfig::new(4, 32, 4), 150, 3, 4),
            (ArrayConfig::new(16, 8, 2), 5, 21, 2),
            (ArrayConfig::new(4, 32, 4), 340, 1, 4),
            (ArrayConfig::new(1, 8, 2), 43, 1, 6),
        ] {
            let (assignments, seq_m) = schedule(cfg, d, rows, m);
            cover(&assignments, d, rows);
            assert!(seq_m >= 1);
            debug_assert_units_disjoint(&assignments);
        }
    }

    #[test]
    fn seq_m_matches_eq15() {
        // M = 2·M_arch on one SA: both level groups run sequentially.
        let (_, seq) = schedule(ArrayConfig::new(1, 8, 2), 5, 21, 4);
        assert_eq!(seq, 2);
        // four SAs absorb both level groups in parallel.
        let (_, seq) = schedule(ArrayConfig::new(4, 8, 2), 5, 21, 4);
        assert_eq!(seq, 1);
    }

    #[test]
    fn plan_has_one_mode_per_accuracy_level() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 4);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(4, 32, 4), &net, &prog);
        assert_eq!(plan.max_m, 4);
        assert_eq!(plan.mode(None).m_run, None);
        assert_eq!(plan.mode(Some(2)).m_run, Some(2));
        // clamped: Some(9) → Some(max_m), Some(0) → Some(1)
        assert_eq!(plan.mode(Some(9)).m_run, Some(4));
        assert_eq!(plan.mode(Some(0)).m_run, Some(1));
        // high accuracy evaluates every level of every layer
        for lp in &plan.mode(None).layers {
            assert_eq!(lp.m_run, net.layers[lp.layer].m);
        }
    }

    #[test]
    fn plan_packs_every_layer() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(1, 8, 2), &net, &prog);
        assert_eq!(plan.packed.len(), net.layers.len());
        for (pk, layer) in plan.packed.iter().zip(&net.layers) {
            assert!(pk.matches(layer));
        }
        // clones share the packed planes instead of re-packing
        let clone = plan.clone();
        assert!(Arc::ptr_eq(&plan.packed, &clone.packed));
    }

    #[test]
    fn plan_bindings_ping_pong() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(1, 8, 2), &net, &prog);
        let half = plan.fbuf_words / 2;
        for lp in &plan.mode(None).layers {
            // input and output must live in opposite halves
            assert_ne!(lp.in_base < half, lp.out_base < half, "layer {}", lp.layer);
            assert!(lp.in_base + lp.in_len <= plan.fbuf_words);
            assert!(lp.out_base + lp.out_len <= plan.fbuf_words);
        }
        // chained layers hand buffers over
        for w in plan.mode(None).layers.windows(2) {
            assert_eq!(w[0].out_base, w[1].in_base);
        }
    }

    #[test]
    fn one_card_shard_is_the_parent_schedule() {
        for (cfg, d, rows, m) in [
            (ArrayConfig::new(1, 8, 2), 5, 21, 2),
            (ArrayConfig::new(4, 32, 4), 150, 3, 4),
            (ArrayConfig::new(1, 8, 2), 43, 1, 6),
        ] {
            let (assignments, _) = schedule(cfg, d, rows, m);
            let cards = shard_schedule(&assignments, 1);
            assert_eq!(cards.len(), 1);
            assert_eq!(cards[0].assignments, assignments);
            assert_eq!(cards[0].claims(), unit_claims(&assignments).as_slice());
        }
    }

    #[test]
    fn shards_cover_all_output_cells() {
        for n_cards in [1usize, 2, 3, 4, 7] {
            for (cfg, d, rows, m) in [
                (ArrayConfig::new(1, 8, 2), 5, 21, 2),
                (ArrayConfig::new(4, 32, 4), 150, 3, 4),
                (ArrayConfig::new(16, 8, 2), 5, 21, 2),
                (ArrayConfig::new(1, 8, 2), 43, 1, 6),
            ] {
                let (assignments, _) = schedule(cfg, d, rows, m);
                let cards = shard_schedule(&assignments, n_cards);
                assert_eq!(cards.len(), n_cards);
                let flat: Vec<WorkUnit> = cards
                    .iter()
                    .flat_map(|c| c.assignments.iter().flatten().cloned())
                    .collect();
                cover(&[flat], d, rows);
                for c in &cards {
                    assert_eq!(c.claims().len(), c.n_units());
                }
            }
        }
    }

    #[test]
    fn sharding_splits_single_unit_rows() {
        // [1,8,2] layer 0 of CNN-A is ONE unit (21 pooled rows × D=5);
        // the whole point of PerFrame sharding is that this still splits.
        let (assignments, _) = schedule(ArrayConfig::new(1, 8, 2), 5, 21, 2);
        assert_eq!(assignments.iter().flatten().count(), 1);
        let cards = shard_schedule(&assignments, 2);
        assert_eq!(cards[0].n_units(), 1);
        assert_eq!(cards[1].n_units(), 1);
        let a = &cards[0].assignments[0][0];
        let b = &cards[1].assignments[0][0];
        assert_eq!(a.rows.len() + b.rows.len(), 21);
        assert_eq!(a.d, 0..5);
        assert_eq!(b.d, 0..5);
    }

    #[test]
    fn shard_plan_indexes_like_execution_plan() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 4);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(4, 32, 4), &net, &prog);
        let sp = ShardPlan::new(&plan, 3);
        assert_eq!(sp.n_cards, 3);
        for mode in [None, Some(1), Some(4), Some(9), Some(0)] {
            let layers = sp.mode(mode);
            assert_eq!(layers.len(), plan.mode(mode).layers.len());
            for (ls, lp) in layers.iter().zip(&plan.mode(mode).layers) {
                let total: usize = ls.cards.iter().map(CardShard::n_units).sum();
                // at least as many sub-units as parent units, covering all
                assert!(total >= lp.assignments.iter().flatten().count());
                for c in &ls.cards {
                    assert_eq!(c.assignments.len(), lp.assignments.len());
                }
            }
        }
    }

    #[test]
    fn shard_plan_cache_covers_every_width() {
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 2);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(1, 8, 2), &net, &prog);
        let cache = ShardPlanCache::new(&plan, 4);
        assert_eq!(cache.max_cards(), 4);
        for n in 1..=4usize {
            assert_eq!(cache.cards(n).n_cards, n);
        }
        // out-of-range widths clamp instead of panicking (a lease is
        // never wider than the pool, but the lookup must stay total)
        assert_eq!(cache.cards(0).n_cards, 1);
        assert_eq!(cache.cards(9).n_cards, 4);
        // degenerate cache still answers
        let one = ShardPlanCache::new(&plan, 0);
        assert_eq!(one.max_cards(), 1);
        assert_eq!(one.cards(3).n_cards, 1);
    }

    #[test]
    fn claims_match_units() {
        let (assignments, _) = schedule(ArrayConfig::new(4, 32, 4), 150, 3, 4);
        let n_units: usize = assignments.iter().map(Vec::len).sum();
        let claims = unit_claims(&assignments);
        assert_eq!(claims.len(), n_units);
        // group-major order: claims line up with a flattened unit walk
        for (claim, unit) in claims.iter().zip(assignments.iter().flatten()) {
            assert_eq!(claim.0, unit.rows);
            assert_eq!(claim.1, unit.d);
        }
    }
}
