//! Address generator unit (paper §IV-B, Algorithm 3, Figs. 8–9).
//!
//! Convolution anchors are *not* emitted in raster order: because the AMU
//! downsamples the output stream directly, all convolutions whose outputs
//! fall into the same pooling window must be produced consecutively.  The
//! AGU therefore walks: conv anchor → across the pooling window (case 1),
//! down within the pooling window (case 2), pooling window right (case 3),
//! pooling window down (case 4) — maintaining anchor addresses with
//! additions only (no multipliers in the RTL).
//!
//! This implementation keeps both the output coordinates and the
//! incrementally maintained byte addresses; a debug assertion checks the
//! add-only address against the multiplicative closed form, which is the
//! property the paper's Algorithm 3 exists to guarantee.

/// One convolution anchor emitted by the AGU.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Anchor {
    /// Conv output coordinates (row, col) = (u, v).
    pub u: usize,
    pub v: usize,
    /// Input-feature anchor address (row-major, channel-minor).
    pub addr: usize,
    /// True for the last conv of its pooling window (AMU emits after it).
    pub last_in_pool: bool,
}

/// AGU for convolutional layers.
///
/// `w_i`: input width, `c_i`: input channels, `stride`: S,
/// `u_out`/`v_out`: conv output dims, `h_p`/`w_p`: pooling window.
/// For layers without pooling pass `h_p = w_p = 1` (raster order results).
#[derive(Clone, Debug)]
pub struct Agu {
    w_i: usize,
    c_i: usize,
    stride: usize,
    u_out: usize,
    v_out: usize,
    h_p: usize,
    w_p: usize,
    // paper state: indexes within the pooling window + anchors
    p_w: usize,
    p_h: usize,
    pool_u: usize,
    pool_v: usize,
    /// a_cv — current conv anchor address (add-only maintenance).
    a_cv: usize,
    /// a_cl — first address of the current row in the current pool window.
    a_cl: usize,
    /// a_po — start address of the current pooling window.
    a_po: usize,
    done: bool,
}

impl Agu {
    pub fn new(
        w_i: usize,
        c_i: usize,
        stride: usize,
        u_out: usize,
        v_out: usize,
        h_p: usize,
        w_p: usize,
    ) -> Self {
        assert!(u_out % h_p == 0 && v_out % w_p == 0,
            "AGU requires pooling to tile the conv output exactly ({u_out}x{v_out} vs {h_p}x{w_p})");
        Self {
            w_i,
            c_i,
            stride,
            u_out,
            v_out,
            h_p,
            w_p,
            p_w: 0,
            p_h: 0,
            pool_u: 0,
            pool_v: 0,
            a_cv: 0,
            a_cl: 0,
            a_po: 0,
            done: u_out == 0 || v_out == 0,
        }
    }

    /// Closed-form anchor address (for the debug cross-check only).
    fn addr_of(&self, u: usize, v: usize) -> usize {
        (u * self.stride * self.w_i + v * self.stride) * self.c_i
    }
}

impl Iterator for Agu {
    type Item = Anchor;

    fn next(&mut self) -> Option<Anchor> {
        if self.done {
            return None;
        }
        let u = self.pool_u * self.h_p + self.p_h;
        let v = self.pool_v * self.w_p + self.p_w;
        debug_assert_eq!(
            self.a_cv,
            self.addr_of(u, v),
            "add-only AGU address diverged at ({u},{v})"
        );
        let last_in_pool = self.p_w + 1 == self.w_p && self.p_h + 1 == self.h_p;
        let anchor = Anchor {
            u,
            v,
            addr: self.a_cv,
            last_in_pool,
        };

        // Algorithm 3's four cases, add-only address updates.
        let sc = self.stride * self.c_i; // one conv step right
        let row = self.stride * self.w_i * self.c_i; // one conv step down
        if self.p_w + 1 < self.w_p {
            // case 1: move conv to next column within the pooling window
            self.a_cv += sc;
            self.p_w += 1;
        } else if self.p_h + 1 < self.h_p {
            // case 2: move conv to next row within the pooling window
            self.a_cl = if self.p_h == 0 { self.a_po } else { self.a_cl };
            self.a_cl += row;
            self.a_cv = self.a_cl;
            self.p_h += 1;
            self.p_w = 0;
        } else if (self.pool_v + 1) * self.w_p < self.v_out {
            // case 3: move pooling window right
            self.a_po += self.w_p * sc;
            self.a_cv = self.a_po;
            self.a_cl = self.a_po;
            self.pool_v += 1;
            self.p_w = 0;
            self.p_h = 0;
        } else if (self.pool_u + 1) * self.h_p < self.u_out {
            // case 4: move pooling window down (back to column 0)
            self.a_po += self.h_p * row - self.pool_v * self.w_p * sc;
            self.a_cv = self.a_po;
            self.a_cl = self.a_po;
            self.pool_u += 1;
            self.pool_v = 0;
            self.p_w = 0;
            self.p_h = 0;
        } else {
            self.done = true;
        }
        Some(anchor)
    }
}

/// AGU for dense layers: a simple linear counter over `n_in` features
/// (§IV-B2 — "the AGU implements a simple linear counter").
pub fn dense_addresses(n_in: usize) -> impl Iterator<Item = usize> {
    0..n_in
}

/// Reference enumerator (nested loops, with multiplications) used by tests
/// and by documentation to define the required ordering.
pub fn reference_order(
    u_out: usize,
    v_out: usize,
    h_p: usize,
    w_p: usize,
) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(u_out * v_out);
    for pu in 0..u_out / h_p {
        for pv in 0..v_out / w_p {
            for ph in 0..h_p {
                for pw in 0..w_p {
                    out.push((pu * h_p + ph, pv * w_p + pw));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matches_reference_order_fig8() {
        // Fig. 8 scenario: 3×3 conv over a feature map with 2×2 pooling.
        let agu = Agu::new(8, 1, 1, 6, 6, 2, 2);
        let got: Vec<(usize, usize)> = agu.map(|a| (a.u, a.v)).collect();
        assert_eq!(got, reference_order(6, 6, 2, 2));
        // The first four anchors form the first pooling window.
        assert_eq!(&got[..4], &[(0, 0), (0, 1), (1, 0), (1, 1)]);
    }

    #[test]
    fn property_matches_reference() {
        prop::check(150, "AGU order == reference for all geometries", |rng| {
            let h_p = 1 + rng.below(3) as usize;
            let w_p = 1 + rng.below(3) as usize;
            let u_out = h_p * (1 + rng.below(6) as usize);
            let v_out = w_p * (1 + rng.below(6) as usize);
            let stride = 1 + rng.below(2) as usize;
            let c = 1 + rng.below(4) as usize;
            let kw = 1 + rng.below(3) as usize;
            let w_i = (v_out - 1) * stride + kw;
            let agu = Agu::new(w_i, c, stride, u_out, v_out, h_p, w_p);
            let got: Vec<Anchor> = agu.collect();
            let want = reference_order(u_out, v_out, h_p, w_p);
            assert_eq!(got.len(), want.len());
            for (a, (u, v)) in got.iter().zip(&want) {
                assert_eq!((a.u, a.v), (*u, *v));
                assert_eq!(a.addr, (u * stride * w_i + v * stride) * c);
            }
        });
    }

    #[test]
    fn last_in_pool_marks_exactly_every_np2() {
        let agu = Agu::new(10, 3, 1, 6, 6, 2, 2);
        let flags: Vec<bool> = agu.map(|a| a.last_in_pool).collect();
        assert_eq!(flags.len(), 36);
        for (i, f) in flags.iter().enumerate() {
            assert_eq!(*f, i % 4 == 3, "index {i}");
        }
    }

    #[test]
    fn no_pooling_is_raster_order() {
        let agu = Agu::new(5, 1, 1, 3, 3, 1, 1);
        let got: Vec<(usize, usize)> = agu.map(|a| (a.u, a.v)).collect();
        let want: Vec<(usize, usize)> =
            (0..3).flat_map(|u| (0..3).map(move |v| (u, v))).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn anchor_count_total() {
        let agu = Agu::new(48, 3, 1, 42, 42, 2, 2);
        assert_eq!(agu.count(), 42 * 42);
    }

    #[test]
    fn dense_counter() {
        let addrs: Vec<usize> = dense_addresses(5).collect();
        assert_eq!(addrs, vec![0, 1, 2, 3, 4]);
    }
}
