//! Cycle-accurate simulator of the BinArray accelerator (paper §III–IV).
//!
//! This is the environment's substitute for the paper's VHDL RTL on the
//! Zynq XC7Z045 (see DESIGN.md §Substitutions): every architectural block
//! is modelled structurally with the RTL's arithmetic, and a cycle counter
//! follows the paper's timing contract:
//!
//! * each PE performs one sign-controlled accumulation per clock cycle —
//!   streaming one `N_c`-element window through a PA costs `N_c` cc
//!   (§IV-E paradigm 1: the α-multiplies overlap with accumulation and
//!   cost latency, not throughput);
//! * the staggered output serialization adds a `D_arch + PIPE_DEPTH`
//!   drain at the end of each pass (visible in the Fig. 5 trace and in
//!   the −1.1‰-class analytical-vs-simulated discrepancy of §V-A3);
//! * the control unit spends one cycle per instruction (§IV-C: CU does
//!   not pipeline; STI setup is negligible vs layer processing);
//! * multi-pass operation per Eqs. 15–17: `⌈M/M_arch⌉` passes for
//!   high-accuracy mode, `⌈D/(D_arch·N_LSA)⌉` passes when output
//!   channels exceed the array, input tiling when `D < D_arch·N_SA`.
//!
//! Module layout mirrors the block diagram (Figs. 3, 4, 6, 7, 10):
//! [`pe`] → [`agu`] → [`amu`] → [`sa`] → [`cu`] → [`system`], with
//! [`plan`] holding the compile-time schedules the executor walks (the
//! plan/execute split: schedules, buffer bindings and tile geometry are
//! derived once per (network, config, mode), never per frame).

pub mod agu;
pub mod amu;
pub mod cu;
pub mod pe;
pub mod plan;
pub mod sa;
pub mod system;

pub use cu::ControlUnit;
pub use plan::{
    CardShard, ExecutionPlan, LayerPlan, LayerShards, ModePlan, ShardPlan, ShardPlanCache,
    WorkUnit,
};
pub use sa::{SaEngine, SimStats, TileScratch};
pub use system::{BinArraySystem, FrameExecutor, FrameStats, ShardRun, ShardTile};

/// Pipeline registers between PA output, barrel shifter, QS and AMU —
/// the depth that makes VHDL simulation slightly slower than Eq. 18.
pub const PIPE_DEPTH: u64 = 4;

/// The three configurable design parameters of BinArray (paper Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArrayConfig {
    /// Number of parallel systolic arrays (throughput).
    pub n_sa: usize,
    /// PEs per PA = output channels in parallel (throughput).
    pub d_arch: usize,
    /// PAs per SA = binary tensors in parallel (throughput/accuracy).
    pub m_arch: usize,
}

impl ArrayConfig {
    pub const fn new(n_sa: usize, d_arch: usize, m_arch: usize) -> Self {
        Self {
            n_sa,
            d_arch,
            m_arch,
        }
    }

    /// `BinArray[N_SA, D_arch, M_arch]` display form used by the paper.
    pub fn label(&self) -> String {
        format!("[{},{},{}]", self.n_sa, self.d_arch, self.m_arch)
    }

    /// Logical SAs for a network approximated with `m` levels (Eq. 15):
    /// `N_LSA = N_SA / ⌈M / M_arch⌉`, saturating at ≥ 1 pass groups.
    pub fn logical_sas(&self, m: usize) -> f64 {
        self.n_sa as f64 / (m as f64 / self.m_arch as f64).ceil()
    }

    /// Number of sequential level-group passes for `m` binary levels.
    pub fn m_passes(&self, m: usize) -> usize {
        m.div_ceil(self.m_arch)
    }
}

/// Paper configurations used throughout the evaluation section.
pub const PAPER_CONFIGS: [ArrayConfig; 4] = [
    ArrayConfig::new(1, 8, 2),
    ArrayConfig::new(1, 32, 2),
    ArrayConfig::new(4, 32, 4),
    ArrayConfig::new(16, 32, 4),
];

/// BinArray's clock frequency on the XC7Z045-2 (§V-B2).
pub const CLOCK_HZ: f64 = 400.0e6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_format_matches_paper() {
        assert_eq!(ArrayConfig::new(1, 32, 2).label(), "[1,32,2]");
    }

    #[test]
    fn logical_sas_eq15() {
        let c = ArrayConfig::new(4, 32, 2);
        assert_eq!(c.logical_sas(2), 4.0); // M = M_arch → all SAs logical
        assert_eq!(c.logical_sas(4), 2.0); // M = 2·M_arch → halved
        assert_eq!(c.logical_sas(6), 4.0 / 3.0);
        assert_eq!(ArrayConfig::new(1, 8, 2).logical_sas(4), 0.5);
    }

    #[test]
    fn m_passes() {
        let c = ArrayConfig::new(1, 8, 2);
        assert_eq!(c.m_passes(1), 1);
        assert_eq!(c.m_passes(2), 1);
        assert_eq!(c.m_passes(3), 2);
        assert_eq!(c.m_passes(4), 2);
        assert_eq!(c.m_passes(6), 3);
    }
}
