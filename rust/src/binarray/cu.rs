//! Control unit (paper §IV-C): fetch/decode/execute of the CNN processing
//! program, configuration register file, and the HLT trigger interface
//! used by the CPU side (our coordinator) to synchronize frames.
//!
//! The CU is not pipelined; every instruction costs one clock cycle, and
//! CONV/DENSE stall until the layer completes (their cycle cost is
//! reported by the layer-execution callback).
//!
//! Under the plan/execute split the CU remains the per-frame trigger (so
//! instruction-cycle accounting stays hardware-faithful), but the layer
//! callback no longer derives anything from the register file — it looks
//! the layer's precomputed [`crate::binarray::plan::LayerPlan`] up by the
//! CONV/DENSE immediate.  The register snapshot in [`LayerRun`] is still
//! produced for tests and tooling that inspect the programmed state.

use crate::isa::{flags, Instr, Program, Reg};

/// Snapshot of configuration registers handed to the layer executor.
#[derive(Clone, Copy, Debug)]
pub struct LayerRun {
    /// Layer id (the CONV/DENSE immediate).
    pub layer_id: u32,
    /// True for DENSE.
    pub dense: bool,
    /// Register file contents at issue time.
    pub regs: [u32; Reg::COUNT],
}

impl LayerRun {
    pub fn reg(&self, r: Reg) -> u32 {
        self.regs[r as usize]
    }

    pub fn flag(&self, bit: u32) -> bool {
        self.regs[Reg::Flags as usize] & bit != 0
    }
}

/// Outcome of running the CU until the next halt point.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CuRun {
    /// Instruction-processing cycles (1 per instruction executed).
    pub instr_cycles: u64,
    /// Cycles spent inside CONV/DENSE layer execution.
    pub layer_cycles: u64,
    /// Layers executed this frame.
    pub layers_run: usize,
    /// True if the LAST-flagged layer completed this run.
    pub frame_done: bool,
}

impl CuRun {
    pub fn total_cycles(&self) -> u64 {
        self.instr_cycles + self.layer_cycles
    }
}

/// The control unit state machine.
#[derive(Clone, Debug)]
pub struct ControlUnit {
    regs: [u32; Reg::COUNT],
    pc: usize,
    /// Cumulative cycle counter over the CU's lifetime.
    pub cycles: u64,
}

impl Default for ControlUnit {
    fn default() -> Self {
        Self::new()
    }
}

impl ControlUnit {
    pub fn new() -> Self {
        Self {
            regs: [0; Reg::COUNT],
            pc: 0,
            cycles: 0,
        }
    }

    pub fn reset(&mut self) {
        self.regs = [0; Reg::COUNT];
        self.pc = 0;
    }

    /// Park the CU at `pc` — the PS writes the entry address after loading
    /// a program, so every frame (including the first) starts from the
    /// entry `HLT` in steady state.  Frame executors use this so a frame's
    /// instruction-cycle cost is identical on every execution lane.
    pub fn park_at(&mut self, pc: usize) {
        self.pc = pc;
    }

    /// Run from the current PC until the next `HLT` is *reached* (frame
    /// boundary).  `exec_layer` performs a CONV/DENSE layer and returns
    /// its cycle cost.  The trigger semantics: the caller invokes
    /// `run_frame` once per input image; execution resumes *past* the HLT
    /// the CU is parked on.
    pub fn run_frame<F>(&mut self, prog: &Program, mut exec_layer: F) -> CuRun
    where
        F: FnMut(LayerRun) -> u64,
    {
        let mut run = CuRun::default();
        // One trigger per run_frame call: the first HLT encountered
        // consumes it (resuming execution); the second parks the CU.
        let mut trigger = true;
        let mut guard = 0u64;
        loop {
            guard += 1;
            assert!(
                guard < 1_000_000,
                "CU runaway: no HLT reached within 1M instructions"
            );
            let Some(&ins) = prog.instrs.get(self.pc) else {
                break; // fell off the program: treat as frame end
            };
            match ins {
                Instr::Hlt => {
                    if trigger {
                        trigger = false;
                        run.instr_cycles += 1;
                        self.pc += 1;
                    } else {
                        // park on the HLT; next trigger resumes past it
                        break;
                    }
                }
                Instr::Nop => {
                    run.instr_cycles += 1;
                    self.pc += 1;
                }
                Instr::Sti(reg, imm) => {
                    self.regs[reg as usize] = imm; // zero-extend
                    run.instr_cycles += 1;
                    self.pc += 1;
                }
                Instr::StiH(reg, imm) => {
                    let low_mask = (1u32 << crate::isa::IMM_BITS) - 1;
                    self.regs[reg as usize] = (self.regs[reg as usize] & low_mask)
                        | (imm << crate::isa::IMM_BITS);
                    run.instr_cycles += 1;
                    self.pc += 1;
                }
                Instr::Conv(id) | Instr::Dense(id) => {
                    let dense = matches!(ins, Instr::Dense(_));
                    let lr = LayerRun {
                        layer_id: id,
                        dense,
                        regs: self.regs,
                    };
                    let last = lr.flag(flags::LAST);
                    run.layer_cycles += exec_layer(lr);
                    run.instr_cycles += 1;
                    run.layers_run += 1;
                    self.pc += 1;
                    if last {
                        run.frame_done = true;
                    }
                }
                Instr::Bra(addr) => {
                    run.instr_cycles += 1;
                    self.pc = addr as usize;
                }
            }
        }
        self.cycles += run.total_cycles();
        run
    }

    pub fn pc(&self) -> usize {
        self.pc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::isa::compile_network;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn frame_runs_all_layers_and_parks_on_hlt() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let prog = compile_network(&net);
        let mut cu = ControlUnit::new();
        let mut seen = Vec::new();
        let run = cu.run_frame(&prog, |lr| {
            seen.push((lr.layer_id, lr.dense));
            1000
        });
        assert_eq!(seen.len(), 5);
        assert_eq!(seen[0], (0, false));
        assert_eq!(seen[2], (2, true));
        assert!(run.frame_done);
        assert_eq!(run.layer_cycles, 5000);
        // parked back on the entry HLT via BRA
        assert_eq!(cu.pc(), prog.entry);
        // every instruction costed 1 cc: NOP consumed at frame 0? pc starts
        // at 0 (NOP), steps to HLT... first frame includes the reset NOP.
        assert!(run.instr_cycles as usize >= prog.instrs.len() - 1);
    }

    #[test]
    fn registers_latch_across_layers() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let prog = compile_network(&net);
        let mut cu = ControlUnit::new();
        let mut widths = Vec::new();
        cu.run_frame(&prog, |lr| {
            widths.push(lr.reg(Reg::WIn));
            0
        });
        assert_eq!(widths[0], 48); // Listing 1: layer 1 W_I=48
        assert_eq!(widths[1], 21); // Listing 1: layer 2 W_I=21
    }

    #[test]
    fn second_frame_reuses_program() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 2);
        let prog = compile_network(&net);
        let mut cu = ControlUnit::new();
        let r1 = cu.run_frame(&prog, |_| 10);
        let r2 = cu.run_frame(&prog, |_| 10);
        assert_eq!(r1.layers_run, r2.layers_run);
        // steady-state frames have identical instruction cost
        let r3 = cu.run_frame(&prog, |_| 10);
        assert_eq!(r2.instr_cycles, r3.instr_cycles);
    }

    #[test]
    fn sti_setup_negligible_vs_layers() {
        // §IV-C rationale: STI cycles ≪ layer cycles
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 2);
        let prog = compile_network(&net);
        let mut cu = ControlUnit::new();
        let run = cu.run_frame(&prog, |_| 100_000);
        assert!(run.instr_cycles * 1000 < run.layer_cycles);
    }
}
