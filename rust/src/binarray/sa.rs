//! Systolic-array layer engine (paper §IV-A, Fig. 7).
//!
//! Executes whole layers with the RTL's arithmetic (identical to
//! [`crate::golden`], asserted by tests) while counting clock cycles per
//! the timing contract in [`super`]:
//!
//! * streaming one `N_c`-element window through the PE array costs `N_c`
//!   cycles — α-multiplies and cascades overlap with accumulation;
//! * if `N_c < D_arch` the serialized per-PA DSP becomes the bottleneck
//!   and the window costs `D_arch` cycles (the structural [`super::pe`]
//!   model exhibits exactly this, and depth-wise MobileNet layers hit it);
//! * each (channel-pass × level-group) re-streams the input;
//! * every pass ends with a `D_arch + PIPE_DEPTH` pipeline drain.
//!
//! Since the plan/execute split, the engine is pure compute over borrowed
//! state: inputs arrive as [`FeatureMapView`]s over the ping half of the
//! feature buffer, outputs leave through disjoint [`FeatureMapTileMut`]
//! claims on the pong half (so one layer's work units can run on parallel
//! host threads), and per-window im2col staging lives in a reusable
//! [`TileScratch`] arena instead of per-call allocations.
//!
//! The inner dot products run on one of two host kernels selected by
//! [`SaEngine::kernel`]: the [`crate::golden`] scalar walk (the oracle)
//! or the bit-packed popcount kernel ([`crate::kernel`]) over the
//! [`PackedPlanes`] view the execution plan builds per layer.  The choice
//! never changes logits or simulated cycles — both kernels are
//! bit-identical by construction and by property test.

use std::ops::Range;

use crate::artifacts::{LayerKind, PackedPlanes, QuantLayer};
use crate::fixp;
use crate::kernel::{self, BitPatch, KernelKind};
use crate::tensor::{FeatureMap, FeatureMapTileMut, FeatureMapTiles, FeatureMapView, Shape};

use super::agu::Agu;
use super::amu::Amu;
use super::PIPE_DEPTH;

/// Cycle/occupancy statistics of one simulated unit of work.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Clock cycles consumed.
    pub cycles: u64,
    /// Convolution windows (dot products per channel group) processed.
    pub windows: u64,
    /// Input features streamed into the PE array.
    pub features: u64,
    /// (channel-pass × level-group) passes executed.
    pub passes: u64,
    /// PE sign-accumulate operations actually performed (utilization).
    pub pe_ops: u64,
    /// DSP multiply-add operations (α scaling) performed.
    pub dsp_ops: u64,
}

impl SimStats {
    pub fn add(&mut self, other: SimStats) {
        self.cycles += other.cycles;
        self.windows += other.windows;
        self.features += other.features;
        self.passes += other.passes;
        self.pe_ops += other.pe_ops;
        self.dsp_ops += other.dsp_ops;
    }

    /// PE utilization: useful sign-accumulates / (cycles × PEs available).
    pub fn pe_utilization(&self, d_arch: usize, m_arch: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.pe_ops as f64 / (self.cycles as f64 * (d_arch * m_arch) as f64)
    }
}

/// Reusable per-executor scratch: the im2col patch, its bit-sliced twin
/// for the packed kernel, and the per-pass value staging buffer.  One
/// arena per host worker thread; buffers grow to the layer maximum once
/// and are reused for every window of every frame.
#[derive(Clone, Debug, Default)]
pub struct TileScratch {
    patch: Vec<i8>,
    bits: BitPatch,
    vals: Vec<i8>,
}

/// One systolic array's layer-execution engine.
#[derive(Clone, Copy, Debug)]
pub struct SaEngine {
    pub d_arch: usize,
    pub m_arch: usize,
    /// Host dot-product kernel — a simulation-speed knob only; logits and
    /// cycle accounting are invariant under the choice.
    pub kernel: KernelKind,
}

impl SaEngine {
    /// Engine with the process-default kernel (`BINARRAY_KERNEL`, else
    /// packed).
    pub fn new(d_arch: usize, m_arch: usize) -> Self {
        Self::with_kernel(d_arch, m_arch, KernelKind::from_env())
    }

    /// Engine with an explicit kernel choice, so one process can race
    /// both kernels (benches, exactness tests,
    /// [`crate::binarray::BinArraySystem::set_kernel`]).
    pub fn with_kernel(d_arch: usize, m_arch: usize, kernel: KernelKind) -> Self {
        Self { d_arch, m_arch, kernel }
    }

    /// The packed-plane view the dot products will actually use: the
    /// caller's view when this engine runs the packed kernel, `None`
    /// (→ golden scalar walk) otherwise.
    fn active_packed<'a>(
        &self,
        layer: &QuantLayer,
        packed: Option<&'a PackedPlanes>,
    ) -> Option<&'a PackedPlanes> {
        match self.kernel {
            KernelKind::Packed => {
                if let Some(pk) = packed {
                    debug_assert!(pk.matches(layer), "packed planes do not match layer");
                }
                packed
            }
            KernelKind::Scalar => None,
        }
    }

    /// Clock cost of streaming one window: `max(N_c, D_arch)` — the DSP
    /// serialization bound kicks in for very short windows (§V-A3's
    /// depth-wise caveat).
    #[inline]
    fn window_cost(&self, n_c: usize) -> u64 {
        n_c.max(self.d_arch) as u64
    }

    /// Execute one tile of a convolution layer: pooled-output rows
    /// `pooled_rows` × output channels `d_range`, writing pooled+activated
    /// results through the tile's claimed region.  `m_run ≤ layer.m`
    /// selects the runtime accuracy mode (§IV-D); `seq_m` is the number of
    /// *sequential* level-group passes this physical SA performs (1 when
    /// level groups are spread across parallel SAs per Eq. 15,
    /// `⌈M/M_arch⌉` on a single SA).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_tile(
        &self,
        layer: &QuantLayer,
        packed: Option<&PackedPlanes>,
        input: &FeatureMapView<'_>,
        pooled_rows: Range<usize>,
        d_range: Range<usize>,
        m_run: usize,
        seq_m: u64,
        out: &mut FeatureMapTileMut<'_>,
        scratch: &mut TileScratch,
        stats: &mut SimStats,
    ) {
        assert_eq!(layer.kind, LayerKind::Conv);
        let np = layer.pool.max(1);
        let conv_shape = input
            .shape
            .conv_out(layer.kh, layer.kw, layer.stride, layer.d);
        let (u_out, v_out) = (conv_shape.h, conv_shape.w);
        assert!(u_out % np == 0 && v_out % np == 0, "AMU downsampling only");
        assert_eq!(out.shape().c, layer.d);

        let n_c = layer.n_c();
        let m_run = m_run.min(layer.m).max(1);
        let m_groups = seq_m;
        let d_passes = d_range.len().div_ceil(self.d_arch);
        let packed = self.active_packed(layer, packed);

        // conv rows covered by this tile of pooled rows
        let conv_row0 = pooled_rows.start * np;
        let conv_rows = (pooled_rows.end - pooled_rows.start) * np;
        if conv_rows == 0 {
            return;
        }

        // One AMU per channel pass (the hardware runs passes sequentially;
        // the host walks windows outermost so each im2col patch is
        // extracted once and reused across all D/D_arch passes — same
        // outputs, same cycle accounting, ~20 % less host work).
        let mut amus: Vec<Amu> = (0..d_passes)
            .map(|dp| {
                let d0 = d_range.start + dp * self.d_arch;
                let d1 = (d0 + self.d_arch).min(d_range.end);
                Amu::new(d1 - d0, np, layer.relu)
            })
            .collect();
        // AGU walks this tile's conv rows in pooling order.
        let agu = Agu::new(
            input.shape.w,
            input.shape.c,
            layer.stride,
            conv_rows,
            v_out,
            np,
            np,
        );
        scratch.vals.resize(self.d_arch, 0);
        for anchor in agu {
            // stream the window: N_c features through all M_arch PAs.
            // (anchor.addr is the AGU's add-only address within the tile;
            // patch() re-derives (y, x) for the host-side copy.)
            input.patch(
                (conv_row0 + anchor.u) * layer.stride,
                anchor.v * layer.stride,
                layer.kh,
                layer.kw,
                &mut scratch.patch,
            );
            // Bit-slice the window once; the cost amortizes over every
            // channel pass and level group that re-reads it below.
            if packed.is_some() {
                scratch.bits.pack(&scratch.patch);
            }
            for (dp, amu) in amus.iter_mut().enumerate() {
                let d0 = d_range.start + dp * self.d_arch;
                let d1 = (d0 + self.d_arch).min(d_range.end);
                let chans = d1 - d0;
                stats.windows += 1;
                stats.features += n_c as u64;
                stats.cycles += self.window_cost(n_c) * m_groups;
                stats.pe_ops += (n_c * chans * m_run) as u64;
                stats.dsp_ops += (chans * m_run) as u64;

                for (k, d) in (d0..d1).enumerate() {
                    let acc = match packed {
                        Some(pk) => kernel::binary_dot_packed(layer, pk, d, &scratch.bits, m_run),
                        None => crate::golden::binary_dot(layer, d, &scratch.patch, m_run),
                    };
                    scratch.vals[k] = fixp::qs(acc, layer.shift);
                }
                if layer.relu || np > 1 {
                    let py = pooled_rows.start + anchor.u / np;
                    let px = anchor.v / np;
                    amu.push_then(&scratch.vals[..chans], |pooled| {
                        out.write(py, px, d0, pooled);
                    });
                } else {
                    // no activation, no pooling: direct ODG write
                    let py = pooled_rows.start + anchor.u;
                    out.write(py, anchor.v, d0, &scratch.vals[..chans]);
                }
            }
        }
        stats.passes += d_passes as u64 * m_groups;
        stats.cycles += d_passes as u64 * (self.d_arch as u64 + PIPE_DEPTH) * m_groups;
    }

    /// Execute a dense layer for output neurons `d_range`, writing through
    /// a tile claimed on the `(1, 1, D)` output region.  `seq_m` as in
    /// [`Self::conv_tile`].
    #[allow(clippy::too_many_arguments)]
    pub fn dense_tile(
        &self,
        layer: &QuantLayer,
        packed: Option<&PackedPlanes>,
        input: &[i8],
        d_range: Range<usize>,
        m_run: usize,
        seq_m: u64,
        out: &mut FeatureMapTileMut<'_>,
        scratch: &mut TileScratch,
        stats: &mut SimStats,
    ) {
        assert_eq!(layer.kind, LayerKind::Dense);
        let n_c = layer.n_c();
        assert_eq!(input.len(), n_c);
        let m_run = m_run.min(layer.m).max(1);
        let m_groups = seq_m;
        let d_passes = d_range.len().div_ceil(self.d_arch);
        let packed = self.active_packed(layer, packed);
        // One bit-slice pass covers every channel pass of the layer.
        if packed.is_some() {
            scratch.bits.pack(input);
        }
        scratch.vals.resize(self.d_arch, 0);

        for dp in 0..d_passes {
            let d0 = d_range.start + dp * self.d_arch;
            let d1 = (d0 + self.d_arch).min(d_range.end);
            stats.windows += 1;
            stats.features += n_c as u64;
            stats.cycles += self.window_cost(n_c) * m_groups;
            stats.pe_ops += (n_c * (d1 - d0) * m_run) as u64;
            stats.dsp_ops += ((d1 - d0) * m_run) as u64;
            for (k, d) in (d0..d1).enumerate() {
                let acc = match packed {
                    Some(pk) => kernel::binary_dot_packed(layer, pk, d, &scratch.bits, m_run),
                    None => crate::golden::binary_dot(layer, d, input, m_run),
                };
                let mut v = fixp::qs(acc, layer.shift);
                if layer.relu {
                    v = v.max(0);
                }
                scratch.vals[k] = v;
            }
            out.write(0, 0, d0, &scratch.vals[..d1 - d0]);
            stats.passes += m_groups;
            stats.cycles += (self.d_arch as u64 + PIPE_DEPTH) * m_groups;
        }
    }

    /// Execute one scheduled work unit — the conv/dense dispatch shared by
    /// the in-card frame executor and the cross-card shard entry
    /// ([`crate::binarray::BinArraySystem::run_shard`]).  `rows` is
    /// ignored for dense layers (their output is a single pooled row).
    #[allow(clippy::too_many_arguments)]
    pub fn run_unit(
        &self,
        layer: &QuantLayer,
        packed: Option<&PackedPlanes>,
        input: FeatureMapView<'_>,
        rows: Range<usize>,
        d: Range<usize>,
        m_run: usize,
        seq_m: u64,
        out: &mut FeatureMapTileMut<'_>,
        scratch: &mut TileScratch,
        stats: &mut SimStats,
    ) {
        match layer.kind {
            LayerKind::Conv => {
                self.conv_tile(layer, packed, &input, rows, d, m_run, seq_m, out, scratch, stats)
            }
            LayerKind::Dense => {
                self.dense_tile(layer, packed, input.data, d, m_run, seq_m, out, scratch, stats)
            }
        }
    }

    /// Sequential level-group passes when this SA handles all of `m_run`
    /// alone: `⌈⌈m_run/M_arch⌉⌉`.
    pub fn seq_m(&self, m_run: usize) -> u64 {
        m_run.max(1).div_ceil(self.m_arch) as u64
    }

    /// Convenience: run a conv layer without tiling (single SA).
    pub fn conv_layer(
        &self,
        layer: &QuantLayer,
        input: &FeatureMap,
        m_run: usize,
    ) -> (FeatureMap, SimStats) {
        let np = layer.pool.max(1);
        let conv = input
            .shape
            .conv_out(layer.kh, layer.kw, layer.stride, layer.d);
        let shape = Shape::new(conv.h / np, conv.w / np, layer.d);
        let mut out = FeatureMap::zeros(shape);
        let mut stats = SimStats::default();
        let mut scratch = TileScratch::default();
        let mut tile = FeatureMapTiles::new(shape, &mut out.data)
            .claim_all(&[(0..shape.h, 0..shape.c)])
            .pop()
            .expect("one claim");
        // Standalone entry: pack on the fly when the packed kernel is
        // selected (the planned path reuses `ExecutionPlan::packed`).
        let packed = match self.kernel {
            KernelKind::Packed => Some(PackedPlanes::pack(layer)),
            KernelKind::Scalar => None,
        };
        self.conv_tile(
            layer,
            packed.as_ref(),
            &input.view(),
            0..shape.h,
            0..layer.d,
            m_run,
            self.seq_m(m_run.min(layer.m)),
            &mut tile,
            &mut scratch,
            &mut stats,
        );
        drop(tile);
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::golden;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::util::{prop, rng::Xoshiro256};

    #[test]
    fn conv_matches_golden_model() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let layer = &net.layers[0];
        let input = FeatureMap::from_vec(
            Shape::new(48, 48, 3),
            prop::i8_vec(&mut rng, 48 * 48 * 3),
        );
        let sa = SaEngine::new(8, 2);
        let (got, stats) = sa.conv_layer(layer, &input, 2);
        let conv = golden::conv_layer(layer, &input, 2);
        let want = golden::relu_maxpool(&conv, 2);
        assert_eq!(got, want);
        // Eq. 18 sanity: 42·42·147 feature-stream cycles + drains
        let want_stream = 42 * 42 * 147u64;
        assert!(stats.cycles >= want_stream);
        assert!(stats.cycles < want_stream + 1000, "cycles {}", stats.cycles);
    }

    #[test]
    fn multi_channel_pass_matches_golden() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let layer = &net.layers[1]; // 150 channels → 19 passes at D_arch=8
        let input = FeatureMap::from_vec(
            Shape::new(21, 21, 5),
            prop::i8_vec(&mut rng, 21 * 21 * 5),
        );
        let sa = SaEngine::new(8, 2);
        let (got, stats) = sa.conv_layer(layer, &input, 2);
        let want = golden::relu_maxpool(&golden::conv_layer(layer, &input, 2), 6);
        assert_eq!(got, want);
        let d_passes = 150u64.div_ceil(8);
        assert_eq!(stats.windows, 18 * 18 * d_passes);
    }

    #[test]
    fn m_passes_double_cycles() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 4); // M=4 on M_arch=2 → 2 level groups
        let layer = &net.layers[0];
        let input = FeatureMap::from_vec(
            Shape::new(48, 48, 3),
            prop::i8_vec(&mut rng, 48 * 48 * 3),
        );
        let sa = SaEngine::new(8, 2);
        let (_, s_full) = sa.conv_layer(layer, &input, 4); // high accuracy
        let (_, s_fast) = sa.conv_layer(layer, &input, 2); // high throughput
        let stream = 42 * 42 * 147u64;
        assert!(s_full.cycles >= 2 * stream);
        assert!(s_fast.cycles < 2 * stream);
        assert!(
            s_full.cycles >= 2 * s_fast.cycles - 100,
            "full {} fast {}",
            s_full.cycles,
            s_fast.cycles
        );
    }

    #[test]
    fn dense_matches_golden() {
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 2);
        let layer = &net.layers[2];
        let input = prop::i8_vec(&mut rng, 1350);
        let sa = SaEngine::new(8, 2);
        let shape = Shape::new(1, 1, 340);
        let mut out = vec![0i8; 340];
        let mut stats = SimStats::default();
        let mut scratch = TileScratch::default();
        {
            let mut tile = FeatureMapTiles::new(shape, &mut out)
                .claim_all(&[(0..1, 0..340)])
                .pop()
                .unwrap();
            sa.dense_tile(layer, None, &input, 0..340, 2, 1, &mut tile, &mut scratch, &mut stats);
        }
        let want = golden::dense_layer(layer, &input, 2);
        assert_eq!(out, want);
        // 43 channel passes × 1350 features
        assert_eq!(stats.windows, 340u64.div_ceil(8));
        assert!(stats.cycles >= 43 * 1350);
    }

    #[test]
    fn tiled_conv_equals_untiled() {
        let mut rng = Xoshiro256::new(5);
        let net = cnn_a_quant(&mut rng, 2);
        let layer = &net.layers[0];
        let input = FeatureMap::from_vec(
            Shape::new(48, 48, 3),
            prop::i8_vec(&mut rng, 48 * 48 * 3),
        );
        let sa = SaEngine::new(8, 2);
        let (want, _) = sa.conv_layer(layer, &input, 2);
        // two tiles: pooled rows 0..10 and 10..21
        let mut out = FeatureMap::zeros(want.shape);
        let mut s1 = SimStats::default();
        let mut s2 = SimStats::default();
        let mut scratch = TileScratch::default();
        {
            let shape = want.shape;
            let mut ts = FeatureMapTiles::new(shape, &mut out.data)
                .claim_all(&[(0..10, 0..5), (10..21, 0..5)]);
            let view = input.view();
            sa.conv_tile(layer, None, &view, 0..10, 0..5, 2, 1, &mut ts[0], &mut scratch, &mut s1);
            sa.conv_tile(layer, None, &view, 10..21, 0..5, 2, 1, &mut ts[1], &mut scratch, &mut s2);
        }
        assert_eq!(out, want);
        // tiles split the work
        assert!(s1.cycles < s2.cycles);
    }

    #[test]
    fn kernel_choice_is_invisible_in_outputs_and_cycles() {
        let mut rng = Xoshiro256::new(7);
        let net = cnn_a_quant(&mut rng, 4);
        let input = FeatureMap::from_vec(
            Shape::new(48, 48, 3),
            prop::i8_vec(&mut rng, 48 * 48 * 3),
        );
        let layer = &net.layers[0];
        let scalar = SaEngine::with_kernel(8, 2, KernelKind::Scalar);
        let packed = SaEngine::with_kernel(8, 2, KernelKind::Packed);
        for m_run in [1, 2, 4] {
            let (a, stats_a) = scalar.conv_layer(layer, &input, m_run);
            let (b, stats_b) = packed.conv_layer(layer, &input, m_run);
            assert_eq!(a, b, "m_run {m_run}");
            assert_eq!(stats_a, stats_b, "m_run {m_run}");
        }
    }

    #[test]
    fn packed_dense_tile_matches_scalar_walk() {
        let mut rng = Xoshiro256::new(8);
        let net = cnn_a_quant(&mut rng, 2);
        let layer = &net.layers[2];
        let input = prop::i8_vec(&mut rng, 1350);
        let pk = PackedPlanes::pack(layer);
        let sa = SaEngine::with_kernel(8, 2, KernelKind::Packed);
        let shape = Shape::new(1, 1, 340);
        let mut scalar_out = vec![0i8; 340];
        let mut packed_out = vec![0i8; 340];
        for (out, packed) in [(&mut scalar_out, None), (&mut packed_out, Some(&pk))] {
            let mut stats = SimStats::default();
            let mut scratch = TileScratch::default();
            let mut tile = FeatureMapTiles::new(shape, out)
                .claim_all(&[(0..1, 0..340)])
                .pop()
                .unwrap();
            sa.dense_tile(
                layer,
                packed,
                &input,
                0..340,
                2,
                1,
                &mut tile,
                &mut scratch,
                &mut stats,
            );
        }
        assert_eq!(scalar_out, golden::dense_layer(layer, &input, 2));
        assert_eq!(scalar_out, packed_out);
    }

    #[test]
    fn short_window_hits_dsp_bound() {
        // N_c < D_arch: the DSP serialization dominates (depth-wise case)
        let sa = SaEngine::new(32, 2);
        assert_eq!(sa.window_cost(9), 32);
        assert_eq!(sa.window_cost(147), 147);
    }

    #[test]
    fn utilization_drops_when_channels_underfill() {
        // CNN-A layer 1 has D=5 on D_arch=32: 15% utilization (paper §V-B3)
        let mut rng = Xoshiro256::new(6);
        let net = cnn_a_quant(&mut rng, 2);
        let layer = &net.layers[0];
        let input = FeatureMap::from_vec(
            Shape::new(48, 48, 3),
            prop::i8_vec(&mut rng, 48 * 48 * 3),
        );
        let (_, s8) = SaEngine::new(8, 2).conv_layer(layer, &input, 2);
        let (_, s32) = SaEngine::new(32, 2).conv_layer(layer, &input, 2);
        let u8 = s8.pe_utilization(8, 2);
        let u32 = s32.pe_utilization(32, 2);
        assert!(u8 > 0.5, "D=5 on 8 PEs should be ~62%: {u8}");
        assert!((0.10..0.20).contains(&u32), "D=5 on 32 PEs ≈ 15%: {u32}");
    }
}
