//! Processing element and processing array — structural per-clock model
//! (paper Figs. 3–5).
//!
//! This level ticks cycle by cycle, including the one-cc input-forwarding
//! delay between vertically chained PEs and the serialized DSP output
//! stream — it is what the `fig5_timing` bench traces and what validates
//! the aggregated timing model in [`super::sa`].

use crate::fixp;

/// One processing element (Fig. 3): conditional sign change, adder,
/// accumulation register, output register.
#[derive(Clone, Debug, Default)]
pub struct Pe {
    acc: i32,
    out: i32,
}

impl Pe {
    /// One clock: accumulate `b·x`; if `last` this is the final element of
    /// the window — the result moves to the output register and the
    /// accumulator clears, ready for the next window with no idle cycle.
    #[inline]
    pub fn tick(&mut self, x: i8, b: i8, last: bool) {
        // conditional sign change + add (the only arithmetic in a PE)
        let addend = if b >= 0 { i32::from(x) } else { -i32::from(x) };
        self.acc += addend;
        debug_assert!(fixp::fits_mulw(self.acc), "PE accumulator overflow");
        if last {
            self.out = self.acc;
            self.acc = 0;
        }
    }

    /// The PE output register (partial result `p_m` of Eq. 9).
    pub fn output(&self) -> i32 {
        self.out
    }
}

/// A weight buffer row: the `N_c` binary weights of one output channel for
/// one binary level, stored as packed bits (the BRAM of Fig. 4).
#[derive(Clone, Debug)]
pub struct WeightRow {
    bits: Vec<u64>,
    len: usize,
}

impl WeightRow {
    pub fn from_signs(signs: &[i8]) -> Self {
        let mut bits = vec![0u64; signs.len().div_ceil(64)];
        for (i, &s) in signs.iter().enumerate() {
            if s >= 0 {
                bits[i / 64] |= 1 << (i % 64);
            }
        }
        Self {
            bits,
            len: signs.len(),
        }
    }

    /// Weight bit `i` as ±1.
    #[inline]
    pub fn sign(&self, i: usize) -> i8 {
        debug_assert!(i < self.len);
        if (self.bits[i / 64] >> (i % 64)) & 1 == 1 {
            1
        } else {
            -1
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Storage bits used (for BRAM accounting: N_c bits per channel).
    pub fn storage_bits(&self) -> usize {
        self.len
    }
}

/// Output event of a PA's serialized DSP stream (Fig. 5): the final
/// cascade value `o_{d,m}` for channel `d` at clock `cc`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PaOutput {
    pub cc: u64,
    pub d: usize,
    /// `r_{d,m} + o_{d,m-1}` — this PA's cascade output.
    pub o: i32,
}

/// A processing array (Fig. 4): `D_arch` vertically chained PEs sharing a
/// one-cc-delayed input feature stream, a weight BRAM, an α memory, and a
/// single time-shared DSP multiply-add.
#[derive(Clone, Debug)]
pub struct Pa {
    pes: Vec<Pe>,
    /// Input delay line: `x_delay[d]` holds the feature PE `d` sees next.
    x_delay: Vec<Option<(i8, usize, bool)>>,
    /// Per-channel weight rows for the currently loaded level.
    weights: Vec<WeightRow>,
    /// α_q per channel (this PA computes one binary level `m`).
    alpha: Vec<i8>,
    clock: u64,
    /// Completed window outputs awaiting DSP serialization: (ready_cc, d, p).
    pending: std::collections::VecDeque<(u64, usize, i32)>,
    /// Next cc at which the shared DSP is free.
    dsp_free_at: u64,
}

impl Pa {
    /// Build a PA with `d_arch` PEs. `weights[d]` is channel `d`'s sign row;
    /// `alpha[d]` its scaling factor.
    pub fn new(weights: Vec<WeightRow>, alpha: Vec<i8>) -> Self {
        let d_arch = weights.len();
        assert_eq!(alpha.len(), d_arch);
        Self {
            pes: vec![Pe::default(); d_arch],
            x_delay: vec![None; d_arch],
            weights,
            alpha,
            clock: 0,
            pending: std::collections::VecDeque::new(),
            dsp_free_at: 0,
        }
    }

    pub fn d_arch(&self) -> usize {
        self.pes.len()
    }

    /// Feed one input feature `x` with window-relative index `i`
    /// (`last` marks the window's final element) into PE 0; returns any
    /// DSP outputs that complete this clock.  `cascade_in(d)` supplies
    /// `o_{d,m-1}` from the previous PA (bias β_d for the first PA).
    pub fn tick<F: Fn(usize) -> i32>(
        &mut self,
        x: Option<(i8, usize, bool)>,
        cascade_in: F,
        out: &mut Vec<PaOutput>,
    ) {
        self.clock += 1;
        // Shift the input down the PE chain: PE d sees the feature d cc
        // after PE 0 (input forwarding with one-cc delay, §III-A).
        for d in (1..self.pes.len()).rev() {
            self.x_delay[d] = self.x_delay[d - 1];
        }
        if !self.pes.is_empty() {
            self.x_delay[0] = x;
        }
        for d in 0..self.pes.len() {
            if let Some((xv, i, last)) = self.x_delay[d] {
                let b = self.weights[d].sign(i);
                self.pes[d].tick(xv, b, last);
                if last {
                    // p_{d,m} captured; queue for the serialized DSP.
                    self.pending.push_back((self.clock, d, self.pes[d].output()));
                }
            }
        }
        // The single DSP retires one multiply-add per clock.
        if let Some(&(ready, d, p)) = self.pending.front() {
            let start = self.dsp_free_at.max(ready);
            if self.clock >= start {
                self.pending.pop_front();
                self.dsp_free_at = self.clock + 1;
                let r = p * i32::from(self.alpha[d]); // r_{d,m} = p_{d,m}·α_{d,m}
                out.push(PaOutput {
                    cc: self.clock,
                    d,
                    o: r + cascade_in(d), // Eq. 11 cascade
                });
            }
        }
    }

    /// Drain remaining outputs after the input stream ends.
    pub fn drain<F: Fn(usize) -> i32>(&mut self, cascade_in: F, out: &mut Vec<PaOutput>) {
        while !self.pending.is_empty() || self.x_delay.iter().any(Option::is_some) {
            self.tick(None, &cascade_in, out);
        }
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Xoshiro256};

    #[test]
    fn pe_accumulates_and_clears() {
        let mut pe = Pe::default();
        pe.tick(10, 1, false);
        pe.tick(5, -1, false);
        pe.tick(2, 1, true);
        assert_eq!(pe.output(), 10 - 5 + 2);
        // next window starts clean
        pe.tick(1, 1, true);
        assert_eq!(pe.output(), 1);
    }

    #[test]
    fn weight_row_roundtrip() {
        prop::check(100, "WeightRow stores signs exactly", |rng| {
            let n = 1 + rng.below(200) as usize;
            let signs = prop::sign_vec(rng, n);
            let row = WeightRow::from_signs(&signs);
            assert_eq!(row.len(), n);
            for (i, &s) in signs.iter().enumerate() {
                assert_eq!(row.sign(i), s);
            }
        });
    }

    /// Drive a full window through a PA and compare against naive math.
    fn run_window(
        d_arch: usize,
        signs: &[Vec<i8>],
        alpha: &[i8],
        xs: &[i8],
        bias: &[i32],
    ) -> Vec<(usize, i32)> {
        let rows = signs.iter().map(|s| WeightRow::from_signs(s)).collect();
        let mut pa = Pa::new(rows, alpha.to_vec());
        let mut outs = Vec::new();
        let n = xs.len();
        for (i, &x) in xs.iter().enumerate() {
            pa.tick(Some((x, i, i == n - 1)), |d| bias[d], &mut outs);
        }
        pa.drain(|d| bias[d], &mut outs);
        assert_eq!(outs.len(), d_arch);
        outs.iter().map(|o| (o.d, o.o)).collect()
    }

    #[test]
    fn pa_computes_all_channels() {
        prop::check(60, "PA window == naive dot products", |rng| {
            let d_arch = 1 + rng.below(8) as usize;
            let n = 2 + rng.below(40) as usize;
            let signs: Vec<Vec<i8>> =
                (0..d_arch).map(|_| prop::sign_vec(rng, n)).collect();
            let alpha: Vec<i8> = (0..d_arch).map(|_| rng.range_i64(1, 60) as i8).collect();
            let bias: Vec<i32> = (0..d_arch).map(|_| rng.range_i64(-99, 99) as i32).collect();
            let xs = prop::i8_vec(rng, n);
            let outs = run_window(d_arch, &signs, &alpha, &xs, &bias);
            for (d, o) in outs {
                let p: i32 = xs
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| i32::from(signs[d][i]) * i32::from(x))
                    .sum();
                assert_eq!(o, p * i32::from(alpha[d]) + bias[d], "channel {d}");
            }
        });
    }

    #[test]
    fn pa_outputs_are_staggered_one_cc() {
        // Fig. 5: channels complete in consecutive cycles (serialized DSP
        // + one-cc input forwarding).
        let d_arch = 4;
        let n = 10;
        let signs: Vec<Vec<i8>> = (0..d_arch).map(|_| vec![1i8; n]).collect();
        let rows = signs.iter().map(|s| WeightRow::from_signs(s)).collect();
        let mut pa = Pa::new(rows, vec![1; d_arch]);
        let mut outs = Vec::new();
        for i in 0..n {
            pa.tick(Some((1, i, i == n - 1)), |_| 0, &mut outs);
        }
        pa.drain(|_| 0, &mut outs);
        let ccs: Vec<u64> = outs.iter().map(|o| o.cc).collect();
        for w in ccs.windows(2) {
            assert_eq!(w[1], w[0] + 1, "outputs must be 1 cc apart: {ccs:?}");
        }
        // channel order is 0..D_arch
        let ds: Vec<usize> = outs.iter().map(|o| o.d).collect();
        assert_eq!(ds, vec![0, 1, 2, 3]);
    }

    #[test]
    fn back_to_back_windows_no_idle() {
        // Two consecutive windows of length n ≥ D_arch keep every PE busy;
        // total clocks ≈ 2n + drain.
        let d_arch = 2;
        let n = 6;
        let signs: Vec<Vec<i8>> = (0..d_arch).map(|_| vec![1i8; n]).collect();
        let rows: Vec<WeightRow> = signs.iter().map(|s| WeightRow::from_signs(s)).collect();
        let mut pa = Pa::new(rows, vec![1; d_arch]);
        let mut outs = Vec::new();
        let mut rng = Xoshiro256::new(1);
        let xs1 = prop::i8_vec(&mut rng, n);
        let xs2 = prop::i8_vec(&mut rng, n);
        for (i, &x) in xs1.iter().enumerate() {
            pa.tick(Some((x, i, i == n - 1)), |_| 0, &mut outs);
        }
        for (i, &x) in xs2.iter().enumerate() {
            pa.tick(Some((x, i, i == n - 1)), |_| 0, &mut outs);
        }
        pa.drain(|_| 0, &mut outs);
        assert_eq!(outs.len(), 2 * d_arch);
        let w1: i32 = xs1.iter().map(|&x| i32::from(x)).sum();
        let w2: i32 = xs2.iter().map(|&x| i32::from(x)).sum();
        assert_eq!(outs[0].o, w1);
        assert_eq!(outs[2].o, w2);
        // drain cost is bounded by D_arch + DSP serialization
        assert!(
            pa.clock() <= (2 * n) as u64 + d_arch as u64 + 2,
            "clock {} too high",
            pa.clock()
        );
    }
}
