//! PJRT runtime: load and execute the AOT-lowered JAX graphs.
//!
//! The Python side lowers the float reference model and the Pallas-kernel
//! binary-approximated model to HLO *text* once at build time
//! (`make artifacts`); this module compiles those artifacts on the PJRT
//! CPU client and runs them from Rust.  Python is never on the request
//! path — the executables are self-contained after `compile()`.
//!
//! Used for (a) golden-model cross-checks of the int8 pipeline against the
//! float binary-approximated network, and (b) the `serve_gtsrb` example's
//! float scoring path.
//!
//! The `xla` bindings are not vendored in the offline build environment,
//! so the real implementation is gated behind the `xla` cargo feature;
//! without it this module compiles to an API-compatible stub whose
//! constructor returns an explanatory error (callers such as
//! `serve_gtsrb` already degrade gracefully on `Runtime::cpu()` failure).

#[cfg(feature = "xla")]
mod imp {
    use std::path::Path;

    use anyhow::{Context, Result};

    /// A compiled HLO executable with fixed input geometry.
    pub struct HloModel {
        exe: xla::PjRtLoadedExecutable,
        /// Input shape (batch, h, w, c) the graph was lowered for.
        pub input_dims: Vec<usize>,
    }

    /// Shared PJRT CPU client (one per process).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Ok(Self {
                client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO text artifact and compile it.
        ///
        /// `input_dims`: the example-input geometry the graph was lowered
        /// with (e.g. `[8, 48, 48, 3]` for `cnn_a_pallas_b8.hlo.txt`).
        pub fn load_hlo(&self, path: &Path, input_dims: &[usize]) -> Result<HloModel> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            Ok(HloModel {
                exe,
                input_dims: input_dims.to_vec(),
            })
        }
    }

    impl HloModel {
        /// Run the model on a float batch (row-major NHWC), returning
        /// logits as a flat `Vec<f32>` (batch × classes).
        ///
        /// The graphs are lowered with `return_tuple=True`, so the output
        /// is a 1-tuple literal (see /opt/xla-example/README.md).
        pub fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
            let want: usize = self.input_dims.iter().product();
            anyhow::ensure!(
                batch.len() == want,
                "batch len {} != expected {want}",
                batch.len()
            );
            let dims: Vec<i64> = self.input_dims.iter().map(|&d| d as i64).collect();
            let x = xla::Literal::vec1(batch).reshape(&dims)?;
            let result = self.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Convenience: int8 activations (binary point `f_input`) → float
        /// batch → logits.
        pub fn run_quantized(&self, batch_q: &[i8], f_input: i32) -> Result<Vec<f32>> {
            let scale = 1.0 / (1i64 << f_input) as f32;
            let floats: Vec<f32> = batch_q.iter().map(|&v| f32::from(v) * scale).collect();
            self.run(&floats)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Result};

    /// Stub of the PJRT executable (built without the `xla` feature).
    pub struct HloModel {
        /// Input shape the graph would have been lowered for.
        pub input_dims: Vec<usize>,
    }

    /// Stub of the PJRT CPU client.  [`Runtime::cpu`] fails with an
    /// explanatory error; the rest of the API exists so callers typecheck
    /// identically with and without the feature.
    pub struct Runtime {}

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            bail!(
                "PJRT runtime unavailable: binarray was built without PJRT \
                 support (the `xla` bindings are not vendored in the offline \
                 environment). On a machine that provides them, add the \
                 `xla` bindings to rust/Cargo.toml [dependencies] and \
                 rebuild with `--features xla`."
            )
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn load_hlo(&self, _path: &Path, input_dims: &[usize]) -> Result<HloModel> {
            let _ = input_dims;
            bail!("PJRT runtime unavailable (built without the `xla` feature)")
        }
    }

    impl HloModel {
        pub fn run(&self, _batch: &[f32]) -> Result<Vec<f32>> {
            bail!("PJRT runtime unavailable (built without the `xla` feature)")
        }

        pub fn run_quantized(&self, _batch_q: &[i8], _f_input: i32) -> Result<Vec<f32>> {
            bail!("PJRT runtime unavailable (built without the `xla` feature)")
        }
    }
}

pub use imp::{HloModel, Runtime};

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        crate::artifacts::default_dir()
            .join("cnn_a_float_b1.hlo.txt")
            .exists()
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn float_model_runs_batch1() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let dir = crate::artifacts::default_dir();
        let model = rt
            .load_hlo(&dir.join("cnn_a_float_b1.hlo.txt"), &[1, 48, 48, 3])
            .unwrap();
        let x = vec![0.5f32; 48 * 48 * 3];
        let logits = model.run(&x).unwrap();
        assert_eq!(logits.len(), 43);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pallas_model_runs_and_is_finite() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let dir = crate::artifacts::default_dir();
        let pl = rt
            .load_hlo(&dir.join("cnn_a_pallas_b1.hlo.txt"), &[1, 48, 48, 3])
            .unwrap();
        let calib = crate::artifacts::CalibBatch::load(&dir.join("calib.bin")).ok();
        let x: Vec<f32> = match &calib {
            Some(c) => c
                .image(0)
                .iter()
                .map(|&v| f32::from(v) / (1 << c.f_input) as f32)
                .collect(),
            None => vec![0.5f32; 48 * 48 * 3],
        };
        let logits = pl.run(&x).unwrap();
        assert_eq!(logits.len(), 43);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = Runtime::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("xla"), "{err}");
    }
}
