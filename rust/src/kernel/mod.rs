//! Bit-packed popcount kernels — the host datapath that makes the binary
//! promise pay.
//!
//! The paper's premise is that binary-approximated weights turn
//! convolutions into multiply-free sign-accumulates, and XNORBIN/FINN
//! build exactly that datapath in silicon: packed sign bits, AND/XNOR and
//! popcount reduction.  This module is the host-simulator version of that
//! datapath.  Weights are ±1 signs packed one bit per weight
//! ([`crate::artifacts::PackedPlanes`], built once per layer at plan
//! construction); activations are `i8`, so the kernel uses the bit-serial
//! identity over the activation's 8 two's-complement bit-slices.
//!
//! ## Formulation
//!
//! Pack the activation patch `x` into 8 bit-slices `slice_k` (bit `i` of
//! `slice_k` = bit `k` of `x_i`; slice 7 is the sign bit and carries
//! weight −2⁷).  With `plane` the mask of +1 weights, `S = Σ x_i`, and
//!
//! ```text
//! P = Σ_{k=0}^{6} 2^k · popcount(plane & slice_k)
//!     − 128 · popcount(plane & slice_7)     // = Σ_{w_i = +1} x_i
//! ```
//!
//! the signed dot product is exactly `Σ w_i·x_i = 2P − S`.  Each of the
//! layer's d×m plane dots then costs 8 AND+popcount ops per 64 weights,
//! while the patch pack and `S` are paid once per window and amortize
//! over every channel pass and level group that re-reads it.  Zero-padded
//! tail bits (both sides are padded with zeros past the logical length)
//! contribute nothing to any popcount, so the identity is exact in `i32`
//! with no edge handling on the dot path.
//!
//! ## Dispatch
//!
//! [`plane_dot`] picks a backend once per process via runtime feature
//! detection: AVX2 (nibble-LUT popcount + `movemask` packing), bare
//! `popcnt`, NEON (`vcntq_u8`), or the portable fallback.  The
//! `BINARRAY_KERNEL` env var overrides the default: `scalar` routes the
//! engines back to the [`crate::golden`] oracle walk, `portable` keeps
//! the packed kernel but disables SIMD dispatch, `packed`/`auto` (and
//! unset) select the packed kernel with full dispatch.  Logits and
//! simulated cycles are invariant under every choice — the kernel is a
//! host-speed knob only, property-tested bit-identical to
//! `golden::{signed_dot, binary_dot}` (`tests/kernel_exactness.rs`).

use std::sync::OnceLock;

use crate::artifacts::{PackedPlanes, QuantLayer};
use crate::fixp;

/// Planes and bit-slices are padded to a multiple of this many `u64`
/// words (256 bits) so SIMD dot paths need no tail loop.
pub const LANE_WORDS: usize = 4;

/// Contribution of bit-slice `k` to `P`: two's complement gives bit 7
/// weight −2⁷.
const SLICE_WEIGHT: [i32; 8] = [1, 2, 4, 8, 16, 32, 64, -128];

/// Packed words per plane for a dot length of `n_c` elements:
/// `ceil(n_c / 64)` rounded up to [`LANE_WORDS`].  Shared by the weight
/// packer and the activation slicer so their strides always agree.
pub fn plane_stride(n_c: usize) -> usize {
    n_c.div_ceil(64).div_ceil(LANE_WORDS) * LANE_WORDS
}

/// Which host dot-product kernel the engines use.  Selection never
/// changes logits or simulated cycles — both paths are bit-identical.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Per-element `i8` walk through [`crate::golden::binary_dot`] (the
    /// oracle path, kept as the reference and the `BINARRAY_KERNEL=scalar`
    /// CI leg).
    Scalar,
    /// Bit-packed popcount kernel over [`PackedPlanes`] (this module).
    Packed,
}

impl KernelKind {
    /// Parse a `BINARRAY_KERNEL` value.  `scalar` forces the oracle walk;
    /// `packed`/`auto`/`portable` select the packed kernel (`portable`
    /// additionally pins the [`plane_dot`] backend to the non-SIMD path).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Self::Scalar),
            "packed" | "auto" | "portable" => Some(Self::Packed),
            _ => None,
        }
    }

    /// Resolve a raw `BINARRAY_KERNEL` value.  Unset (`None`) defaults to
    /// `Packed`; an unrecognized value is an error naming the accepted
    /// set.  Pure so the rejection is unit-testable — [`Self::from_env`]
    /// is this plus the env read and the cache.
    pub fn from_env_value(v: Option<&str>) -> Result<Self, String> {
        match v {
            None => Ok(Self::Packed),
            Some(s) => Self::parse(s).ok_or_else(|| {
                format!(
                    "BINARRAY_KERNEL={s:?} is not a recognized kernel \
                     (accepted: scalar | packed | auto | portable)"
                )
            }),
        }
    }

    /// Process-wide default from the `BINARRAY_KERNEL` env var, read once
    /// and cached.  Unset defaults to `Packed`; an unrecognized value
    /// PANICS with the accepted set — a differential or fuzz arm forced
    /// to one kernel must never silently run another (the old fall-back
    /// to `Packed` turned a typo'd `BINARRAY_KERNEL=scaler` CI leg into a
    /// second packed run that "passed" without testing anything).
    pub fn from_env() -> Self {
        static KIND: OnceLock<KernelKind> = OnceLock::new();
        *KIND.get_or_init(|| {
            let v = std::env::var("BINARRAY_KERNEL").ok();
            Self::from_env_value(v.as_deref()).unwrap_or_else(|e| panic!("{e}"))
        })
    }
}

/// The SIMD backend behind [`plane_dot`], detected once per process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Portable,
    #[cfg(target_arch = "x86_64")]
    Popcnt,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

#[allow(unreachable_code)] // per-arch early returns leave dead tails on some targets
fn detect() -> Backend {
    if let Ok(v) = std::env::var("BINARRAY_KERNEL") {
        if v.trim().eq_ignore_ascii_case("portable") {
            return Backend::Portable;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
        if is_x86_feature_detected!("popcnt") {
            return Backend::Popcnt;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Backend::Neon;
    }
    Backend::Portable
}

fn backend() -> Backend {
    static BACKEND: OnceLock<Backend> = OnceLock::new();
    *BACKEND.get_or_init(detect)
}

/// Name of the detected [`plane_dot`] backend (for bench/diagnostic
/// output): `"portable"`, `"popcnt"`, `"avx2"` or `"neon"`.
pub fn backend_name() -> &'static str {
    match backend() {
        Backend::Portable => "portable",
        #[cfg(target_arch = "x86_64")]
        Backend::Popcnt => "popcnt",
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => "avx2",
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => "neon",
    }
}

/// An activation patch packed into 8 two's-complement bit-slices, plus
/// its element sum `S` — everything [`plane_dot`] needs besides the
/// weight plane.  Reused across windows via [`BitPatch::pack`] (it lives
/// in the engine's `TileScratch`), so packing allocates only on growth.
#[derive(Clone, Debug, Default)]
pub struct BitPatch {
    /// Slice-major: slice `k` occupies `slices[k * stride..(k+1) * stride]`.
    slices: Vec<u64>,
    stride: usize,
    len: usize,
    sum: i32,
}

impl BitPatch {
    /// Repack from `x`, zero-padding every slice to [`plane_stride`].
    pub fn pack(&mut self, x: &[i8]) {
        self.len = x.len();
        self.sum = x.iter().map(|&v| i32::from(v)).sum();
        self.stride = plane_stride(x.len());
        self.slices.clear();
        self.slices.resize(8 * self.stride, 0);
        if self.stride == 0 {
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if backend() == Backend::Avx2 {
            // SAFETY: `backend()` returned Avx2, so runtime CPUID detection
            // proved the `avx2` target feature is available on this host —
            // the only contract the `#[target_feature]` fn imposes.
            unsafe { x86::pack_slices_avx2(x, self.stride, &mut self.slices) };
            pack_tail_portable(x, self.stride, &mut self.slices);
            return;
        }
        pack_full_portable(x, self.stride, &mut self.slices);
        pack_tail_portable(x, self.stride, &mut self.slices);
    }

    /// Number of packed activation elements.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Words per slice (matches [`plane_stride`] of [`Self::len`]).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// `S = Σ x_i` of the packed elements.
    pub fn sum(&self) -> i32 {
        self.sum
    }
}

/// Pack all full 64-element groups of `x` via the in-register 8×8 bit
/// transpose.
fn pack_full_portable(x: &[i8], stride: usize, slices: &mut [u64]) {
    for (w, chunk) in x.chunks_exact(64).enumerate() {
        let group = pack_group64(chunk.try_into().expect("64-byte chunk"));
        for (k, &g) in group.iter().enumerate() {
            slices[k * stride + w] = g;
        }
    }
}

/// Pack the trailing partial group (if any) through a zeroed staging
/// buffer, so padding bits are guaranteed zero.
fn pack_tail_portable(x: &[i8], stride: usize, slices: &mut [u64]) {
    let full = x.len() / 64;
    let rem = x.len() % 64;
    if rem == 0 {
        return;
    }
    let mut buf = [0i8; 64];
    buf[..rem].copy_from_slice(&x[full * 64..]);
    let group = pack_group64(&buf);
    for (k, &g) in group.iter().enumerate() {
        slices[k * stride + full] = g;
    }
}

/// Bit-slice one 64-element group: returns `out[k]` = bit `k` of each of
/// the 64 bytes, gathered into one `u64` (element `i` → bit `i`).
fn pack_group64(chunk: &[i8; 64]) -> [u64; 8] {
    let mut out = [0u64; 8];
    for g in 0..8 {
        let mut bytes = [0u8; 8];
        for (i, b) in bytes.iter_mut().enumerate() {
            *b = chunk[g * 8 + i] as u8;
        }
        let t = transpose8(u64::from_le_bytes(bytes));
        // After the transpose, byte k of `t` holds slice-k bits for these
        // 8 elements.
        for (k, o) in out.iter_mut().enumerate() {
            *o |= ((t >> (8 * k)) & 0xFF) << (8 * g);
        }
    }
    out
}

/// 8×8 bit-matrix transpose within a `u64` (Hacker's Delight 7-3): bit
/// `(8r + c)` of the input lands at bit `(8c + r)` of the output.
fn transpose8(mut x: u64) -> u64 {
    let mut t = (x ^ (x >> 7)) & 0x00AA_00AA_00AA_00AA;
    x ^= t ^ (t << 7);
    t = (x ^ (x >> 14)) & 0x0000_CCCC_0000_CCCC;
    x ^= t ^ (t << 14);
    t = (x ^ (x >> 28)) & 0x0000_0000_F0F0_F0F0;
    x ^= t ^ (t << 28);
    x
}

/// `Σ w_i·x_i` with `w ∈ {±1}` given the packed +1 mask and the sliced
/// patch — dispatches to the detected SIMD backend.  `plane` must be
/// exactly `patch.stride()` words ([`PackedPlanes::plane`] guarantees
/// this when both sides were packed for the same length).
#[inline]
pub fn plane_dot(plane: &[u64], patch: &BitPatch) -> i32 {
    // Each `#[target_feature]` fn below is only reached through its own
    // `backend()` arm, and `backend()` returns that variant only after
    // runtime CPUID/auxv detection proved the feature is present — the
    // sole precondition the fns impose (slice-shape invariants are
    // ordinary debug-asserted contracts, same as the portable body's).
    match backend() {
        Backend::Portable => plane_dot_generic(plane, patch),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection proved `popcnt` (see above).
        Backend::Popcnt => unsafe { x86::plane_dot_popcnt(plane, patch) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: detection proved `avx2` (see above).
        Backend::Avx2 => unsafe { x86::plane_dot_avx2(plane, patch) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: detection proved `neon` (see above).
        Backend::Neon => unsafe { arm::plane_dot_neon(plane, patch) },
    }
}

/// [`plane_dot`] pinned to the portable path regardless of the detected
/// backend — lets tests race the fallback against the SIMD dispatch.
pub fn plane_dot_portable(plane: &[u64], patch: &BitPatch) -> i32 {
    plane_dot_generic(plane, patch)
}

/// The 2P − S identity over `count_ones` — the portable kernel body,
/// also the body the `popcnt`-featured wrapper recompiles with hardware
/// popcount enabled.
#[inline(always)]
fn plane_dot_generic(plane: &[u64], patch: &BitPatch) -> i32 {
    let stride = patch.stride;
    debug_assert_eq!(plane.len(), stride);
    let mut pos = 0i32;
    for (k, &w) in SLICE_WEIGHT.iter().enumerate() {
        let slice = &patch.slices[k * stride..(k + 1) * stride];
        let mut c = 0u32;
        for (&a, &b) in plane.iter().zip(slice) {
            c += (a & b).count_ones();
        }
        pos += w * c as i32;
    }
    2 * pos - patch.sum
}

/// Packed-kernel twin of [`crate::golden::binary_dot`]: bias + the α
/// cascade over the first `m_run` levels, each level's PE dot computed
/// by [`plane_dot`].  Bit-identical to the golden walk by construction
/// (property-tested in `tests/kernel_exactness.rs`).
#[inline]
pub fn binary_dot_packed(
    layer: &QuantLayer,
    packed: &PackedPlanes,
    d: usize,
    patch: &BitPatch,
    m_run: usize,
) -> i32 {
    debug_assert!(packed.matches(layer), "packed planes do not match layer geometry");
    debug_assert_eq!(patch.len(), packed.n_c());
    debug_assert_eq!(patch.stride(), packed.stride());
    let mut acc_total: i32 = layer.bias_q[d];
    for m in 0..m_run.min(layer.m) {
        let p = plane_dot(packed.plane(d, m), patch);
        debug_assert!(fixp::fits_mulw(p), "PE accumulator overflow: {p}");
        acc_total += p * i32::from(layer.alpha(d, m));
    }
    acc_total
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use super::{plane_dot_generic, BitPatch, SLICE_WEIGHT};

    /// Same generic body, recompiled with hardware `popcnt` enabled —
    /// the default x86-64 baseline lowers `count_ones` to a SWAR
    /// sequence, so this wrapper matters on AVX2-less hosts.
    #[target_feature(enable = "popcnt")]
    pub(super) unsafe fn plane_dot_popcnt(plane: &[u64], patch: &BitPatch) -> i32 {
        plane_dot_generic(plane, patch)
    }

    /// Nibble-LUT popcount (Muła): per 256-bit lane, table-look-up both
    /// nibbles of every byte and horizontally sum via `sad_epu8`.  The
    /// [`super::plane_stride`] contract (stride % 4 == 0, zero padding)
    /// means no tail loop.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn plane_dot_avx2(plane: &[u64], patch: &BitPatch) -> i32 {
        const NIBBLE_POP: [u8; 32] = {
            let mut t = [0u8; 32];
            let mut i = 0;
            while i < 32 {
                t[i] = (i as u32 & 0xF).count_ones() as u8;
                i += 1;
            }
            t
        };
        let stride = patch.stride;
        debug_assert_eq!(plane.len(), stride);
        debug_assert_eq!(stride % 4, 0);
        // SAFETY: the caller established `avx2` (the fn's only feature
        // precondition).  Every 32-byte load reads 4 `u64`s at offset
        // `j ≤ stride − 4` from slices the `plane_stride` contract sizes
        // to exactly `stride` words (zero-padded, stride % 4 == 0), and
        // `loadu` has no alignment requirement.
        unsafe {
            let lut = _mm256_loadu_si256(NIBBLE_POP.as_ptr().cast::<__m256i>());
            let low = _mm256_set1_epi8(0x0F);
            let zero = _mm256_setzero_si256();
            let mut pos = 0i64;
            for (k, &w) in SLICE_WEIGHT.iter().enumerate() {
                let slice = &patch.slices[k * stride..(k + 1) * stride];
                let mut acc = zero;
                for j in (0..stride).step_by(4) {
                    let a = _mm256_loadu_si256(plane.as_ptr().add(j).cast::<__m256i>());
                    let b = _mm256_loadu_si256(slice.as_ptr().add(j).cast::<__m256i>());
                    let v = _mm256_and_si256(a, b);
                    let lo = _mm256_and_si256(v, low);
                    let hi = _mm256_and_si256(_mm256_srli_epi16(v, 4), low);
                    let cnt = _mm256_add_epi8(
                        _mm256_shuffle_epi8(lut, lo),
                        _mm256_shuffle_epi8(lut, hi),
                    );
                    acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
                }
                let c = _mm256_extract_epi64(acc, 0)
                    + _mm256_extract_epi64(acc, 1)
                    + _mm256_extract_epi64(acc, 2)
                    + _mm256_extract_epi64(acc, 3);
                pos += i64::from(w) * c;
            }
            (2 * pos - i64::from(patch.sum)) as i32
        }
    }

    /// Bit-slice all full 64-element groups of `x` with `movemask`:
    /// each pass extracts every byte's MSB (slice 7 first), then a
    /// byte-wise self-add shifts the next bit into MSB position.  The
    /// tail group (if any) is left to the portable stager.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pack_slices_avx2(x: &[i8], stride: usize, slices: &mut [u64]) {
        // SAFETY: the caller established `avx2`.  Each iteration loads
        // the two unaligned 32-byte halves of the 64-byte group at
        // `x[w * 64..]` with `w < x.len() / 64`, so both loads stay in
        // bounds; the `slices` writes are ordinary checked indexing.
        unsafe {
            for w in 0..x.len() / 64 {
                let p = x.as_ptr().add(w * 64).cast::<__m256i>();
                let mut lo = _mm256_loadu_si256(p);
                let mut hi = _mm256_loadu_si256(p.add(1));
                for k in (0..8).rev() {
                    let mlo = _mm256_movemask_epi8(lo) as u32 as u64;
                    let mhi = _mm256_movemask_epi8(hi) as u32 as u64;
                    slices[k * stride + w] = (mhi << 32) | mlo;
                    if k > 0 {
                        lo = _mm256_add_epi8(lo, lo);
                        hi = _mm256_add_epi8(hi, hi);
                    }
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    use super::{BitPatch, SLICE_WEIGHT};

    /// NEON popcount path: `vcntq_u8` counts per byte, `vaddlvq_u8`
    /// horizontally sums a 128-bit lane.  Stride is a multiple of
    /// [`super::LANE_WORDS`] = 4, so the 2-word chunks cover everything.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn plane_dot_neon(plane: &[u64], patch: &BitPatch) -> i32 {
        let stride = patch.stride;
        debug_assert_eq!(plane.len(), stride);
        debug_assert_eq!(stride % 2, 0);
        // SAFETY: the caller established `neon`.  Each 16-byte load
        // reads 2 `u64`s at offset `j ≤ stride − 2` from slices the
        // `plane_stride` contract sizes to exactly `stride` words
        // (stride is a multiple of LANE_WORDS = 4, hence of 2).
        unsafe {
            let mut pos = 0i32;
            for (k, &w) in SLICE_WEIGHT.iter().enumerate() {
                let slice = &patch.slices[k * stride..(k + 1) * stride];
                let mut c = 0u32;
                for j in (0..stride).step_by(2) {
                    let a = vld1q_u8(plane.as_ptr().add(j).cast::<u8>());
                    let b = vld1q_u8(slice.as_ptr().add(j).cast::<u8>());
                    c += u32::from(vaddlvq_u8(vcntq_u8(vandq_u8(a, b))));
                }
                pos += w * c as i32;
            }
            2 * pos - patch.sum
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn transpose8_is_a_bit_transpose() {
        let mut rng = Xoshiro256::new(1);
        for _ in 0..64 {
            let x = rng.next_u64();
            let t = transpose8(x);
            for r in 0..8 {
                for c in 0..8 {
                    assert_eq!((t >> (8 * c + r)) & 1, (x >> (8 * r + c)) & 1);
                }
            }
        }
    }

    #[test]
    fn bit_slices_match_twos_complement_bits() {
        let mut rng = Xoshiro256::new(2);
        let mut patch = BitPatch::default();
        for n in [0usize, 1, 7, 63, 64, 65, 130, 147, 256, 340] {
            let x = prop::i8_vec(&mut rng, n);
            patch.pack(&x);
            assert_eq!(patch.len(), n);
            assert_eq!(patch.stride(), plane_stride(n));
            assert_eq!(patch.sum(), x.iter().map(|&v| i32::from(v)).sum::<i32>());
            for (i, &v) in x.iter().enumerate() {
                let byte = v as u8;
                for k in 0..8 {
                    let word = patch.slices[k * patch.stride + i / 64];
                    let want = u64::from((byte >> k) & 1);
                    assert_eq!((word >> (i % 64)) & 1, want, "n={n} i={i} k={k}");
                }
            }
            // Padding — tail bits and alignment words — must stay zero.
            for k in 0..8 {
                let slice = &patch.slices[k * patch.stride..(k + 1) * patch.stride];
                let mut mask = vec![0u64; patch.stride];
                for i in 0..n {
                    mask[i / 64] |= 1u64 << (i % 64);
                }
                for (j, &word) in slice.iter().enumerate() {
                    assert_eq!(word & !mask[j], 0, "n={n} k={k} word {j} has padding bits");
                }
            }
        }
    }

    #[test]
    fn plane_dot_matches_signed_dot() {
        let mut rng = Xoshiro256::new(3);
        let mut patch = BitPatch::default();
        for trial in 0..300 {
            let n = rng.below(400) as usize;
            let signs = prop::sign_vec(&mut rng, n);
            let x = prop::i8_vec(&mut rng, n);
            let stride = plane_stride(n);
            let mut plane = vec![0u64; stride];
            for (i, &s) in signs.iter().enumerate() {
                if s > 0 {
                    plane[i / 64] |= 1u64 << (i % 64);
                }
            }
            patch.pack(&x);
            let want = crate::golden::signed_dot(&signs, &x);
            assert_eq!(plane_dot(&plane, &patch), want, "trial {trial} n={n}");
            assert_eq!(plane_dot_portable(&plane, &patch), want, "trial {trial} n={n}");
        }
    }

    #[test]
    fn kernel_kind_parses_env_values() {
        assert_eq!(KernelKind::parse("scalar"), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse("packed"), Some(KernelKind::Packed));
        assert_eq!(KernelKind::parse("auto"), Some(KernelKind::Packed));
        assert_eq!(KernelKind::parse("portable"), Some(KernelKind::Packed));
        assert_eq!(KernelKind::parse(" Scalar "), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse("simd"), None);
        assert_eq!(KernelKind::parse(""), None);
    }

    #[test]
    fn kernel_kind_from_env_value_rejects_unknown() {
        assert_eq!(KernelKind::from_env_value(None), Ok(KernelKind::Packed));
        assert_eq!(
            KernelKind::from_env_value(Some("scalar")),
            Ok(KernelKind::Scalar)
        );
        assert_eq!(
            KernelKind::from_env_value(Some("portable")),
            Ok(KernelKind::Packed)
        );
        // an unknown value is a hard error (from_env panics with it), and
        // the message names both the bad value and the accepted set
        let err = KernelKind::from_env_value(Some("scaler")).unwrap_err();
        assert!(err.contains("scaler"), "{err}");
        assert!(err.contains("scalar | packed | auto | portable"), "{err}");
    }

    #[test]
    fn plane_stride_is_lane_aligned() {
        assert_eq!(plane_stride(0), 0);
        assert_eq!(plane_stride(1), LANE_WORDS);
        assert_eq!(plane_stride(64), LANE_WORDS);
        assert_eq!(plane_stride(64 * LANE_WORDS), LANE_WORDS);
        assert_eq!(plane_stride(64 * LANE_WORDS + 1), 2 * LANE_WORDS);
        assert_eq!(plane_stride(1350), 24);
    }
}
