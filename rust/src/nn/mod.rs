//! Network descriptions: layer specs, the paper's reference networks, and
//! MAC accounting.
//!
//! The performance (Table III) and resource (Table IV) experiments need
//! exact layer *shapes* of the three reference networks:
//!
//! * **CNN-A** — 2 conv + 3 dense on 48×48×3 (GTSRB), ~5.8 M MACs
//! * **CNN-B1** — MobileNetV1 ρ=0.57 (input 128), α=0.5, ≈49 M MACs
//! * **CNN-B2** — MobileNetV1 ρ=1 (input 224), α=1, ≈569 M MACs
//!
//! MobileNet depth-wise layers are flagged so the performance model can
//! apply the paper's §V-A3 rule (D_arch=1 — no output-channel parallelism
//! for depth-wise convolutions).

/// One BinArray-schedulable layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layer {
    /// Standard convolution (valid padding unless `pad > 0`).
    Conv {
        w_in: usize,
        h_in: usize,
        c_in: usize,
        kh: usize,
        kw: usize,
        d_out: usize,
        stride: usize,
        pad: usize,
        /// N_p of the fused max-pool after this conv (1 = none).
        pool: usize,
    },
    /// Depth-wise convolution: one filter per input channel.
    DepthwiseConv {
        w_in: usize,
        h_in: usize,
        c_in: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
    /// Fully-connected layer.
    Dense { n_in: usize, n_out: usize },
    /// Global average pool — offloaded to the CPU in the paper (§V-B3);
    /// carried in the spec so MAC accounting and offload decisions see it.
    GlobalAvgPool { w_in: usize, h_in: usize, c: usize },
}

impl Layer {
    /// Output spatial dims (U, V, D) of Eq. 14 (for layers that have them).
    pub fn out_dims(&self) -> (usize, usize, usize) {
        match *self {
            Layer::Conv {
                w_in,
                h_in,
                kh,
                kw,
                d_out,
                stride,
                pad,
                ..
            } => (
                (h_in - kh + 2 * pad) / stride + 1,
                (w_in - kw + 2 * pad) / stride + 1,
                d_out,
            ),
            Layer::DepthwiseConv {
                w_in,
                h_in,
                c_in,
                kh,
                kw,
                stride,
                pad,
            } => (
                (h_in - kh + 2 * pad) / stride + 1,
                (w_in - kw + 2 * pad) / stride + 1,
                c_in,
            ),
            Layer::Dense { n_out, .. } => (1, 1, n_out),
            Layer::GlobalAvgPool { c, .. } => (1, 1, c),
        }
    }

    /// Multiply-accumulate operations per inference.
    pub fn macs(&self) -> u64 {
        match *self {
            Layer::Conv {
                c_in, kh, kw, d_out, ..
            } => {
                let (u, v, _) = self.out_dims();
                (u * v * kh * kw * c_in * d_out) as u64
            }
            Layer::DepthwiseConv {
                c_in, kh, kw, ..
            } => {
                let (u, v, _) = self.out_dims();
                (u * v * kh * kw * c_in) as u64
            }
            Layer::Dense { n_in, n_out } => (n_in * n_out) as u64,
            Layer::GlobalAvgPool { w_in, h_in, c } => (w_in * h_in * c) as u64,
        }
    }

    /// Coefficients per output filter N_c (the binary dot-product length).
    pub fn n_c(&self) -> usize {
        match *self {
            Layer::Conv { c_in, kh, kw, .. } => kh * kw * c_in,
            Layer::DepthwiseConv { kh, kw, .. } => kh * kw,
            Layer::Dense { n_in, .. } => n_in,
            Layer::GlobalAvgPool { .. } => 0,
        }
    }

    /// Number of output filters D (rows of weight storage).
    pub fn d_out(&self) -> usize {
        self.out_dims().2
    }

    pub fn is_depthwise(&self) -> bool {
        matches!(self, Layer::DepthwiseConv { .. })
    }
}

/// A full network: ordered layers + metadata.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// MACs excluding layers the paper offloads to the CPU for MobileNet
    /// (global average pool + the final dense classifier, §V-B3).
    pub fn accelerated_macs(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| !matches!(l, Layer::GlobalAvgPool { .. }))
            .map(Layer::macs)
            .sum()
    }

    /// Total weight coefficients (for compression/BRAM accounting).
    pub fn weight_coeffs(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| (l.n_c() * l.d_out()) as u64)
            .sum()
    }
}

/// CNN-A (paper §V-A1): the GTSRB network, dims per Listing 1.
pub fn cnn_a() -> Network {
    Network {
        name: "CNN-A".into(),
        layers: vec![
            Layer::Conv {
                w_in: 48,
                h_in: 48,
                c_in: 3,
                kh: 7,
                kw: 7,
                d_out: 5,
                stride: 1,
                pad: 0,
                pool: 2,
            },
            Layer::Conv {
                w_in: 21,
                h_in: 21,
                c_in: 5,
                kh: 4,
                kw: 4,
                d_out: 150,
                stride: 1,
                pad: 0,
                pool: 6,
            },
            Layer::Dense {
                n_in: 1350,
                n_out: 340,
            },
            Layer::Dense {
                n_in: 340,
                n_out: 490,
            },
            Layer::Dense {
                n_in: 490,
                n_out: 43,
            },
        ],
    }
}

/// MobileNetV1 (Howard et al. [11]) with width multiplier `alpha` and
/// input resolution `input` (the paper's ρ expressed as pixels).
///
/// Standard topology: conv3×3/2, then 13 depthwise-separable blocks
/// (dw3×3 + pw1×1), global average pool, dense 1024α→1000.
pub fn mobilenet_v1(input: usize, alpha: f64) -> Network {
    let ch = |c: usize| ((c as f64 * alpha).round() as usize).max(1);
    let mut layers = Vec::new();
    let mut hw = input;
    let mut c = 3usize;

    // Initial full conv: 32α filters, stride 2, 'same' padding (pad=1).
    let d0 = ch(32);
    layers.push(Layer::Conv {
        w_in: hw,
        h_in: hw,
        c_in: c,
        kh: 3,
        kw: 3,
        d_out: d0,
        stride: 2,
        pad: 1,
        pool: 1,
    });
    hw = hw.div_ceil(2);
    c = d0;

    // (out_channels, stride) of the 13 separable blocks.
    let blocks: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (d, s) in blocks {
        layers.push(Layer::DepthwiseConv {
            w_in: hw,
            h_in: hw,
            c_in: c,
            kh: 3,
            kw: 3,
            stride: s,
            pad: 1,
        });
        if s == 2 {
            hw = hw.div_ceil(2);
        }
        let dd = ch(d);
        layers.push(Layer::Conv {
            w_in: hw,
            h_in: hw,
            c_in: c,
            kh: 1,
            kw: 1,
            d_out: dd,
            stride: 1,
            pad: 0,
            pool: 1,
        });
        c = dd;
    }

    layers.push(Layer::GlobalAvgPool {
        w_in: hw,
        h_in: hw,
        c,
    });
    layers.push(Layer::Dense {
        n_in: c,
        n_out: 1000,
    });

    Network {
        name: format!("MobileNetV1-{input}-a{alpha}"),
        layers,
    }
}

/// CNN-B1: MobileNetV1 ρ=0.57 (128×128 input), α=0.5 — ≈49 M MACs.
pub fn cnn_b1() -> Network {
    let mut n = mobilenet_v1(128, 0.5);
    n.name = "CNN-B1".into();
    n
}

/// CNN-B2: MobileNetV1 ρ=1 (224×224 input), α=1 — ≈569 M MACs.
pub fn cnn_b2() -> Network {
    let mut n = mobilenet_v1(224, 1.0);
    n.name = "CNN-B2".into();
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnn_a_macs_match_hand_count() {
        let want = 42 * 42 * 7 * 7 * 3 * 5
            + 18 * 18 * 4 * 4 * 5 * 150
            + 1350 * 340
            + 340 * 490
            + 490 * 43;
        assert_eq!(cnn_a().macs(), want as u64);
    }

    #[test]
    fn cnn_a_dense_input_is_1350() {
        let net = cnn_a();
        let Layer::Dense { n_in, .. } = net.layers[2] else {
            panic!("layer 2 should be dense");
        };
        assert_eq!(n_in, 1350);
        // and the conv stack actually produces 1350 features: 3*3*150
        let Layer::Conv { d_out, pool, .. } = net.layers[1] else {
            panic!()
        };
        let (u, _, _) = net.layers[1].out_dims();
        assert_eq!((u / pool) * (u / pool) * d_out, 1350);
    }

    #[test]
    fn cnn_b1_macs_near_paper_49m() {
        let m = cnn_b1().macs();
        // paper: "a total of 49M MACs"
        assert!(
            (40_000_000..60_000_000).contains(&m),
            "CNN-B1 MACs {m} outside 49M±20%"
        );
    }

    #[test]
    fn cnn_b2_macs_near_paper_569m() {
        let m = cnn_b2().macs();
        assert!(
            (500_000_000..640_000_000).contains(&m),
            "CNN-B2 MACs {m} outside 569M±12%"
        );
    }

    #[test]
    fn mobilenet_layer_count() {
        // 1 + 13*2 conv-ish layers + gap + dense
        assert_eq!(cnn_b2().layers.len(), 1 + 26 + 1 + 1);
    }

    #[test]
    fn depthwise_flagging() {
        let net = cnn_b1();
        let dw = net.layers.iter().filter(|l| l.is_depthwise()).count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn out_dims_stride_padding() {
        let l = Layer::Conv {
            w_in: 224,
            h_in: 224,
            c_in: 3,
            kh: 3,
            kw: 3,
            d_out: 32,
            stride: 2,
            pad: 1,
            pool: 1,
        };
        let (u, v, d) = l.out_dims();
        assert_eq!((u, v, d), (112, 112, 32));
    }

    #[test]
    fn n_c_values() {
        let net = cnn_a();
        assert_eq!(net.layers[0].n_c(), 147); // 7*7*3
        assert_eq!(net.layers[1].n_c(), 80); // 4*4*5
        assert_eq!(net.layers[2].n_c(), 1350);
    }
}
