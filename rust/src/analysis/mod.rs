//! Static plan verifier — compile-time proofs over every artifact the
//! serving stack publishes.
//!
//! The datapath is only correct inside hard static envelopes: `i8`
//! activations (DW = 8), a 28-bit DSP accumulate path
//! ([`fixp::MULW`]), `i8` α factors, a bounded QS shift, and a 32-bit
//! ISA with 21-bit immediates.  Until now the only enforcement was a
//! `debug_assert!` in `pe.rs` that vanishes in release builds, and the
//! dynamic racers in [`crate::verify`] that can only *sample* inputs.
//! This module proves the envelopes once per compiled artifact, before
//! a single frame is served:
//!
//! 1. **Fixed-point range analysis** ([`analyze_ranges`]) — abstract
//!    interpretation over per-layer intervals.  Starting from the full
//!    admissible input range `[-128, 127]`, it walks every ±1 weight
//!    plane element by element (the PE's sign-controlled accumulation
//!    order), tracking the *hull of all prefix sums* — exactly the
//!    values the per-tick `debug_assert!(fits_mulw(acc))` in
//!    [`crate::binarray::pe`] samples — then the DSP α product and the
//!    cascade after every binary level (which covers every truncated
//!    `m_run` mode at once, truncations being prefixes of the
//!    cascade).  If every hull stays inside `[MULW_MIN, MULW_MAX]`,
//!    the accumulator provably cannot overflow for *any* admissible
//!    input; otherwise the error carries a concrete witness
//!    (layer, channel, level, bound).  Intervals are computed in
//!    `i64`, so a would-be `i32` overflow is detected, never wrapped.
//!    Layer output ranges are the QS image of the cascade hull
//!    (round/saturate are monotone, so endpoints map to endpoints),
//!    clamped by ReLU / the AMU's zero-seeded max-pool, and become the
//!    next layer's input range.
//! 2. **Schedule linting** ([`lint_plan`], [`lint_shards`],
//!    [`lint_cover`]) — for every accuracy mode and shard width:
//!    every output cell written exactly once, tiles in bounds, claims
//!    in sync with units, shard partitions disjoint-and-covering with
//!    group structure preserved, ping-pong feature views never
//!    aliased within a layer, buffers in bounds, layers chained.
//! 3. **ISA linting** ([`lint_program`]) — a register-file simulation
//!    of the compiled program: STI/STIH immediates inside the 21-bit
//!    encoding, every CONV/DENSE issued with exactly the register
//!    values its layer requires, memory bases disjoint and ordered,
//!    HLT/BRA frame-loop scaffolding intact.
//! 4. **Cycle pricing** ([`lint_cycles`]) — an independent
//!    recomputation of the per-mode frame cost cross-checked against
//!    what [`CapacityModel`] prices admission on, plus the sanity law
//!    that no truncated mode prices above high accuracy.
//!
//! [`verify_model`] bundles all four; [`crate::coordinator::registry`]
//! runs it before publishing any model, the `binarray analyze` CLI
//! prints the per-layer report for the paper configs, and
//! [`crate::verify`] races it as one more oracle arm.

use std::fmt;

use crate::artifacts::{LayerKind, QuantLayer, QuantNetwork};
use crate::binarray::plan::{ExecutionPlan, ShardPlan, WorkUnit};
use crate::coordinator::CapacityModel;
use crate::fixp;
use crate::isa::{flags, Instr, Program, Reg, IMM_BITS};

/// Largest QS shift the barrel shifter / rounding path supports:
/// `round_shift` computes `1 << (shift - 1)` in 32 bits, so any shift
/// past 31 is a malformed layer regardless of accumulator range.
pub const MAX_SHIFT: u32 = 31;

/// Why a compiled artifact failed static verification.  Every variant
/// carries a concrete witness — the analyzer never says just "no".
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AnalysisError {
    /// The interval analysis found admissible inputs that drive the
    /// MULW accumulator to `[lo, hi]`, outside the 28-bit range.
    /// `m` is the binary level at which the bound is first exceeded.
    MulwOverflow {
        layer: usize,
        d: usize,
        m: usize,
        lo: i64,
        hi: i64,
    },
    /// QS shift outside the datapath's representable range.
    BadShift { layer: usize, shift: u32 },
    /// A work unit reaches outside the layer's output grid.
    UnitOutOfBounds {
        layer: usize,
        cards: usize,
        rows: usize,
        d_out: usize,
    },
    /// Output cell `(row, d)` written `count` times (want exactly 1).
    /// `cards == 0` means the unsharded schedule, otherwise the shard
    /// width whose flattened partition failed.
    Coverage {
        layer: usize,
        cards: usize,
        row: usize,
        d: usize,
        count: usize,
    },
    /// Precomputed tile claims disagree with the unit list.
    ClaimMismatch { layer: usize },
    /// Input and output feature views share a ping-pong half.
    PingPongAlias { layer: usize },
    /// A feature view reaches past the feature buffer.
    BufferOverrun { layer: usize },
    /// Chained layers do not hand their buffer over.
    ChainBreak { layer: usize },
    /// A shard partition lost the parent's logical-SA group structure.
    GroupMismatch { layer: usize, cards: usize },
    /// An STI/STIH immediate exceeds the 21-bit encoding.
    ImmOutOfRange { pc: usize, imm: u32 },
    /// A layer was issued with a register differing from what its
    /// binding and parameters require.
    RegisterMismatch {
        layer: usize,
        reg: Reg,
        got: u32,
        want: u32,
    },
    /// Program or plan scaffolding broken (missing HLT/BRA, layer
    /// ids out of order, memory bases overlapping, …).
    ProgramShape(String),
    /// The independent cycle recomputation disagrees with what
    /// [`CapacityModel`] prices admission on.
    CycleMismatch { mode_idx: usize, got: u64, want: u64 },
    /// A truncated accuracy mode prices above high accuracy.
    ModeCostInverted {
        mode_idx: usize,
        cost: u64,
        high_cost: u64,
    },
}

impl fmt::Display for AnalysisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AnalysisError::MulwOverflow { layer, d, m, lo, hi } => write!(
                f,
                "layer {layer} channel {d} level {m}: accumulator range [{lo}, {hi}] \
                 exceeds MULW [{}, {}]",
                fixp::MULW_MIN,
                fixp::MULW_MAX
            ),
            AnalysisError::BadShift { layer, shift } => {
                write!(f, "layer {layer}: QS shift {shift} exceeds {MAX_SHIFT}")
            }
            AnalysisError::UnitOutOfBounds { layer, cards, rows, d_out } => write!(
                f,
                "layer {layer} ({}): work unit outside the {rows}×{d_out} output grid",
                width_label(*cards)
            ),
            AnalysisError::Coverage { layer, cards, row, d, count } => write!(
                f,
                "layer {layer} ({}): output cell (row {row}, ch {d}) written {count} \
                 times, want exactly once",
                width_label(*cards)
            ),
            AnalysisError::ClaimMismatch { layer } => {
                write!(f, "layer {layer}: tile claims out of sync with work units")
            }
            AnalysisError::PingPongAlias { layer } => write!(
                f,
                "layer {layer}: input and output views share a ping-pong half"
            ),
            AnalysisError::BufferOverrun { layer } => {
                write!(f, "layer {layer}: feature view past the buffer end")
            }
            AnalysisError::ChainBreak { layer } => write!(
                f,
                "layer {layer}: output base differs from the next layer's input base"
            ),
            AnalysisError::GroupMismatch { layer, cards } => write!(
                f,
                "layer {layer} ({}): shard lost the logical-SA group structure",
                width_label(*cards)
            ),
            AnalysisError::ImmOutOfRange { pc, imm } => write!(
                f,
                "instruction {pc}: immediate {imm} exceeds {IMM_BITS} bits"
            ),
            AnalysisError::RegisterMismatch { layer, reg, got, want } => write!(
                f,
                "layer {layer}: issued with {} = {got}, binding requires {want}",
                reg.name()
            ),
            AnalysisError::ProgramShape(msg) => write!(f, "program shape: {msg}"),
            AnalysisError::CycleMismatch { mode_idx, got, want } => write!(
                f,
                "mode {mode_idx}: recomputed {got} cycles, CapacityModel prices {want}"
            ),
            AnalysisError::ModeCostInverted { mode_idx, cost, high_cost } => write!(
                f,
                "mode {mode_idx}: truncated cost {cost} exceeds high-accuracy {high_cost}"
            ),
        }
    }
}

impl std::error::Error for AnalysisError {}

fn width_label(cards: usize) -> String {
    if cards == 0 {
        "unsharded".into()
    } else {
        format!("{cards}-card shard")
    }
}

// ---------------------------------------------------------------------------
// Interval arithmetic
// ---------------------------------------------------------------------------

/// A closed integer interval, the abstract value of the range analysis.
/// Kept in `i64` so a computation that would overflow the concrete
/// `i32` datapath is *detected* rather than wrapped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub lo: i64,
    pub hi: i64,
}

impl Interval {
    pub fn point(v: i64) -> Self {
        Self { lo: v, hi: v }
    }

    pub fn new(lo: i64, hi: i64) -> Self {
        debug_assert!(lo <= hi);
        Self { lo, hi }
    }

    /// Smallest interval containing both.
    pub fn hull(self, o: Self) -> Self {
        Self {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
        }
    }

    pub fn add(self, o: Self) -> Self {
        Self {
            lo: self.lo + o.lo,
            hi: self.hi + o.hi,
        }
    }

    pub fn neg(self) -> Self {
        Self {
            lo: -self.hi,
            hi: -self.lo,
        }
    }

    /// Multiply by a scalar (negative scalars flip the endpoints).
    pub fn scale(self, k: i64) -> Self {
        if k >= 0 {
            Self {
                lo: self.lo * k,
                hi: self.hi * k,
            }
        } else {
            Self {
                lo: self.hi * k,
                hi: self.lo * k,
            }
        }
    }

    /// Does every value fit the 28-bit MULW accumulator?
    pub fn fits_mulw(&self) -> bool {
        self.lo >= i64::from(fixp::MULW_MIN) && self.hi <= i64::from(fixp::MULW_MAX)
    }

    /// Largest absolute value in the interval.
    pub fn peak(&self) -> i64 {
        self.lo.abs().max(self.hi.abs())
    }
}

/// `round_shift` lifted to `i64` (round half away from zero); monotone
/// in `acc`, so applying it to interval endpoints is exact.
fn round_shift_i64(acc: i64, shift: u32) -> i64 {
    if shift == 0 {
        return acc;
    }
    let half = 1i64 << (shift - 1);
    if acc >= 0 {
        (acc + half) >> shift
    } else {
        -((-acc + half) >> shift)
    }
}

/// The QS block on an interval: round, then saturate into `i8`.
fn qs_interval(v: Interval, shift: u32) -> Interval {
    let sat = |x: i64| x.clamp(i64::from(i8::MIN), i64::from(i8::MAX));
    Interval::new(sat(round_shift_i64(v.lo, shift)), sat(round_shift_i64(v.hi, shift)))
}

// ---------------------------------------------------------------------------
// Range analysis
// ---------------------------------------------------------------------------

/// Per-layer outcome of the range proof (one row of the analyze report).
#[derive(Clone, Debug)]
pub struct LayerRange {
    pub layer: usize,
    pub kind: LayerKind,
    /// Activation range feeding this layer.
    pub input: Interval,
    /// Hull of every PE prefix sum — the values the per-tick
    /// `debug_assert!(fits_mulw(..))` samples dynamically.
    pub pe: Interval,
    /// Hull of the DSP cascade across all channels and level counts
    /// (bias + Σ αᵢ·planeᵢ), i.e. everything the QS block can see.
    pub acc: Interval,
    /// Activation range this layer emits (after QS and ReLU/pool).
    pub output: Interval,
    pub shift: u32,
    /// Unused MULW magnitude bits at the accumulator peak.
    pub headroom_bits: u32,
}

/// Range analysis of one layer given its input activation interval.
/// Returns the layer record; the output interval inside it feeds the
/// next layer.
pub fn layer_range(layer: &QuantLayer, idx: usize, input: Interval) -> Result<LayerRange, AnalysisError> {
    if layer.shift > MAX_SHIFT {
        return Err(AnalysisError::BadShift {
            layer: idx,
            shift: layer.shift,
        });
    }
    let n_c = layer.n_c();
    let mut pe_hull = Interval::point(0);
    let mut acc_hull: Option<Interval> = None;
    // QS sees the cascade after `m_run` levels for every runtime mode
    // `1 ≤ m_run ≤ m` — the hull over those prefixes bounds them all.
    let mut qs_hull: Option<Interval> = None;

    for d in 0..layer.d {
        let bias = i64::from(layer.bias_q[d]);
        let mut casc = Interval::point(bias);
        if !casc.fits_mulw() {
            return Err(AnalysisError::MulwOverflow {
                layer: idx,
                d,
                m: 0,
                lo: casc.lo,
                hi: casc.hi,
            });
        }
        acc_hull = Some(acc_hull.map_or(casc, |h| h.hull(casc)));
        if layer.m == 0 {
            qs_hull = Some(qs_hull.map_or(casc, |h| h.hull(casc)));
        }
        for mi in 0..layer.m {
            // PE walk: sign-controlled accumulation in plane order —
            // the hull of the running prefix covers every per-tick
            // value the hardware accumulator takes.
            let base = (d * layer.m + mi) * n_c;
            let plane = &layer.planes[base..base + n_c];
            let mut run = Interval::point(0);
            let mut prefix = Interval::point(0);
            for &s in plane {
                let contrib = if s >= 0 { input } else { input.neg() };
                run = run.add(contrib);
                prefix = prefix.hull(run);
            }
            if !prefix.fits_mulw() {
                return Err(AnalysisError::MulwOverflow {
                    layer: idx,
                    d,
                    m: mi,
                    lo: prefix.lo,
                    hi: prefix.hi,
                });
            }
            pe_hull = pe_hull.hull(prefix);
            // DSP: α product, then cascade-add (Eq. 11) — both live in
            // the same MULW path and both must fit.
            let r = run.scale(i64::from(layer.alpha(d, mi)));
            if !r.fits_mulw() {
                return Err(AnalysisError::MulwOverflow {
                    layer: idx,
                    d,
                    m: mi,
                    lo: r.lo,
                    hi: r.hi,
                });
            }
            casc = casc.add(r);
            if !casc.fits_mulw() {
                return Err(AnalysisError::MulwOverflow {
                    layer: idx,
                    d,
                    m: mi,
                    lo: casc.lo,
                    hi: casc.hi,
                });
            }
            acc_hull = Some(acc_hull.map_or(casc, |h| h.hull(casc)));
            qs_hull = Some(qs_hull.map_or(casc, |h| h.hull(casc)));
        }
    }

    let acc = acc_hull.unwrap_or_else(|| Interval::point(0));
    let mut out = qs_interval(qs_hull.unwrap_or_else(|| Interval::point(0)), layer.shift);
    // The AMU's zero-seeded max-pool implements ReLU for free; plain
    // ReLU clamps the same way.
    let pooled = layer.kind == LayerKind::Conv && layer.pool > 1;
    if layer.relu || pooled {
        out = Interval::new(out.lo.max(0), out.hi.max(0));
    }
    let peak_bits = 64 - acc.peak().max(1).leading_zeros();
    Ok(LayerRange {
        layer: idx,
        kind: layer.kind,
        input,
        pe: pe_hull,
        acc,
        output: out,
        shift: layer.shift,
        headroom_bits: (fixp::MULW - 1).saturating_sub(peak_bits),
    })
}

/// Prove the whole network overflow-free for any admissible `i8`
/// input, or return the first concrete witness.  The per-layer records
/// are the range half of the analyze report.
pub fn analyze_ranges(net: &QuantNetwork) -> Result<Vec<LayerRange>, AnalysisError> {
    let mut input = Interval::new(i64::from(i8::MIN), i64::from(i8::MAX));
    let mut out = Vec::with_capacity(net.layers.len());
    for (idx, layer) in net.layers.iter().enumerate() {
        let r = layer_range(layer, idx, input)?;
        input = r.output;
        out.push(r);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Schedule linting
// ---------------------------------------------------------------------------

/// Exactly-once coverage: every cell of the `rows × d_out` output grid
/// must be written by exactly one unit.  `cards = 0` labels the
/// unsharded schedule in errors, `cards = n` a flattened n-card shard.
pub fn lint_cover(
    units: &[WorkUnit],
    rows: usize,
    d_out: usize,
    layer: usize,
    cards: usize,
) -> Result<(), AnalysisError> {
    for u in units {
        if u.rows.end > rows || u.d.end > d_out {
            return Err(AnalysisError::UnitOutOfBounds {
                layer,
                cards,
                rows,
                d_out,
            });
        }
    }
    let mut seen = vec![0u32; rows * d_out];
    for u in units {
        for r in u.rows.clone() {
            for d in u.d.clone() {
                seen[r * d_out + d] += 1;
            }
        }
    }
    for (cell, &count) in seen.iter().enumerate() {
        if count != 1 {
            return Err(AnalysisError::Coverage {
                layer,
                cards,
                row: cell / d_out,
                d: cell % d_out,
                count: count as usize,
            });
        }
    }
    Ok(())
}

/// The output grid a layer's schedule must cover: pooled rows × D.
fn layer_grid(net: &QuantNetwork, plan: &ExecutionPlan, mode: Option<usize>, li: usize) -> (usize, usize) {
    let lp = &plan.mode(mode).layers[li];
    let l = &net.layers[lp.layer];
    match l.kind {
        LayerKind::Conv => (lp.out_shape.h, l.d),
        LayerKind::Dense => (1, l.d),
    }
}

/// Lint one [`ExecutionPlan`]: for every accuracy mode, exactly-once
/// coverage, claims/unit agreement, truncation bookkeeping and the
/// ping-pong buffer invariants.
pub fn lint_plan(net: &QuantNetwork, plan: &ExecutionPlan) -> Result<(), AnalysisError> {
    let half = plan.fbuf_words / 2;
    for mode_idx in 0..=plan.max_m {
        let mode = if mode_idx == 0 { None } else { Some(mode_idx) };
        let mp = plan.mode(mode);
        if mp.layers.len() != net.layers.len() {
            return Err(AnalysisError::ProgramShape(format!(
                "mode {mode_idx}: {} layer plans for {} layers",
                mp.layers.len(),
                net.layers.len()
            )));
        }
        for (li, lp) in mp.layers.iter().enumerate() {
            if lp.layer != li {
                return Err(AnalysisError::ProgramShape(format!(
                    "mode {mode_idx}: plan {li} points at layer {}",
                    lp.layer
                )));
            }
            let l = &net.layers[li];
            let want_m = mode.unwrap_or(l.m).min(l.m).max(1);
            if lp.m_run != want_m {
                return Err(AnalysisError::ProgramShape(format!(
                    "mode {mode_idx} layer {li}: m_run {} want {want_m}",
                    lp.m_run
                )));
            }
            // ping-pong: opposite halves, in bounds, chained
            if (lp.in_base < half) == (lp.out_base < half) {
                return Err(AnalysisError::PingPongAlias { layer: li });
            }
            if lp.in_base + lp.in_len > plan.fbuf_words
                || lp.out_base + lp.out_len > plan.fbuf_words
            {
                return Err(AnalysisError::BufferOverrun { layer: li });
            }
            if li + 1 < mp.layers.len() && lp.out_base != mp.layers[li + 1].in_base {
                return Err(AnalysisError::ChainBreak { layer: li });
            }
            // coverage + claims
            let (rows, d_out) = layer_grid(net, plan, mode, li);
            let flat: Vec<WorkUnit> = lp.assignments.iter().flatten().cloned().collect();
            lint_cover(&flat, rows, d_out, li, 0)?;
            let claims = lp.claims();
            if claims.len() != flat.len()
                || claims
                    .iter()
                    .zip(&flat)
                    .any(|(c, u)| c.0 != u.rows || c.1 != u.d)
            {
                return Err(AnalysisError::ClaimMismatch { layer: li });
            }
        }
    }
    Ok(())
}

/// Lint the `width`-card shard partition of a plan: per mode and layer,
/// the per-card sub-schedules must preserve the parent's group count
/// and flatten back to exactly-once coverage (disjoint and covering).
pub fn lint_shards(net: &QuantNetwork, plan: &ExecutionPlan, width: usize) -> Result<(), AnalysisError> {
    let sp = ShardPlan::new(plan, width);
    for mode_idx in 0..=plan.max_m {
        let mode = if mode_idx == 0 { None } else { Some(mode_idx) };
        let layers = sp.mode(mode);
        for (li, ls) in layers.iter().enumerate() {
            let parent = &plan.mode(mode).layers[li];
            if ls.cards.len() != width.max(1) {
                return Err(AnalysisError::GroupMismatch { layer: li, cards: width });
            }
            let mut flat = Vec::new();
            for card in &ls.cards {
                if card.assignments.len() != parent.assignments.len() {
                    return Err(AnalysisError::GroupMismatch { layer: li, cards: width });
                }
                let card_units: Vec<WorkUnit> =
                    card.assignments.iter().flatten().cloned().collect();
                let claims = card.claims();
                if claims.len() != card_units.len()
                    || claims
                        .iter()
                        .zip(&card_units)
                        .any(|(c, u)| c.0 != u.rows || c.1 != u.d)
                {
                    return Err(AnalysisError::ClaimMismatch { layer: li });
                }
                flat.extend(card_units);
            }
            let (rows, d_out) = layer_grid(net, plan, mode, li);
            lint_cover(&flat, rows, d_out, li, width)?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// ISA linting
// ---------------------------------------------------------------------------

/// The register values a layer's CONV/DENSE must be issued with,
/// derived independently from its binding and parameters (the same
/// contract `compile_network` emits — recomputed, not reused).
fn expected_regs(net: &QuantNetwork, prog: &Program, i: usize) -> [u32; Reg::COUNT] {
    let b = &prog.bindings[i];
    let l = &net.layers[i];
    let mut fl = 0u32;
    if l.relu {
        fl |= flags::RELU;
    }
    if l.kind == LayerKind::Dense {
        fl |= flags::DENSE;
    }
    if i + 1 == net.layers.len() {
        fl |= flags::LAST;
    }
    let mut want = [0u32; Reg::COUNT];
    want[Reg::WIn as usize] = b.in_dims.0 as u32;
    want[Reg::HIn as usize] = b.in_dims.1 as u32;
    want[Reg::CIn as usize] = b.in_dims.2 as u32;
    want[Reg::WKer as usize] = l.kw.max(1) as u32;
    want[Reg::HKer as usize] = l.kh.max(1) as u32;
    want[Reg::DOut as usize] = l.d as u32;
    want[Reg::Stride as usize] = l.stride.max(1) as u32;
    want[Reg::Pool as usize] = l.pool.max(1) as u32;
    want[Reg::MLvl as usize] = l.m as u32;
    want[Reg::WgtBase as usize] = b.wgt_base as u32;
    want[Reg::AlphaBase as usize] = b.alpha_base as u32;
    want[Reg::InBase as usize] = b.in_base as u32;
    want[Reg::OutBase as usize] = b.out_base as u32;
    want[Reg::QsShift as usize] = l.shift;
    want[Reg::Flags as usize] = fl;
    want[Reg::NIn as usize] = l.n_c() as u32;
    want
}

/// Lint a compiled program against its network: immediate encodings,
/// register-file contents at every layer issue, memory-base layout and
/// the HLT/BRA frame loop.
pub fn lint_program(net: &QuantNetwork, prog: &Program) -> Result<(), AnalysisError> {
    if prog.bindings.len() != net.layers.len() {
        return Err(AnalysisError::ProgramShape(format!(
            "{} bindings for {} layers",
            prog.bindings.len(),
            net.layers.len()
        )));
    }
    // memory planning: weight/α bases must tile the memories exactly
    let (mut wb, mut ab) = (0usize, 0usize);
    for (i, (b, l)) in prog.bindings.iter().zip(&net.layers).enumerate() {
        if b.layer != i || b.wgt_base != wb || b.alpha_base != ab {
            return Err(AnalysisError::ProgramShape(format!(
                "layer {i}: binding bases (wgt {}, α {}) want ({wb}, {ab})",
                b.wgt_base, b.alpha_base
            )));
        }
        wb += l.d * l.m * l.n_c();
        ab += l.d * l.m + l.d;
    }
    if prog.wgt_words != wb || prog.alpha_words != ab {
        return Err(AnalysisError::ProgramShape(format!(
            "memory totals (wgt {}, α {}) want ({wb}, {ab})",
            prog.wgt_words, prog.alpha_words
        )));
    }
    // frame-loop scaffolding
    if prog.entry >= prog.instrs.len() || prog.instrs[prog.entry] != Instr::Hlt {
        return Err(AnalysisError::ProgramShape(format!(
            "entry {} is not a HLT",
            prog.entry
        )));
    }
    if prog.instrs.last() != Some(&Instr::Bra(prog.entry as u32)) {
        return Err(AnalysisError::ProgramShape(
            "program does not loop back to its entry HLT".into(),
        ));
    }
    // register-file simulation
    let mask: u32 = (1u32 << IMM_BITS) - 1;
    let mut regs = [0u32; Reg::COUNT];
    let mut next_layer = 0usize;
    for (pc, ins) in prog.instrs.iter().enumerate() {
        match *ins {
            Instr::Sti(r, v) => {
                if v > mask {
                    return Err(AnalysisError::ImmOutOfRange { pc, imm: v });
                }
                regs[r as usize] = v;
            }
            Instr::StiH(r, v) => {
                if v > mask {
                    return Err(AnalysisError::ImmOutOfRange { pc, imm: v });
                }
                regs[r as usize] = (regs[r as usize] & mask) | (v << IMM_BITS);
            }
            Instr::Conv(id) | Instr::Dense(id) => {
                if id > mask {
                    return Err(AnalysisError::ImmOutOfRange { pc, imm: id });
                }
                if id as usize != next_layer || next_layer >= net.layers.len() {
                    return Err(AnalysisError::ProgramShape(format!(
                        "instruction {pc} issues layer {id}, expected {next_layer}"
                    )));
                }
                let want_dense = net.layers[next_layer].kind == LayerKind::Dense;
                let is_dense = matches!(ins, Instr::Dense(_));
                if want_dense != is_dense {
                    return Err(AnalysisError::ProgramShape(format!(
                        "layer {next_layer}: issued as {}",
                        if is_dense { "DENSE" } else { "CONV" }
                    )));
                }
                let want = expected_regs(net, prog, next_layer);
                for ri in 0..Reg::COUNT {
                    if regs[ri] != want[ri] {
                        return Err(AnalysisError::RegisterMismatch {
                            layer: next_layer,
                            reg: Reg::from_u8(ri as u8).expect("ri < COUNT"),
                            got: regs[ri],
                            want: want[ri],
                        });
                    }
                }
                next_layer += 1;
            }
            Instr::Bra(a) => {
                if a > mask {
                    return Err(AnalysisError::ImmOutOfRange { pc, imm: a });
                }
            }
            Instr::Hlt | Instr::Nop => {}
        }
    }
    if next_layer != net.layers.len() {
        return Err(AnalysisError::ProgramShape(format!(
            "program issues {next_layer} of {} layers",
            net.layers.len()
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Cycle pricing
// ---------------------------------------------------------------------------

/// Recompute the per-mode frame cost from the plan alone and cross-check
/// it against what [`CapacityModel`] prices admission on.  Also checks
/// the sanity law the brownout premise rests on: truncating levels never
/// makes a frame *more* expensive.  Returns the per-mode cycle vector
/// (index 0 = high accuracy) for the report.
pub fn lint_cycles(net: &QuantNetwork, plan: &ExecutionPlan) -> Result<Vec<u64>, AnalysisError> {
    let est: Vec<u64> = (0..=plan.max_m)
        .map(|i| {
            let mode = if i == 0 { None } else { Some(i) };
            plan.mode(mode)
                .layers
                .iter()
                .map(|lp| {
                    let l = &net.layers[lp.layer];
                    let np = l.pool.max(1);
                    let n_c = l.n_c().max(1) as u64;
                    let widest = lp
                        .assignments
                        .iter()
                        .map(|units| {
                            units
                                .iter()
                                .map(|u| match lp.kind {
                                    LayerKind::Conv => {
                                        (u.rows.len() * np) as u64
                                            * (lp.out_shape.w * np) as u64
                                            * n_c
                                    }
                                    LayerKind::Dense => n_c,
                                })
                                .sum::<u64>()
                        })
                        .max()
                        .unwrap_or(0);
                    widest * lp.seq_m
                })
                .sum::<u64>()
                .max(1)
        })
        .collect();
    let model = CapacityModel::new(plan, net);
    for (i, &got) in est.iter().enumerate() {
        let want = model.est_by_index(i).ok_or_else(|| {
            AnalysisError::ProgramShape(format!("CapacityModel has no mode {i}"))
        })?;
        if got != want {
            return Err(AnalysisError::CycleMismatch {
                mode_idx: i,
                got,
                want,
            });
        }
        if i > 0 && got > est[0] {
            return Err(AnalysisError::ModeCostInverted {
                mode_idx: i,
                cost: got,
                high_cost: est[0],
            });
        }
    }
    Ok(est)
}

// ---------------------------------------------------------------------------
// Top-level verdict + report
// ---------------------------------------------------------------------------

/// Everything [`verify_model`] proved, in printable form — the payload
/// of the `binarray analyze` CLI report.
#[derive(Clone, Debug)]
pub struct AnalysisReport {
    pub layers: Vec<LayerRange>,
    /// Per-mode frame cost (index 0 = high accuracy, `m` = truncated).
    pub mode_cycles: Vec<u64>,
    pub n_instrs: usize,
    /// Shard widths whose partitions were proved disjoint-and-covering.
    pub widths: Vec<usize>,
}

impl fmt::Display for AnalysisReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "  {:<5} {:<5} {:>6} {:>22} {:>24} {:>13} {:>5}",
            "layer", "kind", "shift", "input", "accumulator", "headroom", "out"
        )?;
        for r in &self.layers {
            writeln!(
                f,
                "  {:<5} {:<5} {:>6} {:>22} {:>24} {:>10} bits {:>5}",
                r.layer,
                match r.kind {
                    LayerKind::Conv => "conv",
                    LayerKind::Dense => "dense",
                },
                r.shift,
                format!("[{}, {}]", r.input.lo, r.input.hi),
                format!("[{}, {}]", r.acc.lo, r.acc.hi),
                r.headroom_bits,
                format!("[{}, {}]", r.output.lo, r.output.hi),
            )?;
        }
        let cycles: Vec<String> = self
            .mode_cycles
            .iter()
            .enumerate()
            .map(|(i, c)| {
                if i == 0 {
                    format!("full={c}")
                } else {
                    format!("m{i}={c}")
                }
            })
            .collect();
        writeln!(f, "  cycles/frame: {}", cycles.join(" "))?;
        writeln!(
            f,
            "  proved: MULW({}b) overflow-free for all i8 inputs; exactly-once \
             schedules at widths {:?}; {} instructions in ISA range; \
             cycle pricing consistent with admission",
            fixp::MULW,
            self.widths,
            self.n_instrs
        )
    }
}

/// Run the full static verifier over one compiled model: range proof,
/// program lint, plan lint over every accuracy mode, shard lint over
/// every width `1..=max_cards`, and the cycle-pricing cross-check.
/// `Ok` is a per-(network, config, mode) theorem that the release
/// datapath cannot overflow and the schedules cannot double-write or
/// drop an output; `Err` carries the concrete witness.
pub fn verify_model(
    net: &QuantNetwork,
    prog: &Program,
    plan: &ExecutionPlan,
    max_cards: usize,
) -> Result<AnalysisReport, AnalysisError> {
    let layers = analyze_ranges(net)?;
    lint_program(net, prog)?;
    lint_plan(net, plan)?;
    let widths: Vec<usize> = (1..=max_cards.max(1)).collect();
    for &w in &widths {
        lint_shards(net, plan, w)?;
    }
    let mode_cycles = lint_cycles(net, plan)?;
    Ok(AnalysisReport {
        layers,
        mode_cycles,
        n_instrs: prog.instrs.len(),
        widths,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::synthetic_cnn_a;
    use crate::binarray::ArrayConfig;
    use crate::isa::compile_network;
    use crate::util::rng::Xoshiro256;

    fn cnn_a(m: usize) -> QuantNetwork {
        let mut rng = Xoshiro256::new(0xA11A);
        synthetic_cnn_a(&mut rng, m)
    }

    /// A single dense layer sized so the proof passes with small α and
    /// fails once α widens: n_c·128·127 > MULW_MAX but n_c·128·m stays
    /// far inside it.
    fn big_dense(alpha: i8) -> QuantNetwork {
        let n_c = 16_384usize;
        let d = 2usize;
        let m = 2usize;
        QuantNetwork {
            f_input: 7,
            layers: vec![QuantLayer {
                kind: LayerKind::Dense,
                planes: vec![1i8; d * m * n_c],
                alpha_q: vec![alpha; d * m],
                bias_q: vec![5; d],
                d,
                m,
                kh: n_c,
                kw: 0,
                c: 0,
                f_alpha: 6,
                f_in: 7,
                f_out: 7,
                shift: 7,
                relu: false,
                pool: 1,
                stride: 1,
            }],
        }
    }

    #[test]
    fn paper_configs_prove_clean() {
        for cfg in crate::binarray::PAPER_CONFIGS {
            let net = cnn_a(cfg.m_arch.max(2));
            let prog = compile_network(&net);
            let plan = ExecutionPlan::new(cfg, &net, &prog);
            let report = verify_model(&net, &prog, &plan, 4)
                .unwrap_or_else(|e| panic!("{} rejected: {e}", cfg.label()));
            assert_eq!(report.layers.len(), net.layers.len());
            assert_eq!(report.mode_cycles.len(), plan.max_m + 1);
            assert_eq!(report.widths, vec![1, 2, 3, 4]);
            // every layer keeps real MULW headroom and i8 outputs
            for r in &report.layers {
                assert!(r.acc.fits_mulw());
                assert!(r.output.lo >= -128 && r.output.hi <= 127);
            }
            // the report renders
            let text = report.to_string();
            assert!(text.contains("overflow-free"), "{text}");
        }
    }

    #[test]
    fn relu_and_pool_clamp_propagated_ranges() {
        let net = cnn_a(2);
        let ranges = analyze_ranges(&net).unwrap();
        // layer 0 pools (AMU zero-seed) → non-negative activations into
        // layer 1
        assert!(ranges[0].output.lo >= 0);
        assert_eq!(ranges[1].input, ranges[0].output);
        // the classifier head (no relu) may go negative
        assert!(ranges.last().unwrap().output.lo < 0);
    }

    #[test]
    fn widened_alpha_is_a_concrete_overflow_witness() {
        // known-good at α = 1 …
        analyze_ranges(&big_dense(1)).expect("narrow α proves clean");
        // … widening α past the envelope yields a witness at layer 0
        let err = analyze_ranges(&big_dense(127)).unwrap_err();
        match err {
            AnalysisError::MulwOverflow { layer, m, lo, hi, .. } => {
                assert_eq!(layer, 0);
                assert_eq!(m, 0, "first level already overflows");
                assert!(hi > i64::from(fixp::MULW_MAX) || lo < i64::from(fixp::MULW_MIN));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn monster_bias_is_caught_before_any_level() {
        let mut net = big_dense(1);
        net.layers[0].bias_q[1] = i32::MAX;
        match analyze_ranges(&net).unwrap_err() {
            AnalysisError::MulwOverflow { d, m, .. } => {
                assert_eq!(d, 1);
                assert_eq!(m, 0);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn per_tick_prefix_can_overflow_even_when_the_sum_cancels() {
        // +1 block then −1 block: the final dot is ~0, but the running
        // prefix peaks at n_c/2 · 128 — only the prefix hull sees it.
        let n_c = 3_000_000usize; // 1.5M·128 = 192M > MULW_MAX
        let mut planes = vec![1i8; n_c];
        for p in planes.iter_mut().skip(n_c / 2) {
            *p = -1;
        }
        let layer = QuantLayer {
            kind: LayerKind::Dense,
            planes,
            alpha_q: vec![1],
            bias_q: vec![0],
            d: 1,
            m: 1,
            kh: n_c,
            kw: 0,
            c: 0,
            f_alpha: 6,
            f_in: 7,
            f_out: 7,
            shift: 7,
            relu: false,
            pool: 1,
            stride: 1,
        };
        let err = layer_range(&layer, 0, Interval::new(-128, 127)).unwrap_err();
        assert!(matches!(err, AnalysisError::MulwOverflow { m: 0, .. }), "{err:?}");
    }

    #[test]
    fn dropped_qs_shift_is_rejected() {
        let mut net = cnn_a(2);
        net.layers[2].shift = 40;
        assert_eq!(
            analyze_ranges(&net).unwrap_err(),
            AnalysisError::BadShift { layer: 2, shift: 40 }
        );
    }

    #[test]
    fn overlapping_and_gapped_tiles_are_flagged() {
        let good = vec![
            WorkUnit { rows: 0..2, d: 0..4 },
            WorkUnit { rows: 2..4, d: 0..4 },
        ];
        lint_cover(&good, 4, 4, 7, 2).expect("disjoint cover passes");
        // overlap: both tiles claim row 2
        let overlap = vec![
            WorkUnit { rows: 0..3, d: 0..4 },
            WorkUnit { rows: 2..4, d: 0..4 },
        ];
        match lint_cover(&overlap, 4, 4, 7, 2).unwrap_err() {
            AnalysisError::Coverage { layer, cards, row, count, .. } => {
                assert_eq!((layer, cards, row, count), (7, 2, 2, 2));
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // gap: row 3 never written
        let gap = vec![WorkUnit { rows: 0..3, d: 0..4 }];
        match lint_cover(&gap, 4, 4, 7, 0).unwrap_err() {
            AnalysisError::Coverage { row, count, .. } => {
                assert_eq!((row, count), (3, 0));
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // out of bounds
        let oob = vec![WorkUnit { rows: 0..5, d: 0..4 }];
        assert!(matches!(
            lint_cover(&oob, 4, 4, 0, 0).unwrap_err(),
            AnalysisError::UnitOutOfBounds { .. }
        ));
    }

    #[test]
    fn out_of_range_sti_immediate_is_flagged() {
        let net = cnn_a(2);
        let mut prog = compile_network(&net);
        lint_program(&net, &prog).expect("compiler output lints clean");
        // an in-memory Instr can hold what encode() would refuse —
        // exactly the corruption the lint must catch before emission
        let pc = prog
            .instrs
            .iter()
            .position(|i| matches!(i, Instr::Sti(Reg::WgtBase, _)))
            .unwrap();
        prog.instrs[pc] = Instr::Sti(Reg::WgtBase, 1 << IMM_BITS);
        assert_eq!(
            lint_program(&net, &prog).unwrap_err(),
            AnalysisError::ImmOutOfRange { pc, imm: 1 << IMM_BITS }
        );
    }

    #[test]
    fn corrupted_qs_shift_register_is_flagged() {
        let net = cnn_a(2);
        let mut prog = compile_network(&net);
        let pc = prog
            .instrs
            .iter()
            .position(|i| matches!(i, Instr::Sti(Reg::QsShift, _)))
            .unwrap();
        let Instr::Sti(r, v) = prog.instrs[pc] else { unreachable!() };
        prog.instrs[pc] = Instr::Sti(r, v + 1);
        match lint_program(&net, &prog).unwrap_err() {
            AnalysisError::RegisterMismatch { layer, reg, got, want } => {
                assert_eq!(layer, 0);
                assert_eq!(reg, Reg::QsShift);
                assert_eq!(got, want + 1);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_loop_is_flagged() {
        let net = cnn_a(2);
        let mut prog = compile_network(&net);
        prog.instrs.pop(); // drop the BRA
        assert!(matches!(
            lint_program(&net, &prog).unwrap_err(),
            AnalysisError::ProgramShape(_)
        ));
    }

    #[test]
    fn stih_wide_address_roundtrips_through_the_simulated_cu() {
        // CNN-A with m = 4 pushes late weight bases past 21 bits, so the
        // compiler emits STI+STIH pairs — the lint's register-file
        // simulation must reassemble them, not flag them.
        let net = cnn_a(4);
        let prog = compile_network(&net);
        assert!(
            prog.instrs.iter().any(|i| matches!(i, Instr::StiH(..))),
            "test premise: wide addresses present"
        );
        lint_program(&net, &prog).expect("wide addresses lint clean");
    }

    #[test]
    fn cycle_cross_check_matches_capacity_model() {
        let net = cnn_a(4);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(4, 32, 4), &net, &prog);
        let est = lint_cycles(&net, &plan).unwrap();
        assert_eq!(est.len(), plan.max_m + 1);
        // truncated modes never price above high accuracy
        for (i, &c) in est.iter().enumerate().skip(1) {
            assert!(c <= est[0], "mode {i}: {c} > {}", est[0]);
        }
    }

    #[test]
    fn qs_interval_matches_scalar_qs_on_endpoints() {
        for shift in [0u32, 1, 5, 9] {
            for v in [-4_000_000i64, -129, -1, 0, 1, 127, 4_000_000] {
                let got = qs_interval(Interval::point(v), shift);
                let want = i64::from(fixp::qs(v as i32, shift));
                assert_eq!(got, Interval::point(want), "v={v} shift={shift}");
            }
        }
    }

    #[test]
    fn interval_scale_flips_on_negative_alpha() {
        let v = Interval::new(-3, 10);
        assert_eq!(v.scale(2), Interval::new(-6, 20));
        assert_eq!(v.scale(-2), Interval::new(-20, 6));
        assert_eq!(v.neg(), Interval::new(-10, 3));
        assert_eq!(v.hull(Interval::point(50)), Interval::new(-3, 50));
    }
}
