//! Fixed-point arithmetic of the BinArray datapath (paper §III-C).
//!
//! * activations: `i8` (DW = 8 bits), per-layer binary point
//! * PA accumulators / DSP cascade: 28-bit (MULW) — modelled as `i32`,
//!   with [`MULW_MIN`]/[`MULW_MAX`] range checks available for assertions
//! * α scaling factors: `i8` fixed point with a per-layer fractional width
//! * QS block: round half away from zero at a per-layer shift, saturate
//!   back to DW bits
//! * barrel shifter: power-of-two alignment of partial results between
//!   cascaded PAs

/// Data width of activations (bits).
pub const DW: u32 = 8;
/// Width of the DSP multiply/accumulate path (bits).
pub const MULW: u32 = 28;
/// Smallest representable MULW value.
pub const MULW_MIN: i32 = -(1 << (MULW - 1));
/// Largest representable MULW value.
pub const MULW_MAX: i32 = (1 << (MULW - 1)) - 1;

/// Quantize-and-saturate: the QS block between the last PA and the AMU.
///
/// Rounds half away from zero at `shift` fractional bits, then saturates
/// into the signed `DW`-bit activation range.
#[inline]
pub fn qs(acc: i32, shift: u32) -> i8 {
    let rounded = round_shift(acc, shift);
    saturate_i8(rounded)
}

/// Round half away from zero at `shift` bits (no saturation).
#[inline]
pub fn round_shift(acc: i32, shift: u32) -> i32 {
    if shift == 0 {
        return acc;
    }
    let half = 1i32 << (shift - 1);
    // i32 is wide enough: |acc| ≤ 2^27 and half ≤ 2^26.  Arithmetic >>
    // floors, so negatives shift their magnitude (half away from zero).
    if acc >= 0 {
        (acc + half) >> shift
    } else {
        -((-acc + half) >> shift)
    }
}

/// Saturate an i32 into the i8 activation range.
#[inline]
pub fn saturate_i8(v: i32) -> i8 {
    v.clamp(i8::MIN as i32, i8::MAX as i32) as i8
}

/// Check a value fits the 28-bit MULW accumulator (debug assertion aid).
#[inline]
pub fn fits_mulw(v: i32) -> bool {
    (MULW_MIN..=MULW_MAX).contains(&v)
}

/// Barrel shifter: align a partial result by a signed power-of-two shift
/// (positive = left). Used between cascaded PAs when the fixed-point
/// formats of neighbouring binary levels differ (paper §III-A).
#[inline]
pub fn barrel_shift(v: i32, amount: i32) -> i32 {
    if amount >= 0 {
        v.wrapping_shl(amount as u32)
    } else {
        v >> (-amount) as u32
    }
}

/// Quantize a float to a signed fixed-point integer with `frac` fractional
/// bits and `width` total bits (round to nearest, saturate).
pub fn quantize(v: f32, frac: u32, width: u32) -> i32 {
    let scaled = v as f64 * (1u64 << frac) as f64;
    let r = scaled.round();
    let max = ((1i64 << (width - 1)) - 1) as f64;
    let min = -(1i64 << (width - 1)) as f64;
    r.clamp(min, max) as i32
}

/// Dequantize a fixed-point integer back to float.
pub fn dequantize(v: i32, frac: u32) -> f32 {
    v as f32 / (1u64 << frac) as f32
}

/// Largest fractional width such that `max_abs` still fits signed `width`
/// bits — the calibration rule used by `python/compile/quantize.py`.
pub fn binary_point(max_abs: f32, width: u32) -> u32 {
    if max_abs <= 0.0 {
        return width - 1;
    }
    let int_bits = (max_abs as f64 + 1e-12).log2().ceil().max(0.0) as u32;
    (width - 1).saturating_sub(int_bits).min(width - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn qs_rounds_half_away_from_zero() {
        assert_eq!(qs(3, 1), 2); // (3+1)>>1
        assert_eq!(qs(-3, 1), -2); // -(3+1)>>1
        assert_eq!(qs(2, 1), 1);
        assert_eq!(qs(-2, 1), -1);
        assert_eq!(qs(1, 1), 1); // 0.5 rounds away → 1
        assert_eq!(qs(-1, 1), -1);
    }

    #[test]
    fn qs_saturates_both_ways() {
        assert_eq!(qs(1_000_000, 2), 127);
        assert_eq!(qs(-1_000_000, 2), -128);
        assert_eq!(qs(127, 0), 127);
        assert_eq!(qs(128, 0), 127);
        assert_eq!(qs(-128, 0), -128);
        assert_eq!(qs(-129, 0), -128);
    }

    #[test]
    fn qs_shift_zero_is_saturate_only() {
        for v in -200..200 {
            assert_eq!(qs(v, 0), saturate_i8(v));
        }
    }

    #[test]
    fn round_shift_matches_float_rounding() {
        prop::check(500, "round_shift == round(v / 2^s)", |rng| {
            let v = rng.range_i64(-(1 << 26), 1 << 26) as i32;
            let s = rng.below(12) as u32;
            let want = (v as f64 / f64::from(1u32 << s)).abs().round() as i32
                * v.signum();
            assert_eq!(round_shift(v, s), want, "v={v} s={s}");
        });
    }

    #[test]
    fn barrel_shift_inverse() {
        prop::check(200, "left-then-right barrel shift is identity", |rng| {
            let v = rng.range_i64(-(1 << 20), 1 << 20) as i32;
            let s = rng.below(7) as i32;
            assert_eq!(barrel_shift(barrel_shift(v, s), -s), v);
        });
    }

    #[test]
    fn quantize_dequantize_error_bounded() {
        prop::check(300, "quantization error ≤ half LSB", |rng| {
            let v = rng.f32_range(-0.9, 0.9);
            let q = quantize(v, 7, 8);
            let back = dequantize(q, 7);
            assert!(
                (back - v).abs() <= 0.5 / 128.0 + 1e-6,
                "v={v} q={q} back={back}"
            );
        });
    }

    #[test]
    fn binary_point_rule() {
        assert_eq!(binary_point(0.4, 8), 7);
        assert_eq!(binary_point(1.5, 8), 6);
        assert_eq!(binary_point(3.0, 8), 5);
        assert_eq!(binary_point(100.0, 8), 0); // needs all 7 integer bits
        assert_eq!(binary_point(0.0, 8), 7);
    }

    #[test]
    fn binary_point_value_fits() {
        prop::check(300, "max_abs representable at chosen point", |rng| {
            let v = rng.f32_range(0.01, 60.0);
            let f = binary_point(v, 8);
            // value scaled by 2^f must fit in 8 signed bits (±127), except
            // the degenerate f=0 case where the integer part saturates.
            if f > 0 {
                assert!(
                    (v as f64 * f64::from(1u32 << f)) <= 127.5 * 2.0,
                    "v={v} f={f}"
                );
            }
        });
    }

    #[test]
    fn mulw_bounds() {
        assert!(fits_mulw(0));
        assert!(fits_mulw(MULW_MAX));
        assert!(fits_mulw(MULW_MIN));
        assert!(!fits_mulw(MULW_MAX + 1));
        assert!(!fits_mulw(MULW_MIN - 1));
    }
}
