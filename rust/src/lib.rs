//! # BinArray — a scalable accelerator for binary-approximated CNNs
//!
//! Full-system reproduction of *"BinArray: A Scalable Hardware Accelerator
//! for Binary Approximated CNNs"* (Fischer & Wassner, 2020) as a
//! three-layer Rust + JAX + Pallas stack.  This crate is the request-path
//! layer (L3): the cycle-accurate simulator standing in for the FPGA RTL,
//! the analytical performance/area models, the instruction-set toolchain,
//! the bit-accurate golden model, a serving coordinator, and a PJRT
//! runtime that executes the AOT-lowered JAX graphs.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`approx`] — multi-level binary weight approximation (paper §II)
//! * [`fixp`] — the fixed-point datapath semantics (§III-C)
//! * [`tensor`] — row-major feature maps
//! * [`nn`] — reference network descriptions (CNN-A, MobileNetV1 B1/B2)
//! * [`isa`] — instruction set + assembler + network compiler (§IV-C)
//! * [`golden`] — bit-accurate int8 functional model (§V-A2)
//! * [`artifacts`] — readers for the Python-side AOT outputs
//! * [`binarray`] — the cycle-accurate simulator: PE/PA/SA/AMU/AGU/CU (§III–IV)
//! * [`perf`] — analytical performance model, Eqs. 14–18 (§IV-E)
//! * [`area`] — FPGA resource model (Table IV)
//! * [`coordinator`] — request router / batcher / worker pool (§IV-D)
//! * [`runtime`] — PJRT CPU client for `artifacts/*.hlo.txt`
//! * [`data`] — synthetic GTSRB-like workload generator
//! * [`util`] — PRNG, property-test harness, binary IO

pub mod approx;
pub mod area;
pub mod artifacts;
pub mod binarray;
pub mod coordinator;
pub mod data;
pub mod fixp;
pub mod golden;
pub mod isa;
pub mod nn;
pub mod perf;
pub mod runtime;
pub mod tensor;
pub mod util;
