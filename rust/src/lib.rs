//! # BinArray — a scalable accelerator for binary-approximated CNNs
//!
//! Full-system reproduction of *"BinArray: A Scalable Hardware Accelerator
//! for Binary Approximated CNNs"* (Fischer & Wassner, 2020) as a
//! three-layer Rust + JAX + Pallas stack.  This crate is the request-path
//! layer (L3): the cycle-accurate simulator standing in for the FPGA RTL,
//! the analytical performance/area models, the instruction-set toolchain,
//! the bit-accurate golden model, a serving coordinator, and a PJRT
//! runtime that executes the AOT-lowered JAX graphs.
//!
//! ## Plan/execute architecture
//!
//! The request path follows a FINN-style *plan once, execute many* split:
//!
//! * **plan** — [`binarray::plan::ExecutionPlan`] is built at system
//!   construction from the compiled program: per layer and per runtime
//!   accuracy mode it freezes the work-unit schedule over logical SAs
//!   (Eqs. 15–17), the sequential level-group count, the ping-pong
//!   feature-buffer bindings and the output tile geometry;
//! * **execute** — [`binarray::system::FrameExecutor`] walks that plan
//!   per frame with zero-copy [`tensor::FeatureMapView`] inputs, disjoint
//!   [`tensor::FeatureMapTileMut`] outputs written from a scoped host
//!   thread pool (one thread per logical SA group), and reusable im2col
//!   scratch arenas.  `BinArraySystem::run_frames` executes a whole
//!   coordinator batch back-to-back on one plan.
//!
//! Simulated cycle accounting and logits are invariant under all of this:
//! the executor is bit-identical to [`golden::forward`] (property-tested
//! across configs, modes, batch sizes and host-thread counts).
//!
//! ## Module map (see DESIGN.md for the full inventory)
//!
//! * [`approx`] — multi-level binary weight approximation (paper §II)
//! * [`fixp`] — the fixed-point datapath semantics (§III-C)
//! * [`tensor`] — row-major feature maps + zero-copy views/tiles
//! * [`nn`] — reference network descriptions (CNN-A, MobileNetV1 B1/B2)
//! * [`isa`] — instruction set + assembler + network compiler (§IV-C)
//! * [`golden`] — bit-accurate int8 functional model (§V-A2)
//! * [`kernel`] — bit-packed popcount dot-product kernels (portable /
//!   AVX2 / NEON behind runtime detection, `BINARRAY_KERNEL` override);
//!   bit-identical to `golden` — a host-speed knob, never a semantics one
//! * [`artifacts`] — readers for the Python-side AOT outputs (BAW1/BAC1/
//!   BAG1) + the synthetic CNN-A stand-in for artifact-less environments,
//!   plus the packed sign-plane view the kernel consumes
//! * [`binarray`] — the cycle-accurate simulator: PE/PA/SA/AMU/AGU/CU,
//!   the execution plan and the frame executor (§III–IV)
//! * [`perf`] — analytical performance model, Eqs. 14–18 (§IV-E)
//! * [`area`] — FPGA resource model (Table IV)
//! * [`coordinator`] — request router / batcher / worker pool (§IV-D)
//!   with per-request hybrid dispatch: every request is admitted under a
//!   `DispatchClass` (explicit or `RoutePolicy`-decided) and both lanes
//!   share one card pool — batch-class requests drain through
//!   `run_frames` on single cards, shard-class frames scatter row tiles
//!   (`run_shard`) over cards the orchestrator leases and gathers per
//!   layer
//! * [`runtime`] — PJRT CPU client for `artifacts/*.hlo.txt` (stubbed
//!   without the `xla` cargo feature)
//! * [`data`] — synthetic GTSRB-like workload generator
//! * [`util`] — PRNG, property-test harness, binary IO
//! * [`verify`] — differential racer: random-network generator + arm
//!   racing (golden vs. scalar/packed plan vs. sharded widths) with
//!   seed replay (`BINARRAY_FUZZ_SEED`) and budget shrinking
//! * [`analysis`] — static plan verifier: interval range proof of
//!   MULW overflow-freedom plus schedule/shard/ISA/cycle linting, run
//!   before the registry publishes any model (`binarray analyze`)

#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod approx;
pub mod area;
pub mod artifacts;
pub mod binarray;
pub mod coordinator;
pub mod data;
pub mod fixp;
pub mod golden;
pub mod isa;
pub mod kernel;
pub mod nn;
pub mod perf;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod verify;
