//! Shared utilities: deterministic PRNG, a tiny property-test harness
//! (the environment has no network access, so `proptest` is replaced by
//! [`prop`]), and little-endian binary IO helpers for the artifact formats.

pub mod prop;
pub mod rng;

use std::io::{self, Read};

/// Worker-card counts the cross-card test suites exercise, so CI can
/// matrix over pool widths (`BINARRAY_TEST_CARDS=1,2,4` style) while
/// local `cargo test` keeps the full default coverage.
///
/// Malformed values panic: a CI matrix entry that silently fell back to
/// the default would claim coverage it doesn't have.
pub fn test_cards() -> Vec<usize> {
    match std::env::var("BINARRAY_TEST_CARDS") {
        Err(_) => vec![1, 2, 4],
        Ok(s) => parse_cards(&s),
    }
}

fn parse_cards(s: &str) -> Vec<usize> {
    let cards: Vec<usize> = s
        .split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            let n: usize = t
                .parse()
                .unwrap_or_else(|_| panic!("BINARRAY_TEST_CARDS: bad card count {t:?}"));
            assert!(n > 0, "BINARRAY_TEST_CARDS: card count must be ≥ 1");
            n
        })
        .collect();
    assert!(!cards.is_empty(), "BINARRAY_TEST_CARDS is set but empty");
    cards
}

/// Read a little-endian `u32` from a reader.
pub fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read a little-endian `i32` from a reader.
pub fn read_i32<R: Read>(r: &mut R) -> io::Result<i32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(i32::from_le_bytes(b))
}

/// Read `n` raw `i8` values.
pub fn read_i8_vec<R: Read>(r: &mut R, n: usize) -> io::Result<Vec<i8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf.into_iter().map(|b| b as i8).collect())
}

/// Read `n` little-endian `i32` values.
pub fn read_i32_vec<R: Read>(r: &mut R, n: usize) -> io::Result<Vec<i32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_u32_i32() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        buf.extend_from_slice(&(-12345i32).to_le_bytes());
        let mut cur = io::Cursor::new(buf);
        assert_eq!(read_u32(&mut cur).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_i32(&mut cur).unwrap(), -12345);
    }

    #[test]
    fn i8_vec_sign_preserved() {
        let raw = vec![0xFFu8, 0x01, 0x80, 0x7F];
        let mut cur = io::Cursor::new(raw);
        assert_eq!(read_i8_vec(&mut cur, 4).unwrap(), vec![-1, 1, -128, 127]);
    }

    #[test]
    fn parse_cards_accepts_lists_and_singletons() {
        assert_eq!(parse_cards("1,2,4"), vec![1, 2, 4]);
        assert_eq!(parse_cards(" 3 "), vec![3]);
        assert_eq!(parse_cards("2,"), vec![2]);
    }

    #[test]
    #[should_panic(expected = "bad card count")]
    fn parse_cards_rejects_garbage() {
        parse_cards("1,two");
    }

    #[test]
    fn i32_vec_le() {
        let mut buf = Vec::new();
        for v in [-1i32, 0, 65536] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let mut cur = io::Cursor::new(buf);
        assert_eq!(read_i32_vec(&mut cur, 3).unwrap(), vec![-1, 0, 65536]);
    }
}
