//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! Runs a property over `n` seeded random cases; on failure it reports the
//! failing case index and seed so the case reproduces exactly.  Shrinking
//! is intentionally out of scope — failures print their full input via the
//! property's own panic message.
//!
//! ```
//! use binarray::util::{prop, rng::Xoshiro256};
//! prop::check(100, "addition commutes", |rng| {
//!     let (a, b) = (rng.range_i64(-1000, 1000), rng.range_i64(-1000, 1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Xoshiro256;

/// Base seed for all property runs; change to re-roll the corpus.
pub const BASE_SEED: u64 = 0xB1AA_4201;

/// Run `property` on `cases` seeded inputs. Panics with case/seed info on
/// the first failure.
pub fn check<F: FnMut(&mut Xoshiro256)>(cases: u32, name: &str, mut property: F) {
    for case in 0..cases {
        let seed = BASE_SEED ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Xoshiro256::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Generate a random vector of `i8` activations.
pub fn i8_vec(rng: &mut Xoshiro256, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.i8()).collect()
}

/// Generate a random ±1 sign vector.
pub fn sign_vec(rng: &mut Xoshiro256, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.sign()).collect()
}

/// Generate a random f32 vector from N(0, 1).
pub fn normal_vec(rng: &mut Xoshiro256, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, "trivial", |rng| {
            let v = rng.below(10);
            assert!(v < 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn reports_failure_with_context() {
        check(50, "must fail", |rng| {
            assert!(rng.below(100) < 1, "value too big");
        });
    }

    #[test]
    fn generators_have_right_lengths() {
        let mut rng = Xoshiro256::new(1);
        assert_eq!(i8_vec(&mut rng, 17).len(), 17);
        assert_eq!(sign_vec(&mut rng, 9).iter().all(|&s| s == 1 || s == -1), true);
        assert_eq!(normal_vec(&mut rng, 5).len(), 5);
    }
}
