//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! Runs a property over `n` seeded random cases; on failure it reports the
//! failing case index and seed so the case reproduces exactly.  Shrinking
//! is intentionally out of scope — failures print their full input via the
//! property's own panic message.
//!
//! ```
//! use binarray::util::{prop, rng::Xoshiro256};
//! prop::check(100, "addition commutes", |rng| {
//!     let (a, b) = (rng.range_i64(-1000, 1000), rng.range_i64(-1000, 1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Xoshiro256;

/// Base seed for all property runs; change to re-roll the corpus.
pub const BASE_SEED: u64 = 0xB1AA_4201;

/// The per-case seed derivation every fuzz harness in the repo shares
/// (`check` here, the differential racer in [`crate::verify`], the
/// coordinator schedule fuzzer) — so a printed case index and a printed
/// seed always agree.
pub fn case_seed(case: u64) -> u64 {
    BASE_SEED ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Read a replay seed from an environment variable (`BINARRAY_FUZZ_SEED`,
/// `BINARRAY_SCHED_SEED`).  Accepts decimal or `0x`-prefixed hex — the
/// formats the fuzz harnesses print in their failure messages.  An unset
/// variable is `None`; a set-but-unparsable one panics (a typo'd replay
/// must never silently run the whole corpus instead).
pub fn env_seed(var: &str) -> Option<u64> {
    let raw = std::env::var(var).ok()?;
    let s = raw.trim();
    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse::<u64>(),
    };
    Some(parsed.unwrap_or_else(|_| {
        panic!("{var}={raw:?} is not a seed (expected decimal or 0x-hex u64)")
    }))
}

/// Run `property` on `cases` seeded inputs. Panics with case/seed info on
/// the first failure.
pub fn check<F: FnMut(&mut Xoshiro256)>(cases: u32, name: &str, mut property: F) {
    for case in 0..cases {
        let seed = case_seed(case as u64);
        let mut rng = Xoshiro256::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Generate a random vector of `i8` activations.
pub fn i8_vec(rng: &mut Xoshiro256, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.i8()).collect()
}

/// Generate a random ±1 sign vector.
pub fn sign_vec(rng: &mut Xoshiro256, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.sign()).collect()
}

/// Generate a random f32 vector from N(0, 1).
pub fn normal_vec(rng: &mut Xoshiro256, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, "trivial", |rng| {
            let v = rng.below(10);
            assert!(v < 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn reports_failure_with_context() {
        check(50, "must fail", |rng| {
            assert!(rng.below(100) < 1, "value too big");
        });
    }

    #[test]
    fn env_seed_parses_both_radixes() {
        // set/remove an env var unique to this test: safe even with the
        // parallel test runner, nothing else reads it
        std::env::set_var("BINARRAY_PROP_TEST_SEED", "0xB1AA");
        assert_eq!(env_seed("BINARRAY_PROP_TEST_SEED"), Some(0xB1AA));
        std::env::set_var("BINARRAY_PROP_TEST_SEED", "12345");
        assert_eq!(env_seed("BINARRAY_PROP_TEST_SEED"), Some(12345));
        std::env::remove_var("BINARRAY_PROP_TEST_SEED");
        assert_eq!(env_seed("BINARRAY_PROP_TEST_SEED"), None);
    }

    #[test]
    fn case_seed_matches_check_derivation() {
        // `check` prints seeds derived through `case_seed` — a drift here
        // would break every printed reproducer
        assert_eq!(case_seed(0), BASE_SEED);
        assert_ne!(case_seed(1), case_seed(2));
    }

    #[test]
    fn generators_have_right_lengths() {
        let mut rng = Xoshiro256::new(1);
        assert_eq!(i8_vec(&mut rng, 17).len(), 17);
        assert_eq!(sign_vec(&mut rng, 9).iter().all(|&s| s == 1 || s == -1), true);
        assert_eq!(normal_vec(&mut rng, 5).len(), 5);
    }
}
