//! Deterministic xoshiro256** PRNG — no external `rand` crate offline.
//!
//! Used by tests, the property harness, the synthetic data generator and
//! the load generator.  Not cryptographic; fully reproducible from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so any `u64` (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (i64 range).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f64() as f32 * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call, simple and fine).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random ±1 sign.
    pub fn sign(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 {
            1
        } else {
            -1
        }
    }

    /// Random `i8` in the full range.
    pub fn i8(&mut self) -> i8 {
        (self.next_u64() & 0xFF) as u8 as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sign_balanced() {
        let mut r = Xoshiro256::new(5);
        let pos: i32 = (0..10_000).map(|_| (r.sign() == 1) as i32).sum();
        assert!((4500..5500).contains(&pos), "pos {pos}");
    }
}
