//! Serving coordinator — the CPU ("PS") side of the heterogeneous system
//! (paper §IV-D) grown into a production-style request path.
//!
//! The paper's Zynq integration has the CPU load frames into the global
//! feature buffer through DMA, trigger the accelerator's HLT loop, and
//! collect results (ping-pong buffering overlaps acquisition with
//! inference).  This module is that CPU role as a serving stack:
//!
//! * [`route`] — per-request dispatch routing: every request is admitted
//!   under a [`DispatchClass`] (explicit override or [`RoutePolicy`]
//!   decision from frame size, queue depth and deadline slack), and both
//!   dispatch lanes run concurrently over one worker pool;
//! * [`batcher`] — dynamic batching with a max-batch / max-delay policy,
//!   one queue per (accuracy mode × dispatch class), cut
//!   earliest-deadline-first within each lane;
//! * [`server`] — the router/arbiter plus a worker pool where each worker
//!   owns one simulated BinArray instance (one card).  Batch-class
//!   requests run whole frames back-to-back exactly like the ping-pong
//!   DMA pipeline; shard-class requests scatter row tiles over cards the
//!   orchestrator *leases* from the same pool and gathers between
//!   layers;
//! * [`capacity`] — the admission-control capacity model: per-mode frame
//!   cost derived from the cached [`crate::binarray::ExecutionPlan`]
//!   schedules, calibrated against observed host pace, so `submit` can
//!   *refuse* work the pool provably can't finish inside its SLO
//!   ([`InferError::AdmissionRefused`]) instead of queueing it to die at
//!   the shed gate;
//! * [`metrics`] — latency/throughput accounting (wall-clock of the
//!   simulator *and* simulated 400 MHz accelerator time), including
//!   per-lane routing/leasing counters and per-[`ServiceClass`] SLO
//!   outcomes;
//! * [`wire`] — the TCP front-end: a length-prefixed binary protocol
//!   decoded straight into the zero-copy feature buffers, typed
//!   [`wire::WireStatus`] codes mirroring [`InferError`], and graceful
//!   drain — real traffic enters here instead of through an in-process
//!   [`SubmitHandle`].
//!
//! Runtime accuracy/throughput switching (§IV-D): every request carries a
//! [`Mode`]; the worker flips the simulated accelerator's `m_run` between
//! batches — the same hardware serves both modes.
//!
//! Failures are answered, never dropped: a malformed request yields an
//! `Err(`[`InferError`]`)` on its reply channel (and an `Err` from
//! `infer`), instead of killing a worker and stranding callers.
//!
//! Deadlines are first-class QoS: a request may carry an absolute
//! [`Request::deadline`].  Slack feeds [`RoutePolicy::Adaptive`] (tight
//! slack ⇒ the shard/latency lane), lanes cut earliest-deadline-first,
//! the shard orchestrator spends part of the slack waiting for a *wider*
//! card lease, and work whose deadline has already passed is shed with
//! [`InferError::DeadlineExceeded`] instead of burning a card on a reply
//! nobody can use.

pub mod batcher;
pub mod capacity;
pub mod metrics;
pub mod registry;
pub mod route;
pub mod server;
pub mod wire;

pub use batcher::{Arbitration, Batch, BatchPolicy, Batcher};
pub use capacity::CapacityModel;
pub use metrics::{ClassMetrics, LatencyStats, Metrics, ModelMetrics};
pub use registry::{ModelEntry, ModelId, ModelRegistry};
pub use route::{ClassSpec, ClassTable, DispatchClass, RoutePolicy, ServiceClass, N_CLASSES};
pub use server::{
    Coordinator, CoordinatorConfig, InferError, InferRequest, Reply, ReplyResult, SubmitHandle,
};
pub use wire::{WireClient, WireReply, WireServer, WireStatus};

/// Runtime accuracy mode of a request (paper §IV-D).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Evaluate all M binary levels (multiple passes if M > M_arch).
    HighAccuracy,
    /// Evaluate only the first M_arch levels in a single pass.
    HighThroughput,
}

impl Mode {
    /// The `m_run` this mode requests on hardware with `m_arch` columns,
    /// for a network approximated with `m` levels.
    pub fn m_run(&self, m: usize, m_arch: usize) -> usize {
        match self {
            Mode::HighAccuracy => m,
            Mode::HighThroughput => m_arch.min(m),
        }
    }
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// int8 image, row-major HWC, at the network's input binary point.
    pub image: Vec<i8>,
    pub mode: Mode,
    /// Which registered model serves this request
    /// ([`ModelId::DEFAULT`] = registry slot 0, what v1 wire traffic and
    /// unqualified submissions get).
    pub model: ModelId,
    /// The published [`ModelEntry`] resolved at admission and pinned for
    /// the request's lifetime — a hot swap never changes what an
    /// in-flight request runs on.  `None` before admission (and in unit
    /// rigs that bypass the registry).
    pub entry: Option<std::sync::Arc<ModelEntry>>,
    /// Dispatch lane: the caller's explicit override, or — stamped by
    /// the router at admission — the [`RoutePolicy`] decision.  Stamped
    /// exactly once; never reassigned afterwards.
    pub class: Option<DispatchClass>,
    /// Absolute completion deadline.  `None` = best effort (unless the
    /// request's [`ServiceClass`] carries an SLO — admission then stamps
    /// `submitted + slo` here).  A deadline is a QoS *signal*, not a
    /// hard abort: routing, batch ordering and lease hysteresis spend
    /// slack where it helps, expired work is shed before compute starts
    /// ([`InferError::DeadlineExceeded`]), and a frame that expires
    /// mid-compute still completes (counted `deadline_missed`).
    pub deadline: Option<std::time::Instant>,
    /// Named QoS class (SLO + lane bias + admission budget, resolved
    /// through the coordinator's [`ClassTable`]).  Defaults to
    /// [`ServiceClass::Standard`], which the default table keeps
    /// contract-free — exactly the pre-class behavior.
    pub service: ServiceClass,
    pub submitted: std::time::Instant,
}

impl Request {
    /// Remaining slack at `now`: `None` without a deadline, otherwise
    /// the time left (zero once expired).
    pub fn slack(&self, now: std::time::Instant) -> Option<std::time::Duration> {
        self.deadline.map(|d| d.saturating_duration_since(now))
    }

    /// Has this request's deadline already passed at `now`?
    pub fn expired(&self, now: std::time::Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_m_run() {
        assert_eq!(Mode::HighAccuracy.m_run(4, 2), 4);
        assert_eq!(Mode::HighThroughput.m_run(4, 2), 2);
        assert_eq!(Mode::HighThroughput.m_run(2, 4), 2);
        assert_eq!(Mode::HighAccuracy.m_run(2, 2), 2);
    }

    #[test]
    fn request_slack_and_expiry() {
        use std::time::{Duration, Instant};
        let now = Instant::now();
        let mut req = Request {
            id: 0,
            image: vec![],
            mode: Mode::HighAccuracy,
            model: ModelId::DEFAULT,
            entry: None,
            class: None,
            deadline: None,
            service: ServiceClass::Standard,
            submitted: now,
        };
        assert_eq!(req.slack(now), None, "no deadline, no slack");
        assert!(!req.expired(now));
        req.deadline = Some(now + Duration::from_millis(10));
        assert_eq!(req.slack(now), Some(Duration::from_millis(10)));
        assert!(!req.expired(now));
        assert!(req.expired(now + Duration::from_millis(10)), "at the deadline");
        assert_eq!(
            req.slack(now + Duration::from_millis(25)),
            Some(Duration::ZERO),
            "slack saturates at zero past the deadline"
        );
    }
}
