//! Serving coordinator — the CPU ("PS") side of the heterogeneous system
//! (paper §IV-D) grown into a production-style request path.
//!
//! The paper's Zynq integration has the CPU load frames into the global
//! feature buffer through DMA, trigger the accelerator's HLT loop, and
//! collect results (ping-pong buffering overlaps acquisition with
//! inference).  This module is that CPU role as a serving stack:
//!
//! * [`route`] — per-request dispatch routing: every request is admitted
//!   under a [`DispatchClass`] (explicit override or [`RoutePolicy`]
//!   decision from frame size and queue depth), and both dispatch lanes
//!   run concurrently over one worker pool;
//! * [`batcher`] — dynamic batching with a max-batch / max-delay policy,
//!   one queue per (accuracy mode × dispatch class);
//! * [`server`] — the router/arbiter plus a worker pool where each worker
//!   owns one simulated BinArray instance (one card).  Batch-class
//!   requests run whole frames back-to-back exactly like the ping-pong
//!   DMA pipeline; shard-class requests scatter row tiles over cards the
//!   orchestrator *leases* from the same pool and gathers between
//!   layers;
//! * [`metrics`] — latency/throughput accounting (wall-clock of the
//!   simulator *and* simulated 400 MHz accelerator time), including
//!   per-lane routing/leasing counters.
//!
//! Runtime accuracy/throughput switching (§IV-D): every request carries a
//! [`Mode`]; the worker flips the simulated accelerator's `m_run` between
//! batches — the same hardware serves both modes.
//!
//! Failures are answered, never dropped: a malformed request yields an
//! `Err(`[`InferError`]`)` on its reply channel (and an `Err` from
//! `infer`), instead of killing a worker and stranding callers.

pub mod batcher;
pub mod metrics;
pub mod route;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher};
pub use metrics::{LatencyStats, Metrics};
pub use route::{DispatchClass, RoutePolicy};
pub use server::{
    Coordinator, CoordinatorConfig, InferError, Reply, ReplyResult, SubmitHandle,
};

/// Runtime accuracy mode of a request (paper §IV-D).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Evaluate all M binary levels (multiple passes if M > M_arch).
    HighAccuracy,
    /// Evaluate only the first M_arch levels in a single pass.
    HighThroughput,
}

impl Mode {
    /// The `m_run` this mode requests on hardware with `m_arch` columns,
    /// for a network approximated with `m` levels.
    pub fn m_run(&self, m: usize, m_arch: usize) -> usize {
        match self {
            Mode::HighAccuracy => m,
            Mode::HighThroughput => m_arch.min(m),
        }
    }
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// int8 image, row-major HWC, at the network's input binary point.
    pub image: Vec<i8>,
    pub mode: Mode,
    /// Dispatch lane: the caller's explicit override, or — stamped by
    /// the router at admission — the [`RoutePolicy`] decision.  Stamped
    /// exactly once; never reassigned afterwards.
    pub class: Option<DispatchClass>,
    pub submitted: std::time::Instant,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_m_run() {
        assert_eq!(Mode::HighAccuracy.m_run(4, 2), 4);
        assert_eq!(Mode::HighThroughput.m_run(4, 2), 2);
        assert_eq!(Mode::HighThroughput.m_run(2, 4), 2);
        assert_eq!(Mode::HighAccuracy.m_run(2, 2), 2);
    }
}
