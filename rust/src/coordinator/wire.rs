//! TCP wire front-end — the paper's §IV-D Zynq integration as a network
//! server: remote clients stream frames into the coordinator the way the
//! Zynq PS streams them into the feature buffer over DMA.
//!
//! Everything before this module enters the coordinator through an
//! in-process [`SubmitHandle`]; this is the first path real traffic can
//! take.  Design constraints, in order:
//!
//! * **No async runtime.**  The coordinator is already message-passing
//!   over channels; a blocking `accept` loop plus one reader thread per
//!   connection feeds it naturally.  Concurrency across requests comes
//!   from concurrent connections (and from the coordinator's own lanes),
//!   not from multiplexing one socket.
//! * **Length-prefixed binary frames, no parsing ambiguity.**  A fixed
//!   34-byte request header (magic, version, mode, service class,
//!   request id, relative deadline, dims + payload length) followed by
//!   the raw `i8` pixel payload, decoded straight into the `Vec<i8>`
//!   the zero-copy feature views borrow from — one copy off the socket,
//!   none after.
//! * **Typed status codes, never a stranded caller.**  Every decoded
//!   request is answered exactly once with a [`WireStatus`] mirroring
//!   [`InferError`]; every malformed frame is answered with
//!   [`WireStatus::BadRequest`] (when a reply is still possible) and a
//!   close — the framing can't be trusted past the first bad byte.
//! * **Graceful drain.**  [`WireServer::shutdown`] stops accepting,
//!   lets every in-flight request finish and be written back, answers
//!   frames that arrive mid-drain with [`WireStatus::Draining`], then
//!   joins every connection thread.  Shut the wire server down *before*
//!   the coordinator so in-flight replies still have workers to come
//!   from.
//!
//! # Request frame
//!
//! All integers little-endian.  Two versions share one layout; they
//! differ only in the meaning of byte 7:
//!
//! | offset | size | field |
//! |--------|------|-------|
//! | 0      | 4    | magic `b"BNRY"` |
//! | 4      | 1    | version (`1` or `2`) |
//! | 5      | 1    | mode: 0 = high accuracy, 1 = high throughput |
//! | 6      | 1    | service class: 0 interactive, 1 standard, 2 bulk |
//! | 7      | 1    | v1: reserved (must be 0) · v2: model id (registry slot) |
//! | 8      | 8    | request id (client-chosen, echoed verbatim) |
//! | 16     | 8    | deadline in µs from server receipt (0 = none) |
//! | 24     | 4    | payload length (must equal `h·w·c`, ≤ 16 MiB) |
//! | 28     | 2    | frame height |
//! | 30     | 2    | frame width |
//! | 32     | 2    | frame channels |
//! | 34     | …    | payload: `h·w·c` bytes, row-major HWC `i8` |
//!
//! A v1 frame is served on the registry's default model (slot 0) —
//! exactly the pre-registry behavior, byte for byte.  A v2 frame names
//! any registered model; one naming an unregistered slot is answered
//! with [`WireStatus::UnknownModel`] and the connection stays open (the
//! frame was well-formed — only the name was wrong).
//!
//! # Response frame
//!
//! | offset | size | field |
//! |--------|------|-------|
//! | 0      | 4    | magic `b"BNRY"` |
//! | 4      | 1    | version (echoes the request's) |
//! | 5      | 1    | [`WireStatus`] |
//! | 6      | 2    | reserved (0) |
//! | 8      | 8    | request id (echoed) |
//! | 16     | 8    | µs: end-to-end latency (`Ok`), the capacity model's earliest-feasible budget (`Refused`), else 0 |
//! | 24     | 4    | payload length (logits count; 0 unless `Ok`) |
//! | 28     | …    | payload: logits, `i8` |

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::metrics::Metrics;
use super::registry::ModelId;
use super::server::{InferError, InferRequest, Reply, SubmitHandle};
use super::{Mode, ServiceClass};

/// Frame magic: every request and response starts with these 4 bytes.
pub const MAGIC: [u8; 4] = *b"BNRY";
/// The original, model-less protocol version — still accepted verbatim;
/// requests carrying it serve the registry's default model.
pub const VERSION: u8 = 1;
/// Protocol version 2: identical layout, but byte 7 is the model id
/// (a [`ModelId`] registry slot) instead of a reserved zero.
pub const VERSION_2: u8 = 2;
/// Fixed request-header length (the payload follows).
pub const REQ_HEADER_LEN: usize = 34;
/// Fixed response-header length (the logits follow).
pub const RESP_HEADER_LEN: usize = 28;
/// Hard cap on a request payload: a declared length above this is
/// answered `BadRequest` *before* any allocation or read, so an
/// adversarial length prefix cannot balloon server memory.
pub const MAX_PAYLOAD: u32 = 1 << 24;

/// How often blocked reads wake to poll the drain flag.
const POLL: Duration = Duration::from_millis(25);
/// Once draining, how long a mid-frame read may sit with no progress
/// before the connection is abandoned (a client that sent half a header
/// and hung must not block shutdown forever).
const DRAIN_GRACE: Duration = Duration::from_secs(1);

/// Typed wire status — the on-wire image of [`InferError`] plus the
/// protocol-level outcomes that never reach the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum WireStatus {
    /// Served: the payload carries the logits.
    Ok = 0,
    /// [`InferError::AdmissionRefused`] — the µs field carries the
    /// earliest-feasible budget the refusal names.
    Refused = 1,
    /// [`InferError::DeadlineExceeded`] — shed unserved.
    Deadline = 2,
    /// [`InferError::Failed`] (or the coordinator is gone).
    Failed = 3,
    /// The frame never reached the coordinator: bad magic/version,
    /// reserved bits set, dims/length mismatch, oversized payload.  The
    /// connection closes after this reply — framing is untrusted.
    BadRequest = 4,
    /// The server is draining: the frame was decoded but not submitted.
    Draining = 5,
    /// [`InferError::UnknownModel`] — a v2 frame named a registry slot
    /// that isn't serving.  Unlike [`WireStatus::BadRequest`] the
    /// connection stays open: the frame was well-formed.
    UnknownModel = 6,
}

impl WireStatus {
    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => WireStatus::Ok,
            1 => WireStatus::Refused,
            2 => WireStatus::Deadline,
            3 => WireStatus::Failed,
            4 => WireStatus::BadRequest,
            5 => WireStatus::Draining,
            6 => WireStatus::UnknownModel,
            _ => return None,
        })
    }
}

/// One decoded response, as the client sees it.
#[derive(Clone, Debug)]
pub struct WireReply {
    /// The client-chosen request id, echoed.
    pub id: u64,
    /// The protocol version echoed back (matches the request's).
    pub version: u8,
    pub status: WireStatus,
    /// `Ok`: end-to-end server latency.  `Refused`: the earliest-feasible
    /// budget.  Otherwise zero.
    pub micros: u64,
    /// Logits (empty unless `status == Ok`).
    pub logits: Vec<i8>,
}

/// One decoded request header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct ReqHeader {
    version: u8,
    mode: Mode,
    service: ServiceClass,
    /// Registry slot (always 0 for a v1 frame).
    model: u8,
    id: u64,
    deadline_us: u64,
    payload_len: u32,
    h: u16,
    w: u16,
    c: u16,
}

/// Why a request header was rejected at the protocol layer.  The id is
/// carried when the header was intact enough to echo one; the version is
/// the request's own when plausible, so the refusal echoes it.
#[derive(Debug)]
struct ProtoError {
    id: u64,
    version: u8,
    what: &'static str,
}

fn encode_req_header(buf: &mut [u8; REQ_HEADER_LEN], h: &ReqHeader) {
    buf[0..4].copy_from_slice(&MAGIC);
    buf[4] = h.version;
    buf[5] = match h.mode {
        Mode::HighAccuracy => 0,
        Mode::HighThroughput => 1,
    };
    buf[6] = h.service.index() as u8;
    buf[7] = h.model;
    buf[8..16].copy_from_slice(&h.id.to_le_bytes());
    buf[16..24].copy_from_slice(&h.deadline_us.to_le_bytes());
    buf[24..28].copy_from_slice(&h.payload_len.to_le_bytes());
    buf[28..30].copy_from_slice(&h.h.to_le_bytes());
    buf[30..32].copy_from_slice(&h.w.to_le_bytes());
    buf[32..34].copy_from_slice(&h.c.to_le_bytes());
}

fn decode_req_header(buf: &[u8; REQ_HEADER_LEN]) -> std::result::Result<ReqHeader, ProtoError> {
    // The id field sits past the magic/version checks but is decoded
    // first: even a rejected frame echoes the id when those 8 bytes were
    // at least received, so the client can correlate the refusal.
    let id = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    // Echo a plausible version even on rejection; garbage falls back to v1.
    let version = if buf[4] == VERSION_2 { VERSION_2 } else { VERSION };
    let err = |what| ProtoError { id, version, what };
    if buf[0..4] != MAGIC {
        return Err(ProtoError { id: 0, version: VERSION, what: "bad magic" });
    }
    if buf[4] != VERSION && buf[4] != VERSION_2 {
        return Err(err("unsupported version"));
    }
    let mode = match buf[5] {
        0 => Mode::HighAccuracy,
        1 => Mode::HighThroughput,
        _ => return Err(err("unknown mode")),
    };
    let service = match buf[6] {
        0 => ServiceClass::Interactive,
        1 => ServiceClass::Standard,
        2 => ServiceClass::Bulk,
        _ => return Err(err("unknown service class")),
    };
    // v1 keeps byte 7 reserved-zero (the historical contract, enforced
    // bit for bit); v2 reads it as the model id.
    if buf[4] == VERSION && buf[7] != 0 {
        return Err(err("reserved byte set"));
    }
    let model = if buf[4] == VERSION_2 { buf[7] } else { 0 };
    let deadline_us = u64::from_le_bytes(buf[16..24].try_into().unwrap());
    let payload_len = u32::from_le_bytes(buf[24..28].try_into().unwrap());
    let h = u16::from_le_bytes(buf[28..30].try_into().unwrap());
    let w = u16::from_le_bytes(buf[30..32].try_into().unwrap());
    let c = u16::from_le_bytes(buf[32..34].try_into().unwrap());
    if payload_len > MAX_PAYLOAD {
        return Err(err("payload exceeds MAX_PAYLOAD"));
    }
    if payload_len as u64 != h as u64 * w as u64 * c as u64 || payload_len == 0 {
        return Err(err("payload length does not match dims"));
    }
    Ok(ReqHeader {
        version: buf[4],
        mode,
        service,
        model,
        id,
        deadline_us,
        payload_len,
        h,
        w,
        c,
    })
}

/// Reinterpret raw socket bytes as the `i8` pixel vector the request
/// moves into the coordinator (and the zero-copy feature views borrow
/// from).  `u8` and `i8` are layout-identical, so this is a pointer
/// recast of the same allocation — the one copy off the socket is the
/// only copy the payload ever makes.
fn bytes_into_i8(v: Vec<u8>) -> Vec<i8> {
    let mut v = std::mem::ManuallyDrop::new(v);
    // SAFETY: i8 and u8 have identical size/alignment and every bit
    // pattern is valid for both; ManuallyDrop forfeits the original
    // ownership so the allocation is freed exactly once, by the new Vec.
    unsafe { Vec::from_raw_parts(v.as_mut_ptr() as *mut i8, v.len(), v.capacity()) }
}

/// The reverse recast for writing logits back onto the socket.
fn i8_as_bytes(v: &[i8]) -> &[u8] {
    // SAFETY: same layout argument as `bytes_into_i8`, borrow-only.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}

/// What a polled blocking read ended as.
enum ReadOutcome {
    /// The buffer is full.
    Full,
    /// Clean EOF before the first byte of this frame.
    Closed,
    /// The drain flag was raised before the first byte of this frame.
    Draining,
}

/// `read_exact` against a socket with a poll timeout: timeouts between
/// frames check the drain flag; timeouts *mid-frame* keep waiting (an
/// in-flight frame is answered, not abandoned) until the drain grace
/// expires.  EOF mid-frame is an error; EOF at a frame boundary is a
/// clean close.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    drain: &AtomicBool,
) -> io::Result<ReadOutcome> {
    let mut got = 0;
    let mut drain_seen: Option<Instant> = None;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(ReadOutcome::Closed);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "peer closed mid-frame",
                ));
            }
            Ok(n) => {
                got += n;
                drain_seen = None;
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if drain.load(Ordering::Relaxed) {
                    if got == 0 {
                        return Ok(ReadOutcome::Draining);
                    }
                    let since = *drain_seen.get_or_insert_with(Instant::now);
                    if since.elapsed() > DRAIN_GRACE {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "drain grace expired mid-frame",
                        ));
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadOutcome::Full)
}

fn write_response(
    stream: &mut TcpStream,
    version: u8,
    id: u64,
    status: WireStatus,
    micros: u64,
    logits: &[i8],
) -> io::Result<()> {
    let mut head = [0u8; RESP_HEADER_LEN];
    head[0..4].copy_from_slice(&MAGIC);
    head[4] = version;
    head[5] = status as u8;
    head[8..16].copy_from_slice(&id.to_le_bytes());
    head[16..24].copy_from_slice(&micros.to_le_bytes());
    head[24..28].copy_from_slice(&(logits.len() as u32).to_le_bytes());
    stream.write_all(&head)?;
    if !logits.is_empty() {
        stream.write_all(i8_as_bytes(logits))?;
    }
    stream.flush()
}

/// The TCP front-end: an accept loop plus one blocking reader thread per
/// connection, all submitting into one [`SubmitHandle`].
///
/// Lifecycle: [`WireServer::start`] binds and begins accepting;
/// [`WireServer::shutdown`] drains (stop accepting → answer in-flight →
/// join every thread).  Always drain the wire server *before* calling
/// [`super::Coordinator::shutdown`].
pub struct WireServer {
    addr: SocketAddr,
    drain: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl WireServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting.  `metrics` should be the coordinator's shared
    /// ledger ([`super::Coordinator::metrics`]) so wire counters land in
    /// the same final report.
    pub fn start<A: ToSocketAddrs>(
        listen: A,
        handle: SubmitHandle,
        metrics: Arc<Mutex<Metrics>>,
    ) -> Result<Self> {
        let listener = TcpListener::bind(listen).context("wire: bind")?;
        let addr = listener.local_addr().context("wire: local_addr")?;
        let drain = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let drain = Arc::clone(&drain);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new()
                .name("binarray-wire-accept".into())
                .spawn(move || {
                    for sock in listener.incoming() {
                        if drain.load(Ordering::Relaxed) {
                            // the shutdown wake connection (or any late
                            // dial) is dropped unserved
                            break;
                        }
                        let Ok(sock) = sock else { continue };
                        let h = handle.clone();
                        let d = Arc::clone(&drain);
                        let m = Arc::clone(&metrics);
                        if let Ok(t) = std::thread::Builder::new()
                            .name("binarray-wire-conn".into())
                            .spawn(move || connection_loop(sock, h, d, m))
                        {
                            let mut held = conns.lock().unwrap();
                            // reap finished connections so a long-lived
                            // server doesn't accumulate dead handles
                            let mut live = Vec::with_capacity(held.len() + 1);
                            for j in held.drain(..) {
                                if j.is_finished() {
                                    let _ = j.join();
                                } else {
                                    live.push(j);
                                }
                            }
                            live.push(t);
                            *held = live;
                        }
                    }
                })
                .context("wire: spawn accept thread")?
        };
        Ok(Self { addr, drain, accept: Some(accept), conns })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful drain: stop accepting, answer every in-flight request,
    /// close every connection, join every thread.  Idempotent against
    /// clients that never disconnect — a hung mid-frame read is
    /// abandoned after the drain grace.
    pub fn shutdown(mut self) {
        self.drain.store(true, Ordering::Relaxed);
        // Wake the blocking accept: one throwaway dial to ourselves.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for j in handles {
            let _ = j.join();
        }
    }
}

/// One connection: read frame → submit → await → write response, until
/// clean close, drain, or protocol fault.  Synchronous per connection by
/// design — pipelining across requests comes from concurrent
/// connections, exactly like one DMA channel per PS core.
fn connection_loop(
    mut stream: TcpStream,
    handle: SubmitHandle,
    drain: Arc<AtomicBool>,
    metrics: Arc<Mutex<Metrics>>,
) {
    let _ = stream.set_read_timeout(Some(POLL));
    let _ = stream.set_nodelay(true);
    metrics.lock().unwrap().wire_connections += 1;
    let mut head = [0u8; REQ_HEADER_LEN];
    loop {
        match read_full(&mut stream, &mut head, &drain) {
            Ok(ReadOutcome::Full) => {}
            Ok(ReadOutcome::Closed) | Ok(ReadOutcome::Draining) => return,
            Err(_) => return, // mid-frame disconnect: nothing to answer
        }
        let hdr = match decode_req_header(&head) {
            Ok(h) => h,
            Err(e) => {
                metrics.lock().unwrap().wire_protocol_errors += 1;
                // best-effort reply, then close: framing is untrusted
                let _ =
                    write_response(&mut stream, e.version, e.id, WireStatus::BadRequest, 0, &[]);
                return;
            }
        };
        let mut payload = vec![0u8; hdr.payload_len as usize];
        match read_full(&mut stream, &mut payload, &drain) {
            Ok(ReadOutcome::Full) => {}
            // Closed/Draining are unreachable mid-frame (got > 0 only
            // after the header), but treat them as a close regardless.
            _ => return,
        }
        // The receipt instant anchors the relative deadline *after* the
        // payload arrived: a slow client spends its own budget, not the
        // coordinator's.
        let deadline = (hdr.deadline_us > 0)
            .then(|| Instant::now() + Duration::from_micros(hdr.deadline_us));
        if drain.load(Ordering::Relaxed) {
            let _ = write_response(&mut stream, hdr.version, hdr.id, WireStatus::Draining, 0, &[]);
            return;
        }
        metrics.lock().unwrap().wire_requests += 1;
        let rx = handle.submit(
            InferRequest::new(bytes_into_i8(payload))
                .mode(hdr.mode)
                .service(hdr.service)
                .deadline(deadline)
                .model(ModelId(hdr.model as u32)),
        );
        let (status, micros, logits) = match rx.recv() {
            Ok(Ok(Reply { logits, latency, .. })) => {
                (WireStatus::Ok, latency.as_micros().min(u64::MAX as u128) as u64, logits)
            }
            Ok(Err(InferError::AdmissionRefused { earliest_feasible, .. })) => (
                WireStatus::Refused,
                earliest_feasible.as_micros().min(u64::MAX as u128) as u64,
                Vec::new(),
            ),
            Ok(Err(InferError::DeadlineExceeded { .. })) => {
                (WireStatus::Deadline, 0, Vec::new())
            }
            Ok(Err(InferError::UnknownModel { .. })) => {
                (WireStatus::UnknownModel, 0, Vec::new())
            }
            Ok(Err(InferError::Failed { .. })) | Err(_) => (WireStatus::Failed, 0, Vec::new()),
        };
        if write_response(&mut stream, hdr.version, hdr.id, status, micros, &logits).is_err() {
            // the peer vanished after submit: the reply was consumed
            // above, so nothing is stranded — just close
            return;
        }
    }
}

/// Blocking client for the wire protocol — the test suites and
/// `loadgen`'s building block, not a production SDK.
///
/// [`WireClient::try_clone`] splits the underlying socket so one thread
/// can pace [`WireClient::send`] calls open-loop while another drains
/// [`WireClient::recv`] — request ids (client-chosen, echoed verbatim)
/// correlate the two sides.
pub struct WireClient {
    stream: TcpStream,
}

impl WireClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("wire client: connect")?;
        let _ = stream.set_nodelay(true);
        Ok(Self { stream })
    }

    /// A second handle on the same socket (send/recv split).
    pub fn try_clone(&self) -> Result<Self> {
        Ok(Self { stream: self.stream.try_clone().context("wire client: clone")? })
    }

    /// Send one v1 request frame (served on the registry's default
    /// model).  `deadline_us == 0` means no deadline; `dims` is
    /// `(h, w, c)` and must multiply to `image.len()`.
    pub fn send(
        &mut self,
        id: u64,
        mode: Mode,
        service: ServiceClass,
        deadline_us: u64,
        dims: (u16, u16, u16),
        image: &[i8],
    ) -> Result<()> {
        self.send_frame(VERSION, 0, id, mode, service, deadline_us, dims, image)
    }

    /// Send one v2 request frame naming a registry model.  Model ids on
    /// the wire are a u8 — the registry never exceeds
    /// [`super::registry::MAX_MODELS`] slots, so every model is
    /// addressable.
    pub fn send_to(
        &mut self,
        model: ModelId,
        id: u64,
        mode: Mode,
        service: ServiceClass,
        deadline_us: u64,
        dims: (u16, u16, u16),
        image: &[i8],
    ) -> Result<()> {
        let model: u8 = u8::try_from(model.0)
            .map_err(|_| anyhow::anyhow!("model id {} not wire-addressable", model.0))?;
        self.send_frame(VERSION_2, model, id, mode, service, deadline_us, dims, image)
    }

    #[allow(clippy::too_many_arguments)]
    fn send_frame(
        &mut self,
        version: u8,
        model: u8,
        id: u64,
        mode: Mode,
        service: ServiceClass,
        deadline_us: u64,
        dims: (u16, u16, u16),
        image: &[i8],
    ) -> Result<()> {
        let len = dims.0 as u64 * dims.1 as u64 * dims.2 as u64;
        if len != image.len() as u64 {
            bail!("dims {dims:?} do not match payload length {}", image.len());
        }
        let hdr = ReqHeader {
            version,
            mode,
            service,
            model,
            id,
            deadline_us,
            payload_len: image.len() as u32,
            h: dims.0,
            w: dims.1,
            c: dims.2,
        };
        let mut head = [0u8; REQ_HEADER_LEN];
        encode_req_header(&mut head, &hdr);
        self.stream.write_all(&head).context("wire client: send header")?;
        self.stream.write_all(i8_as_bytes(image)).context("wire client: send payload")?;
        self.stream.flush().context("wire client: flush")?;
        Ok(())
    }

    /// Receive one response frame (blocks).
    pub fn recv(&mut self) -> Result<WireReply> {
        let mut head = [0u8; RESP_HEADER_LEN];
        self.stream.read_exact(&mut head).context("wire client: recv header")?;
        if head[0..4] != MAGIC {
            bail!("wire client: bad response magic");
        }
        if head[4] != VERSION && head[4] != VERSION_2 {
            bail!("wire client: unsupported response version {}", head[4]);
        }
        let status = WireStatus::from_u8(head[5])
            .with_context(|| format!("wire client: unknown status {}", head[5]))?;
        let id = u64::from_le_bytes(head[8..16].try_into().unwrap());
        let micros = u64::from_le_bytes(head[16..24].try_into().unwrap());
        let len = u32::from_le_bytes(head[24..28].try_into().unwrap());
        if len > MAX_PAYLOAD {
            bail!("wire client: oversized response payload {len}");
        }
        let mut payload = vec![0u8; len as usize];
        self.stream.read_exact(&mut payload).context("wire client: recv payload")?;
        Ok(WireReply { id, version: head[4], status, micros, logits: bytes_into_i8(payload) })
    }

    /// Send one v1 request and block for its reply.
    pub fn request(
        &mut self,
        id: u64,
        mode: Mode,
        service: ServiceClass,
        deadline_us: u64,
        dims: (u16, u16, u16),
        image: &[i8],
    ) -> Result<WireReply> {
        self.send(id, mode, service, deadline_us, dims, image)?;
        self.recv()
    }

    /// Send one v2 request naming a model and block for its reply.
    #[allow(clippy::too_many_arguments)]
    pub fn request_to(
        &mut self,
        model: ModelId,
        id: u64,
        mode: Mode,
        service: ServiceClass,
        deadline_us: u64,
        dims: (u16, u16, u16),
        image: &[i8],
    ) -> Result<WireReply> {
        self.send_to(model, id, mode, service, deadline_us, dims, image)?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> ReqHeader {
        ReqHeader {
            version: VERSION,
            mode: Mode::HighThroughput,
            service: ServiceClass::Interactive,
            model: 0,
            id: 0xDEAD_BEEF_CAFE_F00D,
            deadline_us: 2_000,
            payload_len: 300,
            h: 10,
            w: 10,
            c: 3,
        }
    }

    #[test]
    fn request_header_round_trips() {
        let hdr = header();
        let mut buf = [0u8; REQ_HEADER_LEN];
        encode_req_header(&mut buf, &hdr);
        assert_eq!(decode_req_header(&buf).unwrap(), hdr);
    }

    #[test]
    fn v2_header_round_trips_with_a_model() {
        let hdr = ReqHeader {
            version: VERSION_2,
            model: 7,
            ..header()
        };
        let mut buf = [0u8; REQ_HEADER_LEN];
        encode_req_header(&mut buf, &hdr);
        assert_eq!(buf[4], VERSION_2);
        assert_eq!(buf[7], 7);
        assert_eq!(decode_req_header(&buf).unwrap(), hdr);
    }

    #[test]
    fn byte_7_is_reserved_in_v1_and_the_model_in_v2() {
        // A v1 frame with byte 7 set is rejected exactly as before…
        let mut buf = [0u8; REQ_HEADER_LEN];
        encode_req_header(&mut buf, &header());
        buf[7] = 3;
        assert_eq!(decode_req_header(&buf).unwrap_err().what, "reserved byte set");
        // …while the byte-identical frame under v2 decodes as model 3.
        buf[4] = VERSION_2;
        let hdr = decode_req_header(&buf).unwrap();
        assert_eq!(hdr.model, 3);
        assert_eq!(hdr.version, VERSION_2);
        // Rejections echo the request's own version.
        buf[5] = 9; // unknown mode
        let e = decode_req_header(&buf).unwrap_err();
        assert_eq!(e.version, VERSION_2);
    }

    #[test]
    fn header_rejects_every_malformed_field() {
        let hdr = header();
        let mut good = [0u8; REQ_HEADER_LEN];
        encode_req_header(&mut good, &hdr);
        let reject = |mutate: &dyn Fn(&mut [u8; REQ_HEADER_LEN]), what: &str| {
            let mut buf = good;
            mutate(&mut buf);
            let e = decode_req_header(&buf).expect_err(what);
            assert_eq!(e.what, what);
        };
        reject(&|b| b[0] = b'X', "bad magic");
        reject(&|b| b[4] = 99, "unsupported version");
        reject(&|b| b[5] = 7, "unknown mode");
        reject(&|b| b[6] = 3, "unknown service class");
        reject(&|b| b[7] = 1, "reserved byte set");
        reject(
            &|b| b[24..28].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes()),
            "payload exceeds MAX_PAYLOAD",
        );
        reject(
            &|b| b[24..28].copy_from_slice(&299u32.to_le_bytes()),
            "payload length does not match dims",
        );
        reject(
            &|b| b[24..28].copy_from_slice(&0u32.to_le_bytes()),
            "payload length does not match dims",
        );
        // a bad-magic frame can't trust any field, so it echoes id 0;
        // every later rejection echoes the client's id
        let mut buf = good;
        buf[0] = b'X';
        assert_eq!(decode_req_header(&buf).unwrap_err().id, 0);
        buf = good;
        buf[4] = 99;
        assert_eq!(decode_req_header(&buf).unwrap_err().id, hdr.id);
    }

    #[test]
    fn byte_recasts_round_trip() {
        let v: Vec<i8> = vec![-128, -1, 0, 1, 127];
        let bytes = i8_as_bytes(&v).to_vec();
        assert_eq!(bytes, vec![128, 255, 0, 1, 127]);
        assert_eq!(bytes_into_i8(bytes), v);
    }

    #[test]
    fn wire_status_round_trips() {
        for s in [
            WireStatus::Ok,
            WireStatus::Refused,
            WireStatus::Deadline,
            WireStatus::Failed,
            WireStatus::BadRequest,
            WireStatus::Draining,
            WireStatus::UnknownModel,
        ] {
            assert_eq!(WireStatus::from_u8(s as u8), Some(s));
        }
        assert_eq!(WireStatus::from_u8(200), None);
    }
}
