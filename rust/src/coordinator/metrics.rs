//! Serving metrics: latency distributions and dual-clock throughput.
//!
//! Two clocks matter in this system: the *host* wall clock (how fast the
//! simulator + coordinator actually run) and the *simulated accelerator*
//! clock (cycles × 400 MHz — the number the paper's Table III reports).
//! Both are tracked so the end-to-end example can report "simulated
//! BinArray fps" next to "simulation wall fps".

use std::time::Duration;

use crate::util::rng::Xoshiro256;

use super::route::{ServiceClass, N_CLASSES};

/// Most samples a [`LatencyStats`] ever holds.  Below the cap the buffer
/// is exact (every tier-1 test count fits with a wide margin); above it,
/// reservoir sampling (Algorithm R) keeps a uniform sample of the whole
/// stream — bounded memory and bounded percentile cost under sustained
/// serving, where the old unbounded `Vec` was a slow leak and an
/// O(n log n) sort per metrics read.
const RESERVOIR_CAP: usize = 4096;

/// Streaming latency statistics: exact below the 4 096-sample reservoir
/// cap, a uniform reservoir above it.  `count()` and `mean()` always
/// reflect the *full* stream; percentiles are exact until the cap, then
/// read from the reservoir.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
    /// Samples ever recorded (≥ `samples_us.len()`).
    seen: u64,
    /// Sum over the full stream (for an exact mean past the cap).
    total_us: u128,
    /// Reservoir slot selection — the in-crate PRNG, fixed seed
    /// (metrics must not depend on ambient entropy).
    rng: Xoshiro256,
}

impl Default for LatencyStats {
    fn default() -> Self {
        Self {
            samples_us: Vec::new(),
            seen: 0,
            total_us: 0,
            rng: Xoshiro256::new(0x5EED_1A7E),
        }
    }
}

/// Keep a uniform without-replacement subsample of `k` of `v`'s
/// elements (partial Fisher–Yates; order is not preserved).
fn subsample(rng: &mut Xoshiro256, v: &mut Vec<u64>, k: usize) {
    let n = v.len();
    if k >= n {
        return;
    }
    for i in 0..k {
        let j = i + rng.below((n - i) as u64) as usize;
        v.swap(i, j);
    }
    v.truncate(k);
}

impl LatencyStats {
    fn record_us(&mut self, us: u64) {
        self.seen += 1;
        self.total_us += us as u128;
        if self.samples_us.len() < RESERVOIR_CAP {
            self.samples_us.push(us);
        } else {
            // Algorithm R: keep each of the `seen` samples with equal
            // probability CAP/seen.
            let j = self.rng.below(self.seen);
            if (j as usize) < RESERVOIR_CAP {
                self.samples_us[j as usize] = us;
            }
        }
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    /// Samples recorded over the whole stream (not the reservoir size).
    pub fn count(&self) -> usize {
        self.seen.min(usize::MAX as u64) as usize
    }

    pub fn percentile(&self, p: f64) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        Duration::from_micros(v[idx.min(v.len() - 1)])
    }

    /// Exact mean of the full stream (reservoir or not).
    pub fn mean(&self) -> Duration {
        if self.seen == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.total_us / self.seen as u128).min(u64::MAX as u128) as u64)
    }

    /// Fold `other`'s stream into this one.  While the combined sample
    /// buffers fit under the cap this is an exact concatenation; past
    /// it, each stream is allotted reservoir slots in proportion to its
    /// *full* stream length (not its buffer size) and fills them with a
    /// uniform without-replacement subsample of its buffer — a
    /// short-latency stream can't crowd a long one out of the merged
    /// percentiles just because it merged first.  `count()` and
    /// `mean()` stay exact over both full streams.
    pub fn merge(&mut self, other: &LatencyStats) {
        if other.seen == 0 {
            return;
        }
        let seen = self.seen + other.seen;
        let total_us = self.total_us + other.total_us;
        let mut theirs = other.samples_us.clone();
        if self.samples_us.len() + theirs.len() > RESERVOIR_CAP {
            let quota = ((RESERVOIR_CAP as u128 * self.seen as u128) / seen as u128) as usize;
            let mine = quota.clamp(
                RESERVOIR_CAP.saturating_sub(theirs.len()),
                self.samples_us.len().min(RESERVOIR_CAP),
            );
            let theirs_n = (RESERVOIR_CAP - mine).min(theirs.len());
            let mut rng = self.rng.clone();
            subsample(&mut rng, &mut self.samples_us, mine);
            subsample(&mut rng, &mut theirs, theirs_n);
            self.rng = rng;
        }
        self.samples_us.extend_from_slice(&theirs);
        self.seen = seen;
        self.total_us = total_us;
    }
}

/// Per-[`ServiceClass`] serving outcomes: the SLO scoreboard.
#[derive(Clone, Debug, Default)]
pub struct ClassMetrics {
    /// Requests of this class that reached `submit` (admitted or not).
    pub submitted: u64,
    /// Requests answered with a result.
    pub completed: u64,
    /// Completions inside the request's SLO/deadline.
    pub slo_met: u64,
    /// Completions after it (still answered `Ok`).
    pub slo_missed: u64,
    /// Requests shed unserved at a deadline gate.
    pub shed: u64,
    /// Requests refused at admission (`InferError::AdmissionRefused`) —
    /// never queued, never computed.
    pub admission_refused: u64,
    /// End-to-end latency of this class's completions.
    pub latency: LatencyStats,
}

impl ClassMetrics {
    pub fn merge(&mut self, other: &ClassMetrics) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.slo_met += other.slo_met;
        self.slo_missed += other.slo_missed;
        self.shed += other.shed;
        self.admission_refused += other.admission_refused;
        self.latency.merge(&other.latency);
    }
}

/// Per-model serving outcomes — the cross-model arbitration scoreboard.
/// Keyed by [`super::registry::ModelId`]'s raw u32 in [`Metrics::models`].
#[derive(Clone, Debug, Default)]
pub struct ModelMetrics {
    /// Registry name at the time the counter was recorded.
    pub name: String,
    /// Requests naming this model that reached `submit` (admitted or not).
    pub submitted: u64,
    /// Requests answered with a result.
    pub completed: u64,
    /// Requests refused at admission (capacity, class budget or the
    /// model's own inflight cap) — never queued, never computed.
    pub refused: u64,
    /// End-to-end latency of this model's completions.
    pub latency: LatencyStats,
}

impl ModelMetrics {
    pub fn merge(&mut self, other: &ModelMetrics) {
        if self.name.is_empty() {
            self.name = other.name.clone();
        }
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.refused += other.refused;
        self.latency.merge(&other.latency);
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub latency: LatencyStats,
    /// Queue wait portion of latency.
    pub queue_wait: LatencyStats,
    /// Requests that reached `submit` — the left side of the accounting
    /// identity `submitted == completed + failed + admission_refused`
    /// (every request is answered exactly once, somewhere).
    pub submitted: u64,
    /// Requests refused at admission (capacity or class budget) with
    /// `InferError::AdmissionRefused`.  Refusals are *not* failures:
    /// the work was never admitted, never queued, never computed.
    pub admission_refused: u64,
    /// Per-service-class outcomes, indexed by `ServiceClass::index()`.
    pub classes: [ClassMetrics; N_CLASSES],
    /// Per-model outcomes, keyed by the model id's raw u32.  Populated
    /// only when the router resolves a registry entry — single-model
    /// rigs that bypass the registry report nothing here.
    pub models: std::collections::HashMap<u32, ModelMetrics>,
    /// Requests completed.
    pub completed: u64,
    /// Requests that ended in an error reply (bad input, dead card…) —
    /// failures are answered, never dropped, so `completed + failed`
    /// equals requests admitted.
    pub failed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Total simulated accelerator cycles.
    pub sim_cycles: u64,
    /// Total host wall time spent inside the simulator.
    pub sim_wall: Duration,
    /// Correct top-1 predictions (when labels are known).
    pub correct: u64,
    /// Requests with labels.
    pub labelled: u64,
    /// Requests admitted into the whole-frame batching lane.
    pub routed_batch: u64,
    /// Requests admitted into the scatter/gather shard lane.
    pub routed_shard: u64,
    /// Card leases granted to the shard orchestrator.
    pub shard_leases: u64,
    /// Cards granted across all leases (`/ shard_leases` = mean scatter
    /// width actually achieved under the prevailing batch-lane load).
    pub shard_cards_granted: u64,
    /// Cards the shard lane asked for but the batch lane was holding at
    /// grant time — how much scatter width mixed traffic "stole".
    pub shard_cards_stolen: u64,
    /// Wall time the batching lane spent inside the simulator (its share
    /// of `sim_wall` — lane occupancy).
    pub batch_wall: Duration,
    /// Wall time the shard lane spent in scatter/gather frames (its
    /// share of `sim_wall` — lane occupancy).
    pub shard_wall: Duration,
    /// Deadlined requests answered on time.
    pub deadline_met: u64,
    /// Deadlined requests that completed, but late (the frame was
    /// already computing when the deadline passed — still answered Ok).
    pub deadline_missed: u64,
    /// Deadlined requests shed unserved (`InferError::DeadlineExceeded`)
    /// because their deadline expired before any card started them.
    /// Sheds also count into `failed` — every admitted request is
    /// answered exactly once.
    pub deadline_shed: u64,
    /// Wait from a shard-lane lease request to its grant, hysteresis
    /// included (how much latency the orchestrator spent shopping for a
    /// wider lease).
    pub lease_wait: LatencyStats,
    /// TCP connections the wire front-end accepted.
    pub wire_connections: u64,
    /// Well-formed wire requests decoded and submitted to the
    /// coordinator (each also counts into `submitted` downstream).
    pub wire_requests: u64,
    /// Wire frames rejected at the protocol layer (bad magic/version,
    /// oversized payload, malformed header) — answered with a typed
    /// wire status or a close, never submitted, never counted into the
    /// coordinator accounting identity.
    pub wire_protocol_errors: u64,
}

impl Metrics {
    pub fn merge(&mut self, other: &Metrics) {
        self.latency.merge(&other.latency);
        self.queue_wait.merge(&other.queue_wait);
        self.submitted += other.submitted;
        self.admission_refused += other.admission_refused;
        for (c, o) in self.classes.iter_mut().zip(&other.classes) {
            c.merge(o);
        }
        for (id, o) in &other.models {
            self.models.entry(*id).or_default().merge(o);
        }
        self.completed += other.completed;
        self.failed += other.failed;
        self.batches += other.batches;
        self.sim_cycles += other.sim_cycles;
        self.sim_wall += other.sim_wall;
        self.correct += other.correct;
        self.labelled += other.labelled;
        self.routed_batch += other.routed_batch;
        self.routed_shard += other.routed_shard;
        self.shard_leases += other.shard_leases;
        self.shard_cards_granted += other.shard_cards_granted;
        self.shard_cards_stolen += other.shard_cards_stolen;
        self.batch_wall += other.batch_wall;
        self.shard_wall += other.shard_wall;
        self.deadline_met += other.deadline_met;
        self.deadline_missed += other.deadline_missed;
        self.deadline_shed += other.deadline_shed;
        self.lease_wait.merge(&other.lease_wait);
        self.wire_connections += other.wire_connections;
        self.wire_requests += other.wire_requests;
        self.wire_protocol_errors += other.wire_protocol_errors;
    }

    /// Simulated-accelerator throughput (frames / simulated second at
    /// 400 MHz) — comparable to the paper's Table III.
    pub fn simulated_fps(&self) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 * crate::binarray::CLOCK_HZ / self.sim_cycles as f64
    }

    /// Host-side throughput of the simulation (frames / wall second).
    pub fn wall_fps(&self) -> f64 {
        let s = self.sim_wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.completed as f64 / s
    }

    pub fn accuracy(&self) -> Option<f64> {
        (self.labelled > 0).then(|| self.correct as f64 / self.labelled as f64)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.completed as f64 / self.batches as f64
    }

    /// Mean cards per shard-lane lease (0 when the lane never leased).
    pub fn mean_lease(&self) -> f64 {
        if self.shard_leases == 0 {
            return 0.0;
        }
        self.shard_cards_granted as f64 / self.shard_leases as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "req={}{} batches={} (avg {:.1}/batch) | sim {:.1} fps @400MHz | wall {:.1} fps | p50 {:?} p99 {:?}{}{}",
            self.completed,
            if self.failed > 0 {
                format!(" (+{} failed)", self.failed)
            } else {
                String::new()
            },
            self.batches,
            self.mean_batch(),
            self.simulated_fps(),
            self.wall_fps(),
            self.latency.percentile(50.0),
            self.latency.percentile(99.0),
            match self.accuracy() {
                Some(a) => format!(" | acc {:.2}%", 100.0 * a),
                None => String::new(),
            },
            self.lane_summary(),
        ) + &self.deadline_summary()
            + &self.class_summary()
            + &self.model_summary()
            + &self.wire_summary()
    }

    /// Per-model fragment of [`Self::summary`]: elided while the
    /// registry path is unused (single-model rigs keep the pre-registry
    /// summary), one fragment per model in id order otherwise.
    fn model_summary(&self) -> String {
        if self.models.is_empty() {
            return String::new();
        }
        let mut ids: Vec<u32> = self.models.keys().copied().collect();
        ids.sort_unstable();
        let mut s = String::new();
        for id in ids {
            let m = &self.models[&id];
            let label = if m.name.is_empty() {
                format!("model#{id}")
            } else {
                m.name.clone()
            };
            s.push_str(&format!(
                " | {label}: {}/{} done (refused {}) p99 {:?}",
                m.completed,
                m.submitted,
                m.refused,
                m.latency.percentile(99.0),
            ));
        }
        s
    }

    /// Wire fragment of [`Self::summary`] (elided until the TCP
    /// front-end accepted a connection, so in-process reports stay
    /// unchanged).
    fn wire_summary(&self) -> String {
        if self.wire_connections == 0 {
            return String::new();
        }
        format!(
            " | wire conns={} reqs={} proto_errs={}",
            self.wire_connections, self.wire_requests, self.wire_protocol_errors
        )
    }

    /// Per-class fragment of [`Self::summary`]: elided entirely while no
    /// class has an SLO outcome or a refusal (pure-Standard best-effort
    /// traffic keeps the pre-class summary), and per class once it has
    /// something to say.
    fn class_summary(&self) -> String {
        let mut s = String::new();
        for class in ServiceClass::ALL {
            let c = &self.classes[class.index()];
            if c.slo_met + c.slo_missed + c.shed + c.admission_refused == 0 {
                continue;
            }
            s.push_str(&format!(
                " | {}: met {}/{} (shed {}, refused {}) p99 {:?}",
                class.label(),
                c.slo_met,
                c.slo_met + c.slo_missed + c.shed,
                c.shed,
                c.admission_refused,
                c.latency.percentile(99.0),
            ));
        }
        s
    }

    /// Deadlines seen across all requests (0 ⇒ the fragment is elided).
    fn deadlined(&self) -> u64 {
        self.deadline_met + self.deadline_missed + self.deadline_shed
    }

    /// Deadline fragment of [`Self::summary`] (empty until a deadlined
    /// request is answered, so best-effort reports stay unchanged).
    fn deadline_summary(&self) -> String {
        if self.deadlined() == 0 {
            return String::new();
        }
        format!(
            " | deadlines met={} missed={} shed={}",
            self.deadline_met, self.deadline_missed, self.deadline_shed
        )
    }

    /// Per-lane fragment of [`Self::summary`] (empty before any request
    /// is routed, so single-path reports stay unchanged).
    fn lane_summary(&self) -> String {
        if self.routed_batch + self.routed_shard == 0 {
            return String::new();
        }
        let mut s = format!(
            " | lanes batch={} shard={}",
            self.routed_batch, self.routed_shard
        );
        if self.shard_leases > 0 {
            s.push_str(&format!(
                " (lease {:.1} cards, {} stolen",
                self.mean_lease(),
                self.shard_cards_stolen
            ));
            if self.lease_wait.count() > 0 {
                s.push_str(&format!(", wait p50 {:?}", self.lease_wait.percentile(50.0)));
            }
            s.push(')');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut l = LatencyStats::default();
        for i in 1..=100u64 {
            l.record(Duration::from_micros(i));
        }
        assert!(l.percentile(50.0) <= l.percentile(90.0));
        assert!(l.percentile(90.0) <= l.percentile(99.0));
        assert_eq!(l.percentile(0.0), Duration::from_micros(1));
        assert_eq!(l.percentile(100.0), Duration::from_micros(100));
        assert_eq!(l.mean(), Duration::from_micros(50));
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::default();
        assert_eq!(l.percentile(99.0), Duration::ZERO);
        assert_eq!(l.mean(), Duration::ZERO);
        let m = Metrics::default();
        assert_eq!(m.simulated_fps(), 0.0);
        assert_eq!(m.wall_fps(), 0.0);
        assert!(m.accuracy().is_none());
    }

    #[test]
    fn simulated_fps_uses_400mhz() {
        let m = Metrics {
            completed: 10,
            sim_cycles: 4_000_000, // 10 frames in 4 M cc → 1 k fps
            ..Default::default()
        };
        assert!((m.simulated_fps() - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics {
            completed: 2,
            batches: 1,
            sim_cycles: 100,
            ..Default::default()
        };
        let b = Metrics {
            completed: 3,
            failed: 1,
            batches: 2,
            sim_cycles: 200,
            correct: 2,
            labelled: 3,
            routed_batch: 2,
            routed_shard: 1,
            shard_leases: 1,
            shard_cards_granted: 3,
            shard_cards_stolen: 1,
            batch_wall: Duration::from_millis(4),
            shard_wall: Duration::from_millis(6),
            deadline_met: 2,
            deadline_missed: 1,
            deadline_shed: 4,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.failed, 1);
        assert_eq!(a.batches, 3);
        assert_eq!(a.sim_cycles, 300);
        assert_eq!(a.accuracy(), Some(2.0 / 3.0));
        assert_eq!(a.routed_batch, 2);
        assert_eq!(a.routed_shard, 1);
        assert_eq!(a.shard_leases, 1);
        assert_eq!(a.mean_lease(), 3.0);
        assert_eq!(a.batch_wall, Duration::from_millis(4));
        assert_eq!(a.shard_wall, Duration::from_millis(6));
        assert_eq!(a.deadline_met, 2);
        assert_eq!(a.deadline_missed, 1);
        assert_eq!(a.deadline_shed, 4);
    }

    #[test]
    fn deadline_summary_only_after_deadlined_traffic() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("deadlines"));
        m.deadline_met = 3;
        m.deadline_shed = 2;
        assert!(m.summary().contains("deadlines met=3 missed=0 shed=2"));
    }

    #[test]
    fn lease_wait_rides_the_lane_summary() {
        let mut m = Metrics {
            routed_shard: 1,
            shard_leases: 1,
            shard_cards_granted: 2,
            ..Default::default()
        };
        assert!(m.summary().contains("lease 2.0 cards, 0 stolen)"));
        assert!(!m.summary().contains("wait p50"));
        m.lease_wait.record(Duration::from_micros(120));
        assert!(m.summary().contains("wait p50"));
    }

    /// The reservoir cap: memory stays bounded under sustained serving,
    /// `count()`/`mean()` stay exact over the full stream, and
    /// percentiles keep reading from inside the observed range.
    #[test]
    fn reservoir_bounds_memory_and_keeps_exact_counts() {
        let mut l = LatencyStats::default();
        let n = (RESERVOIR_CAP * 4) as u64;
        for i in 1..=n {
            l.record(Duration::from_micros(i));
        }
        assert_eq!(l.count() as u64, n, "count reflects the full stream");
        assert!(l.samples_us.len() <= RESERVOIR_CAP, "memory capped");
        assert_eq!(l.mean(), Duration::from_micros((n + 1) / 2), "mean stays exact");
        let p50 = l.percentile(50.0);
        assert!(p50 >= Duration::from_micros(1) && p50 <= Duration::from_micros(n));
        // a uniform sample of 1..=4·CAP should not have its median in
        // either outer quartile — deterministic, the RNG is seeded
        assert!(p50 > Duration::from_micros(n / 4), "{p50:?}");
        assert!(p50 < Duration::from_micros(3 * n / 4), "{p50:?}");
    }

    /// Below the cap the buffer is exact — the tier-1 sample counts all
    /// live here, so existing percentile expectations hold unchanged.
    #[test]
    fn below_the_cap_percentiles_are_exact_and_merge_concatenates() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        for i in 1..=50u64 {
            a.record(Duration::from_micros(i));
            b.record(Duration::from_micros(50 + i));
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert_eq!(a.percentile(0.0), Duration::from_micros(1));
        assert_eq!(a.percentile(100.0), Duration::from_micros(100));
        assert_eq!(a.mean(), Duration::from_micros(50));
    }

    /// Merging two capped streams is *weighted*: each stream's share of
    /// the merged reservoir follows its full stream length, so the
    /// merged percentiles reflect both distributions (merge order must
    /// not matter).
    #[test]
    fn merge_of_capped_streams_is_weighted_fairly() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        let n = (RESERVOIR_CAP * 8) as u64;
        for _ in 0..n {
            a.record(Duration::from_micros(1_000)); // fast worker
            b.record(Duration::from_micros(10_000)); // slow worker
        }
        a.merge(&b);
        assert!(a.samples_us.len() <= RESERVOIR_CAP);
        assert_eq!(a.count() as u64, 2 * n);
        // equal stream lengths ⇒ each holds half the reservoir: the
        // lower quartile is all fast samples, the upper all slow ones
        assert_eq!(a.percentile(25.0), Duration::from_micros(1_000));
        assert_eq!(a.percentile(75.0), Duration::from_micros(10_000));
    }

    /// Merging capped stats keeps the stream totals exact even though
    /// the sample buffers are lossy.
    #[test]
    fn merge_of_capped_stats_keeps_totals() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        let n = (RESERVOIR_CAP * 2) as u64;
        for i in 1..=n {
            a.record(Duration::from_micros(10));
            b.record(Duration::from_micros(i));
        }
        a.merge(&b);
        assert_eq!(a.count() as u64, 2 * n);
        assert!(a.samples_us.len() <= RESERVOIR_CAP);
        let want = (10 * n as u128 + (1..=n as u128).sum::<u128>()) / (2 * n as u128);
        assert_eq!(a.mean(), Duration::from_micros(want as u64));
    }

    #[test]
    fn class_metrics_merge_and_summary_fragment() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("interactive"), "elided without traffic");
        let mut other = Metrics::default();
        let i = ServiceClass::Interactive.index();
        other.classes[i].submitted = 5;
        other.classes[i].completed = 3;
        other.classes[i].slo_met = 2;
        other.classes[i].slo_missed = 1;
        other.classes[i].shed = 1;
        other.classes[i].admission_refused = 1;
        other.classes[i].latency.record(Duration::from_micros(700));
        m.merge(&other);
        m.merge(&other);
        assert_eq!(m.classes[i].slo_met, 4);
        assert_eq!(m.classes[i].submitted, 10);
        assert_eq!(m.classes[i].latency.count(), 2);
        let s = m.summary();
        assert!(s.contains("interactive: met 4/8 (shed 2, refused 2)"), "{s}");
        assert!(!s.contains("bulk:"), "quiet classes stay elided: {s}");
    }

    #[test]
    fn model_metrics_merge_and_summary_fragment() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("gtsrb"), "elided without registry traffic");
        let mut other = Metrics::default();
        let mm = other.models.entry(0).or_default();
        mm.name = "gtsrb".into();
        mm.submitted = 5;
        mm.completed = 4;
        mm.refused = 1;
        mm.latency.record(Duration::from_micros(900));
        let mm1 = other.models.entry(1).or_default();
        mm1.name = "mobilenet".into();
        mm1.submitted = 2;
        mm1.completed = 2;
        m.merge(&other);
        m.merge(&other);
        assert_eq!(m.models[&0].submitted, 10);
        assert_eq!(m.models[&0].completed, 8);
        assert_eq!(m.models[&0].refused, 2);
        assert_eq!(m.models[&0].latency.count(), 2);
        assert_eq!(m.models[&0].name, "gtsrb", "name survives the merge");
        let s = m.summary();
        assert!(s.contains("gtsrb: 8/10 done (refused 2)"), "{s}");
        assert!(s.contains("mobilenet: 4/4 done (refused 0)"), "{s}");
        let g = s.find("gtsrb").unwrap();
        let mn = s.find("mobilenet").unwrap();
        assert!(g < mn, "fragments in id order: {s}");
    }

    #[test]
    fn submitted_and_refused_ride_merge() {
        let mut a = Metrics {
            submitted: 4,
            completed: 2,
            failed: 1,
            admission_refused: 1,
            ..Default::default()
        };
        let b = Metrics {
            submitted: 6,
            completed: 5,
            failed: 0,
            admission_refused: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.submitted, 10);
        assert_eq!(a.admission_refused, 2);
        assert_eq!(
            a.submitted,
            a.completed + a.failed + a.admission_refused,
            "the accounting identity survives merge"
        );
    }

    #[test]
    fn wire_counters_merge_and_summary_fragment() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("wire"), "elided without wire traffic");
        let other = Metrics {
            wire_connections: 2,
            wire_requests: 7,
            wire_protocol_errors: 1,
            ..Default::default()
        };
        m.merge(&other);
        m.merge(&other);
        assert_eq!(m.wire_connections, 4);
        assert_eq!(m.wire_requests, 14);
        assert_eq!(m.wire_protocol_errors, 2);
        assert!(m.summary().contains("wire conns=4 reqs=14 proto_errs=2"));
    }

    #[test]
    fn lane_summary_only_after_routing() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("lanes"));
        m.routed_batch = 3;
        m.routed_shard = 2;
        assert!(m.summary().contains("lanes batch=3 shard=2"));
        m.shard_leases = 2;
        m.shard_cards_granted = 3;
        m.shard_cards_stolen = 1;
        assert!(m.summary().contains("lease 1.5 cards, 1 stolen"));
    }
}
