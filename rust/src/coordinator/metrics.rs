//! Serving metrics: latency distributions and dual-clock throughput.
//!
//! Two clocks matter in this system: the *host* wall clock (how fast the
//! simulator + coordinator actually run) and the *simulated accelerator*
//! clock (cycles × 400 MHz — the number the paper's Table III reports).
//! Both are tracked so the end-to-end example can report "simulated
//! BinArray fps" next to "simulation wall fps".

use std::time::Duration;

/// Streaming latency statistics (exact percentiles from a sorted buffer —
/// request counts here are small enough that a full buffer is fine).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn percentile(&self, p: f64) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        Duration::from_micros(v[idx.min(v.len() - 1)])
    }

    pub fn mean(&self) -> Duration {
        if self.samples_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.samples_us.iter().sum::<u64>() / self.samples_us.len() as u64,
        )
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub latency: LatencyStats,
    /// Queue wait portion of latency.
    pub queue_wait: LatencyStats,
    /// Requests completed.
    pub completed: u64,
    /// Requests that ended in an error reply (bad input, dead card…) —
    /// failures are answered, never dropped, so `completed + failed`
    /// equals requests admitted.
    pub failed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Total simulated accelerator cycles.
    pub sim_cycles: u64,
    /// Total host wall time spent inside the simulator.
    pub sim_wall: Duration,
    /// Correct top-1 predictions (when labels are known).
    pub correct: u64,
    /// Requests with labels.
    pub labelled: u64,
    /// Requests admitted into the whole-frame batching lane.
    pub routed_batch: u64,
    /// Requests admitted into the scatter/gather shard lane.
    pub routed_shard: u64,
    /// Card leases granted to the shard orchestrator.
    pub shard_leases: u64,
    /// Cards granted across all leases (`/ shard_leases` = mean scatter
    /// width actually achieved under the prevailing batch-lane load).
    pub shard_cards_granted: u64,
    /// Cards the shard lane asked for but the batch lane was holding at
    /// grant time — how much scatter width mixed traffic "stole".
    pub shard_cards_stolen: u64,
    /// Wall time the batching lane spent inside the simulator (its share
    /// of `sim_wall` — lane occupancy).
    pub batch_wall: Duration,
    /// Wall time the shard lane spent in scatter/gather frames (its
    /// share of `sim_wall` — lane occupancy).
    pub shard_wall: Duration,
    /// Deadlined requests answered on time.
    pub deadline_met: u64,
    /// Deadlined requests that completed, but late (the frame was
    /// already computing when the deadline passed — still answered Ok).
    pub deadline_missed: u64,
    /// Deadlined requests shed unserved (`InferError::DeadlineExceeded`)
    /// because their deadline expired before any card started them.
    /// Sheds also count into `failed` — every admitted request is
    /// answered exactly once.
    pub deadline_shed: u64,
    /// Wait from a shard-lane lease request to its grant, hysteresis
    /// included (how much latency the orchestrator spent shopping for a
    /// wider lease).
    pub lease_wait: LatencyStats,
}

impl Metrics {
    pub fn merge(&mut self, other: &Metrics) {
        self.latency
            .samples_us
            .extend_from_slice(&other.latency.samples_us);
        self.queue_wait
            .samples_us
            .extend_from_slice(&other.queue_wait.samples_us);
        self.completed += other.completed;
        self.failed += other.failed;
        self.batches += other.batches;
        self.sim_cycles += other.sim_cycles;
        self.sim_wall += other.sim_wall;
        self.correct += other.correct;
        self.labelled += other.labelled;
        self.routed_batch += other.routed_batch;
        self.routed_shard += other.routed_shard;
        self.shard_leases += other.shard_leases;
        self.shard_cards_granted += other.shard_cards_granted;
        self.shard_cards_stolen += other.shard_cards_stolen;
        self.batch_wall += other.batch_wall;
        self.shard_wall += other.shard_wall;
        self.deadline_met += other.deadline_met;
        self.deadline_missed += other.deadline_missed;
        self.deadline_shed += other.deadline_shed;
        self.lease_wait
            .samples_us
            .extend_from_slice(&other.lease_wait.samples_us);
    }

    /// Simulated-accelerator throughput (frames / simulated second at
    /// 400 MHz) — comparable to the paper's Table III.
    pub fn simulated_fps(&self) -> f64 {
        if self.sim_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 * crate::binarray::CLOCK_HZ / self.sim_cycles as f64
    }

    /// Host-side throughput of the simulation (frames / wall second).
    pub fn wall_fps(&self) -> f64 {
        let s = self.sim_wall.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.completed as f64 / s
    }

    pub fn accuracy(&self) -> Option<f64> {
        (self.labelled > 0).then(|| self.correct as f64 / self.labelled as f64)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.completed as f64 / self.batches as f64
    }

    /// Mean cards per shard-lane lease (0 when the lane never leased).
    pub fn mean_lease(&self) -> f64 {
        if self.shard_leases == 0 {
            return 0.0;
        }
        self.shard_cards_granted as f64 / self.shard_leases as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "req={}{} batches={} (avg {:.1}/batch) | sim {:.1} fps @400MHz | wall {:.1} fps | p50 {:?} p99 {:?}{}{}",
            self.completed,
            if self.failed > 0 {
                format!(" (+{} failed)", self.failed)
            } else {
                String::new()
            },
            self.batches,
            self.mean_batch(),
            self.simulated_fps(),
            self.wall_fps(),
            self.latency.percentile(50.0),
            self.latency.percentile(99.0),
            match self.accuracy() {
                Some(a) => format!(" | acc {:.2}%", 100.0 * a),
                None => String::new(),
            },
            self.lane_summary(),
        ) + &self.deadline_summary()
    }

    /// Deadlines seen across all requests (0 ⇒ the fragment is elided).
    fn deadlined(&self) -> u64 {
        self.deadline_met + self.deadline_missed + self.deadline_shed
    }

    /// Deadline fragment of [`Self::summary`] (empty until a deadlined
    /// request is answered, so best-effort reports stay unchanged).
    fn deadline_summary(&self) -> String {
        if self.deadlined() == 0 {
            return String::new();
        }
        format!(
            " | deadlines met={} missed={} shed={}",
            self.deadline_met, self.deadline_missed, self.deadline_shed
        )
    }

    /// Per-lane fragment of [`Self::summary`] (empty before any request
    /// is routed, so single-path reports stay unchanged).
    fn lane_summary(&self) -> String {
        if self.routed_batch + self.routed_shard == 0 {
            return String::new();
        }
        let mut s = format!(
            " | lanes batch={} shard={}",
            self.routed_batch, self.routed_shard
        );
        if self.shard_leases > 0 {
            s.push_str(&format!(
                " (lease {:.1} cards, {} stolen",
                self.mean_lease(),
                self.shard_cards_stolen
            ));
            if self.lease_wait.count() > 0 {
                s.push_str(&format!(", wait p50 {:?}", self.lease_wait.percentile(50.0)));
            }
            s.push(')');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut l = LatencyStats::default();
        for i in 1..=100u64 {
            l.record(Duration::from_micros(i));
        }
        assert!(l.percentile(50.0) <= l.percentile(90.0));
        assert!(l.percentile(90.0) <= l.percentile(99.0));
        assert_eq!(l.percentile(0.0), Duration::from_micros(1));
        assert_eq!(l.percentile(100.0), Duration::from_micros(100));
        assert_eq!(l.mean(), Duration::from_micros(50));
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::default();
        assert_eq!(l.percentile(99.0), Duration::ZERO);
        assert_eq!(l.mean(), Duration::ZERO);
        let m = Metrics::default();
        assert_eq!(m.simulated_fps(), 0.0);
        assert_eq!(m.wall_fps(), 0.0);
        assert!(m.accuracy().is_none());
    }

    #[test]
    fn simulated_fps_uses_400mhz() {
        let m = Metrics {
            completed: 10,
            sim_cycles: 4_000_000, // 10 frames in 4 M cc → 1 k fps
            ..Default::default()
        };
        assert!((m.simulated_fps() - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics {
            completed: 2,
            batches: 1,
            sim_cycles: 100,
            ..Default::default()
        };
        let b = Metrics {
            completed: 3,
            failed: 1,
            batches: 2,
            sim_cycles: 200,
            correct: 2,
            labelled: 3,
            routed_batch: 2,
            routed_shard: 1,
            shard_leases: 1,
            shard_cards_granted: 3,
            shard_cards_stolen: 1,
            batch_wall: Duration::from_millis(4),
            shard_wall: Duration::from_millis(6),
            deadline_met: 2,
            deadline_missed: 1,
            deadline_shed: 4,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.failed, 1);
        assert_eq!(a.batches, 3);
        assert_eq!(a.sim_cycles, 300);
        assert_eq!(a.accuracy(), Some(2.0 / 3.0));
        assert_eq!(a.routed_batch, 2);
        assert_eq!(a.routed_shard, 1);
        assert_eq!(a.shard_leases, 1);
        assert_eq!(a.mean_lease(), 3.0);
        assert_eq!(a.batch_wall, Duration::from_millis(4));
        assert_eq!(a.shard_wall, Duration::from_millis(6));
        assert_eq!(a.deadline_met, 2);
        assert_eq!(a.deadline_missed, 1);
        assert_eq!(a.deadline_shed, 4);
    }

    #[test]
    fn deadline_summary_only_after_deadlined_traffic() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("deadlines"));
        m.deadline_met = 3;
        m.deadline_shed = 2;
        assert!(m.summary().contains("deadlines met=3 missed=0 shed=2"));
    }

    #[test]
    fn lease_wait_rides_the_lane_summary() {
        let mut m = Metrics {
            routed_shard: 1,
            shard_leases: 1,
            shard_cards_granted: 2,
            ..Default::default()
        };
        assert!(m.summary().contains("lease 2.0 cards, 0 stolen)"));
        assert!(!m.summary().contains("wait p50"));
        m.lease_wait.record(Duration::from_micros(120));
        assert!(m.summary().contains("wait p50"));
    }

    #[test]
    fn lane_summary_only_after_routing() {
        let mut m = Metrics::default();
        assert!(!m.summary().contains("lanes"));
        m.routed_batch = 3;
        m.routed_shard = 2;
        assert!(m.summary().contains("lanes batch=3 shard=2"));
        m.shard_leases = 2;
        m.shard_cards_granted = 3;
        m.shard_cards_stolen = 1;
        assert!(m.summary().contains("lease 1.5 cards, 1 stolen"));
    }
}
