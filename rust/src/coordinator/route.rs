//! Per-request dispatch routing — the policy half of hybrid dispatch.
//!
//! BinArray's headline property is that throughput vs. latency is a
//! *runtime* choice (the paper's three design parameters plus §IV-D's
//! dynamic accuracy switching).  The coordinator mirrors that at the
//! request level: every [`crate::coordinator::Request`] is assigned a
//! [`DispatchClass`] when it is admitted — either an explicit override
//! from the caller, or a [`RoutePolicy`] decision from what the router
//! can observe (frame size, current queue depth, and the request's
//! remaining deadline slack) — and the two dispatch lanes run
//! concurrently over one worker pool:
//!
//! * [`DispatchClass::Batch`] — the throughput lane: whole frames are
//!   batched back-to-back onto single cards (amortized DMA, pool
//!   throughput scales with workers);
//! * [`DispatchClass::Shard`] — the latency lane: the frame's row tiles
//!   scatter over the cards the shard orchestrator can lease right now
//!   and gather between layers (frame latency shrinks with cards).
//!
//! Routing is **total and stable**: `classify` is a pure function of its
//! inputs (every `(frame_len, queue_depth, slack)` lands in exactly one
//! lane), the router stamps the class once at admission and never
//! re-examines it, and an explicit override is never reassigned (see
//! [`RoutePolicy::route`]).  Whatever the lane, replies stay
//! bit-identical to [`crate::golden::forward`] — routing moves *where* a
//! frame computes, never *what* it computes.

use std::time::Duration;

/// Which dispatch lane serves a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DispatchClass {
    /// Whole-frame dynamic batching onto a single card (throughput lane).
    Batch,
    /// Cross-card row-tile scatter/gather per frame (latency lane).
    Shard,
}

/// How the router assigns a [`DispatchClass`] to requests that don't
/// carry an explicit override.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Every request takes the batching lane (the pre-hybrid
    /// "`ShardPolicy::Off`" behavior).
    #[default]
    BatchOnly,
    /// Every request takes the shard lane (the pre-hybrid dedicated
    /// "`ShardPolicy::PerFrame`" behavior).
    ShardOnly,
    /// Route by observed load and urgency: while the queue is shallow
    /// (`queue_depth < deep_queue`), a frame goes to the shard lane when
    /// it is big enough for sharding to pay off
    /// (`frame_len ≥ shard_min_len`) **or** its deadline slack is tight
    /// (`slack ≤ tight_slack` — the latency lane is what deadlines buy).
    /// Everything else batches.  A deep queue means the server is in a
    /// throughput regime — spending the whole pool on one frame's
    /// latency while others wait would hurt aggregate latency, so even
    /// urgent frames fall back to batching there.
    Adaptive {
        /// Smallest frame (in input words) worth scattering: below this
        /// the per-layer scatter/gather traffic outweighs the row-tile
        /// parallelism.
        shard_min_len: usize,
        /// Queue depth at which the router stops sharding (`0` = never
        /// shard — the queue is always considered deep).
        deep_queue: usize,
        /// Largest remaining deadline slack that still counts as
        /// "tight" — at or below it a frame takes the shard lane
        /// whatever its size.  `Duration::ZERO` disables the signal for
        /// unexpired requests (and requests without a deadline are
        /// never tight).
        tight_slack: Duration,
    },
}

impl RoutePolicy {
    /// Pick the lane for a request without an explicit class.  Pure and
    /// total: the same `(frame_len, queue_depth, slack)` always yields
    /// the same single lane.  `slack` is the request's remaining
    /// deadline budget at admission (`None` = no deadline).
    pub fn classify(
        &self,
        frame_len: usize,
        queue_depth: usize,
        slack: Option<Duration>,
    ) -> DispatchClass {
        match *self {
            RoutePolicy::BatchOnly => DispatchClass::Batch,
            RoutePolicy::ShardOnly => DispatchClass::Shard,
            RoutePolicy::Adaptive {
                shard_min_len,
                deep_queue,
                tight_slack,
            } => {
                let tight = slack.is_some_and(|s| s <= tight_slack);
                if queue_depth < deep_queue && (frame_len >= shard_min_len || tight) {
                    DispatchClass::Shard
                } else {
                    DispatchClass::Batch
                }
            }
        }
    }

    /// The class a request is admitted under: the explicit override when
    /// the caller set one (never reassigned, whatever the policy says),
    /// otherwise [`Self::classify`].
    pub fn route(
        &self,
        explicit: Option<DispatchClass>,
        frame_len: usize,
        queue_depth: usize,
        slack: Option<Duration>,
    ) -> DispatchClass {
        explicit.unwrap_or_else(|| self.classify(frame_len, queue_depth, slack))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SLACKS: [Option<Duration>; 3] = [
        None,
        Some(Duration::ZERO),
        Some(Duration::from_secs(3600)),
    ];

    #[test]
    fn fixed_policies_ignore_signals() {
        for len in [0usize, 1, 6912, usize::MAX] {
            for depth in [0usize, 7, usize::MAX] {
                for slack in SLACKS {
                    assert_eq!(
                        RoutePolicy::BatchOnly.classify(len, depth, slack),
                        DispatchClass::Batch
                    );
                    assert_eq!(
                        RoutePolicy::ShardOnly.classify(len, depth, slack),
                        DispatchClass::Shard
                    );
                }
            }
        }
    }

    #[test]
    fn adaptive_routes_large_frames_until_queue_deepens() {
        let p = RoutePolicy::Adaptive {
            shard_min_len: 1000,
            deep_queue: 4,
            tight_slack: Duration::ZERO,
        };
        assert_eq!(p.classify(999, 0, None), DispatchClass::Batch, "small frame");
        assert_eq!(p.classify(1000, 0, None), DispatchClass::Shard, "large, idle");
        assert_eq!(p.classify(1000, 3, None), DispatchClass::Shard, "large, shallow");
        assert_eq!(p.classify(1000, 4, None), DispatchClass::Batch, "large, deep");
        // deep_queue = 0: the queue is always deep — sharding never fires
        let never = RoutePolicy::Adaptive {
            shard_min_len: 0,
            deep_queue: 0,
            tight_slack: Duration::from_secs(3600),
        };
        assert_eq!(never.classify(usize::MAX, 0, None), DispatchClass::Batch);
        assert_eq!(
            never.classify(usize::MAX, 0, Some(Duration::ZERO)),
            DispatchClass::Batch,
            "deep queue overrides even a tight deadline"
        );
    }

    #[test]
    fn adaptive_tight_slack_takes_the_latency_lane() {
        let p = RoutePolicy::Adaptive {
            shard_min_len: 1000,
            deep_queue: 4,
            tight_slack: Duration::from_millis(5),
        };
        // small frame, but the deadline is tight ⇒ shard
        assert_eq!(
            p.classify(10, 0, Some(Duration::from_millis(5))),
            DispatchClass::Shard,
            "tight slack"
        );
        assert_eq!(
            p.classify(10, 0, Some(Duration::from_millis(6))),
            DispatchClass::Batch,
            "slack just above the threshold"
        );
        // no deadline is never tight
        assert_eq!(p.classify(10, 0, None), DispatchClass::Batch);
        // a deep queue still wins over urgency
        assert_eq!(
            p.classify(10, 4, Some(Duration::ZERO)),
            DispatchClass::Batch,
            "deep queue"
        );
        // tight_slack = ZERO only fires for already-expired slack — the
        // router sheds those before classify, so the signal is inert
        let inert = RoutePolicy::Adaptive {
            shard_min_len: 1000,
            deep_queue: 4,
            tight_slack: Duration::ZERO,
        };
        assert_eq!(
            inert.classify(10, 0, Some(Duration::from_nanos(1))),
            DispatchClass::Batch
        );
    }

    #[test]
    fn explicit_override_is_never_reassigned() {
        let policies = [
            RoutePolicy::BatchOnly,
            RoutePolicy::ShardOnly,
            RoutePolicy::Adaptive {
                shard_min_len: 64,
                deep_queue: 2,
                tight_slack: Duration::from_millis(1),
            },
        ];
        for p in policies {
            for len in [0usize, 64, 100_000] {
                for depth in [0usize, 2, 50] {
                    for slack in SLACKS {
                        assert_eq!(
                            p.route(Some(DispatchClass::Batch), len, depth, slack),
                            DispatchClass::Batch
                        );
                        assert_eq!(
                            p.route(Some(DispatchClass::Shard), len, depth, slack),
                            DispatchClass::Shard
                        );
                        assert_eq!(p.route(None, len, depth, slack), p.classify(len, depth, slack));
                    }
                }
            }
        }
    }
}
