//! Per-request dispatch routing — the policy half of hybrid dispatch.
//!
//! BinArray's headline property is that throughput vs. latency is a
//! *runtime* choice (the paper's three design parameters plus §IV-D's
//! dynamic accuracy switching).  The coordinator mirrors that at the
//! request level: every [`crate::coordinator::Request`] is assigned a
//! [`DispatchClass`] when it is admitted — either an explicit override
//! from the caller, or a [`RoutePolicy`] decision from what the router
//! can observe (frame size, current queue depth, and the request's
//! remaining deadline slack) — and the two dispatch lanes run
//! concurrently over one worker pool:
//!
//! * [`DispatchClass::Batch`] — the throughput lane: whole frames are
//!   batched back-to-back onto single cards (amortized DMA, pool
//!   throughput scales with workers);
//! * [`DispatchClass::Shard`] — the latency lane: the frame's row tiles
//!   scatter over the cards the shard orchestrator can lease right now
//!   and gather between layers (frame latency shrinks with cards).
//!
//! Routing is **total and stable**: `classify` is a pure function of its
//! inputs (every `(frame_len, queue_depth, slack)` lands in exactly one
//! lane), the router stamps the class once at admission and never
//! re-examines it, and an explicit override is never reassigned (see
//! [`RoutePolicy::route`]).  Whatever the lane, replies stay
//! bit-identical to [`crate::golden::forward`] — routing moves *where* a
//! frame computes, never *what* it computes.

use std::time::{Duration, Instant};

/// Which dispatch lane serves a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DispatchClass {
    /// Whole-frame dynamic batching onto a single card (throughput lane).
    Batch,
    /// Cross-card row-tile scatter/gather per frame (latency lane).
    Shard,
}

/// How the router assigns a [`DispatchClass`] to requests that don't
/// carry an explicit override.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Every request takes the batching lane (the pre-hybrid
    /// "`ShardPolicy::Off`" behavior).
    #[default]
    BatchOnly,
    /// Every request takes the shard lane (the pre-hybrid dedicated
    /// "`ShardPolicy::PerFrame`" behavior).
    ShardOnly,
    /// Route by observed load and urgency: while the queue is shallow
    /// (`queue_depth < deep_queue`), a frame goes to the shard lane when
    /// it is big enough for sharding to pay off
    /// (`frame_len ≥ shard_min_len`) **or** its deadline slack is tight
    /// (`slack ≤ tight_slack` — the latency lane is what deadlines buy).
    /// Everything else batches.  A deep queue means the server is in a
    /// throughput regime — spending the whole pool on one frame's
    /// latency while others wait would hurt aggregate latency, so even
    /// urgent frames fall back to batching there.
    Adaptive {
        /// Smallest frame (in input words) worth scattering: below this
        /// the per-layer scatter/gather traffic outweighs the row-tile
        /// parallelism.
        shard_min_len: usize,
        /// Queue depth at which the router stops sharding (`0` = never
        /// shard — the queue is always considered deep).
        deep_queue: usize,
        /// Largest remaining deadline slack that still counts as
        /// "tight" — at or below it a frame takes the shard lane
        /// whatever its size.  `Duration::ZERO` disables the signal for
        /// unexpired requests (and requests without a deadline are
        /// never tight).
        tight_slack: Duration,
    },
}

impl RoutePolicy {
    /// Pick the lane for a request without an explicit class.  Pure and
    /// total: the same `(frame_len, queue_depth, slack)` always yields
    /// the same single lane.  `slack` is the request's remaining
    /// deadline budget at admission (`None` = no deadline).
    pub fn classify(
        &self,
        frame_len: usize,
        queue_depth: usize,
        slack: Option<Duration>,
    ) -> DispatchClass {
        match *self {
            RoutePolicy::BatchOnly => DispatchClass::Batch,
            RoutePolicy::ShardOnly => DispatchClass::Shard,
            RoutePolicy::Adaptive {
                shard_min_len,
                deep_queue,
                tight_slack,
            } => {
                let tight = slack.is_some_and(|s| s <= tight_slack);
                if queue_depth < deep_queue && (frame_len >= shard_min_len || tight) {
                    DispatchClass::Shard
                } else {
                    DispatchClass::Batch
                }
            }
        }
    }

    /// The class a request is admitted under: the explicit override when
    /// the caller set one (never reassigned, whatever the policy says),
    /// otherwise [`Self::classify`].
    pub fn route(
        &self,
        explicit: Option<DispatchClass>,
        frame_len: usize,
        queue_depth: usize,
        slack: Option<Duration>,
    ) -> DispatchClass {
        explicit.unwrap_or_else(|| self.classify(frame_len, queue_depth, slack))
    }
}

/// Named QoS class of a request — the knob a *caller* turns, as opposed
/// to [`DispatchClass`], which is the knob the *router* turns.  A class
/// bundles a latency SLO, a default dispatch-lane bias, and an admission
/// budget (see [`ClassSpec`]); the concrete values live in the
/// coordinator's [`ClassTable`] so deployments can retune them without
/// touching the request path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ServiceClass {
    /// Tight-SLO traffic (UIs, control loops): admission promises the
    /// SLO or refuses, and SLO-aware arbitration spends cards on it
    /// first when its slack runs low.
    Interactive,
    /// The default class: today's behavior, no SLO unless the table
    /// sets one.
    #[default]
    Standard,
    /// Throughput traffic (backfills, batch scoring): no SLO by
    /// default, biased to the batching lane.
    Bulk,
}

/// Number of service classes (array sizes in the metrics/ledgers).
pub const N_CLASSES: usize = 3;

impl ServiceClass {
    /// All classes, index order (= [`Self::index`]).
    pub const ALL: [ServiceClass; N_CLASSES] =
        [ServiceClass::Interactive, ServiceClass::Standard, ServiceClass::Bulk];

    /// Stable index for per-class arrays, most urgent first.
    pub fn index(self) -> usize {
        match self {
            ServiceClass::Interactive => 0,
            ServiceClass::Standard => 1,
            ServiceClass::Bulk => 2,
        }
    }

    /// Short human label (metrics summaries, CLI output).
    pub fn label(self) -> &'static str {
        match self {
            ServiceClass::Interactive => "interactive",
            ServiceClass::Standard => "standard",
            ServiceClass::Bulk => "bulk",
        }
    }
}

impl std::str::FromStr for ServiceClass {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "interactive" => Ok(ServiceClass::Interactive),
            "standard" => Ok(ServiceClass::Standard),
            "bulk" => Ok(ServiceClass::Bulk),
            other => Err(format!(
                "unknown service class '{other}' (expected interactive|standard|bulk)"
            )),
        }
    }
}

/// Per-class QoS contract: what one [`ServiceClass`] promises and what
/// the coordinator may spend on it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassSpec {
    /// Latency SLO: a request of this class without an explicit deadline
    /// is stamped `submitted + slo` at admission, so the whole deadline
    /// machinery (EDF ordering, shedding, met/missed accounting) applies
    /// per class.  `None` = best effort.
    pub slo: Option<Duration>,
    /// Default dispatch-lane bias: used instead of the [`RoutePolicy`]
    /// decision when the caller didn't pin a [`DispatchClass`] itself
    /// (a per-request override still wins).  `None` = let the policy
    /// decide.
    pub dispatch_bias: Option<DispatchClass>,
    /// Admission budget: most requests of this class admitted but not
    /// yet answered.  At the cap, new work is refused with
    /// `InferError::AdmissionRefused` instead of queued.  `0` =
    /// unlimited.
    pub admission_limit: usize,
}

/// The coordinator's QoS table: one [`ClassSpec`] per [`ServiceClass`].
///
/// The default table keeps `Standard` and `Bulk` SLO-free (exactly the
/// pre-class behavior for every existing caller) and gives `Interactive`
/// a 50 ms SLO; `Bulk` is biased to the batching lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClassTable {
    specs: [ClassSpec; N_CLASSES],
}

impl Default for ClassTable {
    fn default() -> Self {
        let mut specs = [ClassSpec::default(); N_CLASSES];
        specs[ServiceClass::Interactive.index()].slo = Some(Duration::from_millis(50));
        specs[ServiceClass::Bulk.index()].dispatch_bias = Some(DispatchClass::Batch);
        Self { specs }
    }
}

impl ClassTable {
    /// A table with the same spec for every class (tests, single-tenant
    /// deployments).
    pub fn uniform(spec: ClassSpec) -> Self {
        Self { specs: [spec; N_CLASSES] }
    }

    pub fn spec(&self, class: ServiceClass) -> &ClassSpec {
        &self.specs[class.index()]
    }

    /// Replace one class's spec (builder style).
    pub fn with(mut self, class: ServiceClass, spec: ClassSpec) -> Self {
        self.specs[class.index()] = spec;
        self
    }
}

/// Remaining slack of a request *relative to its class SLO* at `now` —
/// the urgency signal SLO-aware cross-lane arbitration compares between
/// lanes.  `0.0` = the budget is spent, `1.0` = the whole budget
/// remains.  A request with an explicit deadline but an SLO-free class
/// is normalized against its own end-to-end budget
/// (`deadline − submitted`); a request with no deadline at all has no
/// SLO urgency (`None` — it never outranks deadlined work).
pub fn relative_slack(
    submitted: Instant,
    deadline: Option<Instant>,
    slo: Option<Duration>,
    now: Instant,
) -> Option<f64> {
    let d = deadline?;
    let budget = slo.unwrap_or_else(|| d.saturating_duration_since(submitted));
    if budget.is_zero() {
        return Some(0.0);
    }
    let left = d.saturating_duration_since(now);
    Some(left.as_secs_f64() / budget.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SLACKS: [Option<Duration>; 3] = [
        None,
        Some(Duration::ZERO),
        Some(Duration::from_secs(3600)),
    ];

    #[test]
    fn fixed_policies_ignore_signals() {
        for len in [0usize, 1, 6912, usize::MAX] {
            for depth in [0usize, 7, usize::MAX] {
                for slack in SLACKS {
                    assert_eq!(
                        RoutePolicy::BatchOnly.classify(len, depth, slack),
                        DispatchClass::Batch
                    );
                    assert_eq!(
                        RoutePolicy::ShardOnly.classify(len, depth, slack),
                        DispatchClass::Shard
                    );
                }
            }
        }
    }

    #[test]
    fn adaptive_routes_large_frames_until_queue_deepens() {
        let p = RoutePolicy::Adaptive {
            shard_min_len: 1000,
            deep_queue: 4,
            tight_slack: Duration::ZERO,
        };
        assert_eq!(p.classify(999, 0, None), DispatchClass::Batch, "small frame");
        assert_eq!(p.classify(1000, 0, None), DispatchClass::Shard, "large, idle");
        assert_eq!(p.classify(1000, 3, None), DispatchClass::Shard, "large, shallow");
        assert_eq!(p.classify(1000, 4, None), DispatchClass::Batch, "large, deep");
        // deep_queue = 0: the queue is always deep — sharding never fires
        let never = RoutePolicy::Adaptive {
            shard_min_len: 0,
            deep_queue: 0,
            tight_slack: Duration::from_secs(3600),
        };
        assert_eq!(never.classify(usize::MAX, 0, None), DispatchClass::Batch);
        assert_eq!(
            never.classify(usize::MAX, 0, Some(Duration::ZERO)),
            DispatchClass::Batch,
            "deep queue overrides even a tight deadline"
        );
    }

    #[test]
    fn adaptive_tight_slack_takes_the_latency_lane() {
        let p = RoutePolicy::Adaptive {
            shard_min_len: 1000,
            deep_queue: 4,
            tight_slack: Duration::from_millis(5),
        };
        // small frame, but the deadline is tight ⇒ shard
        assert_eq!(
            p.classify(10, 0, Some(Duration::from_millis(5))),
            DispatchClass::Shard,
            "tight slack"
        );
        assert_eq!(
            p.classify(10, 0, Some(Duration::from_millis(6))),
            DispatchClass::Batch,
            "slack just above the threshold"
        );
        // no deadline is never tight
        assert_eq!(p.classify(10, 0, None), DispatchClass::Batch);
        // a deep queue still wins over urgency
        assert_eq!(
            p.classify(10, 4, Some(Duration::ZERO)),
            DispatchClass::Batch,
            "deep queue"
        );
        // tight_slack = ZERO only fires for already-expired slack — the
        // router sheds those before classify, so the signal is inert
        let inert = RoutePolicy::Adaptive {
            shard_min_len: 1000,
            deep_queue: 4,
            tight_slack: Duration::ZERO,
        };
        assert_eq!(
            inert.classify(10, 0, Some(Duration::from_nanos(1))),
            DispatchClass::Batch
        );
    }

    #[test]
    fn default_class_table_keeps_standard_best_effort() {
        let t = ClassTable::default();
        assert_eq!(t.spec(ServiceClass::Standard).slo, None, "pre-class behavior");
        assert_eq!(t.spec(ServiceClass::Standard).dispatch_bias, None);
        assert_eq!(t.spec(ServiceClass::Standard).admission_limit, 0);
        assert!(t.spec(ServiceClass::Interactive).slo.is_some());
        assert_eq!(
            t.spec(ServiceClass::Bulk).dispatch_bias,
            Some(DispatchClass::Batch)
        );
        // builder replaces exactly one class
        let tuned = t.with(
            ServiceClass::Bulk,
            ClassSpec {
                slo: Some(Duration::from_secs(5)),
                dispatch_bias: None,
                admission_limit: 7,
            },
        );
        assert_eq!(tuned.spec(ServiceClass::Bulk).admission_limit, 7);
        assert_eq!(tuned.spec(ServiceClass::Standard), t.spec(ServiceClass::Standard));
        // index/ALL agree
        for (i, c) in ServiceClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(c.label().parse::<ServiceClass>().unwrap(), *c);
        }
        assert!("turbo".parse::<ServiceClass>().is_err());
    }

    #[test]
    fn relative_slack_normalizes_against_the_budget() {
        let t0 = Instant::now();
        let ms = Duration::from_millis(1);
        // half the 50 ms SLO budget left at now = submitted + 25 ms
        let r = relative_slack(t0, Some(t0 + 50 * ms), Some(50 * ms), t0 + 25 * ms);
        assert!((r.unwrap() - 0.5).abs() < 1e-9);
        // SLO-free class: normalized against its own deadline budget
        let r = relative_slack(t0, Some(t0 + 100 * ms), None, t0 + 75 * ms);
        assert!((r.unwrap() - 0.25).abs() < 1e-9);
        // expired ⇒ zero, not negative
        assert_eq!(
            relative_slack(t0, Some(t0 + ms), Some(ms), t0 + 5 * ms),
            Some(0.0)
        );
        // degenerate zero budget ⇒ zero (most urgent), not a division
        assert_eq!(relative_slack(t0, Some(t0), None, t0), Some(0.0));
        // no deadline ⇒ no SLO urgency
        assert_eq!(relative_slack(t0, None, Some(ms), t0), None);
    }

    #[test]
    fn explicit_override_is_never_reassigned() {
        let policies = [
            RoutePolicy::BatchOnly,
            RoutePolicy::ShardOnly,
            RoutePolicy::Adaptive {
                shard_min_len: 64,
                deep_queue: 2,
                tight_slack: Duration::from_millis(1),
            },
        ];
        for p in policies {
            for len in [0usize, 64, 100_000] {
                for depth in [0usize, 2, 50] {
                    for slack in SLACKS {
                        assert_eq!(
                            p.route(Some(DispatchClass::Batch), len, depth, slack),
                            DispatchClass::Batch
                        );
                        assert_eq!(
                            p.route(Some(DispatchClass::Shard), len, depth, slack),
                            DispatchClass::Shard
                        );
                        assert_eq!(p.route(None, len, depth, slack), p.classify(len, depth, slack));
                    }
                }
            }
        }
    }
}
