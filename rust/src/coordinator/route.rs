//! Per-request dispatch routing — the policy half of hybrid dispatch.
//!
//! BinArray's headline property is that throughput vs. latency is a
//! *runtime* choice (the paper's three design parameters plus §IV-D's
//! dynamic accuracy switching).  The coordinator mirrors that at the
//! request level: every [`crate::coordinator::Request`] is assigned a
//! [`DispatchClass`] when it is admitted — either an explicit override
//! from the caller, or a [`RoutePolicy`] decision from what the router
//! can observe (frame size, current queue depth) — and the two dispatch
//! lanes run concurrently over one worker pool:
//!
//! * [`DispatchClass::Batch`] — the throughput lane: whole frames are
//!   batched back-to-back onto single cards (amortized DMA, pool
//!   throughput scales with workers);
//! * [`DispatchClass::Shard`] — the latency lane: the frame's row tiles
//!   scatter over the cards the shard orchestrator can lease right now
//!   and gather between layers (frame latency shrinks with cards).
//!
//! Routing is **total and stable**: `classify` is a pure function of its
//! inputs (every `(frame_len, queue_depth)` lands in exactly one lane),
//! the router stamps the class once at admission and never re-examines
//! it, and an explicit override is never reassigned (see
//! [`RoutePolicy::route`]).  Whatever the lane, replies stay
//! bit-identical to [`crate::golden::forward`] — routing moves *where* a
//! frame computes, never *what* it computes.

/// Which dispatch lane serves a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DispatchClass {
    /// Whole-frame dynamic batching onto a single card (throughput lane).
    Batch,
    /// Cross-card row-tile scatter/gather per frame (latency lane).
    Shard,
}

/// How the router assigns a [`DispatchClass`] to requests that don't
/// carry an explicit override.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Every request takes the batching lane (the pre-hybrid
    /// "`ShardPolicy::Off`" behavior).
    #[default]
    BatchOnly,
    /// Every request takes the shard lane (the pre-hybrid dedicated
    /// "`ShardPolicy::PerFrame`" behavior).
    ShardOnly,
    /// Route by observed load: a frame big enough for sharding to pay
    /// off (`frame_len ≥ shard_min_len`) goes to the shard lane while
    /// the queue is shallow (`queue_depth < deep_queue`); everything
    /// else batches.  A deep
    /// queue means the server is in a throughput regime — spending the
    /// whole pool on one frame's latency while others wait would hurt
    /// aggregate latency, so large frames fall back to batching there.
    Adaptive {
        /// Smallest frame (in input words) worth scattering: below this
        /// the per-layer scatter/gather traffic outweighs the row-tile
        /// parallelism.
        shard_min_len: usize,
        /// Queue depth at which the router stops sharding (`0` = never
        /// shard — the queue is always considered deep).
        deep_queue: usize,
    },
}

impl RoutePolicy {
    /// Pick the lane for a request without an explicit class.  Pure and
    /// total: the same `(frame_len, queue_depth)` always yields the same
    /// single lane.
    pub fn classify(&self, frame_len: usize, queue_depth: usize) -> DispatchClass {
        match *self {
            RoutePolicy::BatchOnly => DispatchClass::Batch,
            RoutePolicy::ShardOnly => DispatchClass::Shard,
            RoutePolicy::Adaptive {
                shard_min_len,
                deep_queue,
            } => {
                if frame_len >= shard_min_len && queue_depth < deep_queue {
                    DispatchClass::Shard
                } else {
                    DispatchClass::Batch
                }
            }
        }
    }

    /// The class a request is admitted under: the explicit override when
    /// the caller set one (never reassigned, whatever the policy says),
    /// otherwise [`Self::classify`].
    pub fn route(
        &self,
        explicit: Option<DispatchClass>,
        frame_len: usize,
        queue_depth: usize,
    ) -> DispatchClass {
        explicit.unwrap_or_else(|| self.classify(frame_len, queue_depth))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_policies_ignore_signals() {
        for len in [0usize, 1, 6912, usize::MAX] {
            for depth in [0usize, 7, usize::MAX] {
                assert_eq!(RoutePolicy::BatchOnly.classify(len, depth), DispatchClass::Batch);
                assert_eq!(RoutePolicy::ShardOnly.classify(len, depth), DispatchClass::Shard);
            }
        }
    }

    #[test]
    fn adaptive_routes_large_frames_until_queue_deepens() {
        let p = RoutePolicy::Adaptive {
            shard_min_len: 1000,
            deep_queue: 4,
        };
        assert_eq!(p.classify(999, 0), DispatchClass::Batch, "small frame");
        assert_eq!(p.classify(1000, 0), DispatchClass::Shard, "large, idle");
        assert_eq!(p.classify(1000, 3), DispatchClass::Shard, "large, shallow");
        assert_eq!(p.classify(1000, 4), DispatchClass::Batch, "large, deep");
        // deep_queue = 0: the queue is always deep — sharding never fires
        let never = RoutePolicy::Adaptive {
            shard_min_len: 0,
            deep_queue: 0,
        };
        assert_eq!(never.classify(usize::MAX, 0), DispatchClass::Batch);
    }

    #[test]
    fn explicit_override_is_never_reassigned() {
        let policies = [
            RoutePolicy::BatchOnly,
            RoutePolicy::ShardOnly,
            RoutePolicy::Adaptive {
                shard_min_len: 64,
                deep_queue: 2,
            },
        ];
        for p in policies {
            for len in [0usize, 64, 100_000] {
                for depth in [0usize, 2, 50] {
                    assert_eq!(
                        p.route(Some(DispatchClass::Batch), len, depth),
                        DispatchClass::Batch
                    );
                    assert_eq!(
                        p.route(Some(DispatchClass::Shard), len, depth),
                        DispatchClass::Shard
                    );
                    assert_eq!(p.route(None, len, depth), p.classify(len, depth));
                }
            }
        }
    }
}
