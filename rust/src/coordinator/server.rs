//! The coordinator proper: a routing/arbitration thread plus a worker
//! pool of simulated BinArray instances, serving two dispatch lanes
//! concurrently over the same cards.
//!
//! Topology (one process, std threads — the request path has no Python
//! and no async runtime dependency):
//!
//! ```text
//!   submit() ──mpsc──▶ router thread (stamps DispatchClass, batches,
//!            ▲         arbitrates cards between the lanes)
//!            │              │
//!   WorkerDone/Lease/       ├─ Batch lane: whole batches to free cards
//!   Unlease notifications   │      ─▶ worker 0 (BinArraySystem) ─▶ replies
//!            │              │      ─▶ worker 1 ...
//!            │              └─ Shard lane: frames to the orchestrator
//!            │                     │ lease k free cards from the router
//!            └─────────────────────┤ per layer: scatter k tile jobs to
//!                                  │   the *leased* cards' queues,
//!                                  │   gather tiles into the pong half
//!                                  └ return the lease, answer the caller
//! ```
//!
//! Each worker owns a full simulated accelerator (its own weight BRAM and
//! feature buffers — one "card").  Mode switches (§IV-D) happen per batch
//! by flipping the card's `m_run`.
//!
//! The two lanes trade latency against throughput per *request*, not per
//! coordinator: the batching lane keeps cards busy on *different* frames
//! (throughput scales with workers, per-frame latency is one card's),
//! while the shard lane spends *leased* cards on one frame's row tiles
//! (latency shrinks with the lease width).  The router is the arbiter:
//! cards are leased to the shard orchestrator only while they are not
//! running a batch, and a pending lease has priority over queued batches
//! when a card frees up (the shard lane is the latency lane).  Whatever
//! the lane, replies are bit-identical to [`golden::forward`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::artifacts::QuantNetwork;
use crate::binarray::{
    ArrayConfig, BinArraySystem, ControlUnit, ExecutionPlan, FrameStats, ShardPlan,
    ShardPlanCache, ShardRun, SimStats,
};
use crate::golden;
use crate::isa::{compile_network, Program};
use crate::tensor::scatter_tile;

use super::batcher::{Batch, BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::route::{DispatchClass, RoutePolicy};
use super::{Mode, Request};

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Reply {
    pub id: u64,
    pub logits: Vec<i8>,
    pub class: usize,
    /// Simulated accelerator cycles spent on this frame.
    pub cycles: u64,
    /// End-to-end host latency (submit → reply).
    pub latency: Duration,
    pub mode: Mode,
}

/// A failed inference: the request was admitted but could not be served
/// (malformed image, dead worker pool…).  Failures are *answered* on the
/// reply channel — a bad batch must never strand its callers on
/// `RecvError` or take the worker thread down with it.
#[derive(Clone, Debug)]
pub struct InferError {
    pub id: u64,
    pub reason: String,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {}: {}", self.id, self.reason)
    }
}

impl std::error::Error for InferError {}

/// What arrives on a reply channel: the inference or a per-request error.
pub type ReplyResult = std::result::Result<Reply, InferError>;

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub array: ArrayConfig,
    /// Worker cards in the pool (each a full BinArray instance), shared
    /// by both dispatch lanes.
    pub workers: usize,
    pub policy: BatchPolicy,
    /// How requests *without* an explicit [`DispatchClass`] override are
    /// routed (explicit overrides are always honored).
    pub route: RoutePolicy,
    /// Cap on the cards one shard-lane frame may lease (`0` = the whole
    /// pool).  A frame's actual scatter width is `min(max_shard_cards,
    /// cards not busy in the batch lane, pool size)`, decided per lease.
    pub max_shard_cards: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            array: ArrayConfig::new(1, 8, 2),
            workers: 1,
            policy: BatchPolicy::default(),
            route: RoutePolicy::BatchOnly,
            max_shard_cards: 0,
        }
    }
}

/// Reply channels of one cut batch, in request order.
type ReplyTxs = Vec<Sender<ReplyResult>>;

enum RouterMsg {
    Submit(Request, Sender<ReplyResult>),
    /// A worker finished a batch and is free again.
    WorkerDone(usize),
    /// The shard orchestrator wants up to `want` cards.
    Lease {
        want: usize,
        reply: Sender<Vec<usize>>,
    },
    /// The orchestrator returns leased cards.
    Unlease(Vec<usize>),
    /// The orchestrator discovered a leased card is dead (its channel is
    /// gone): drop it from the pool instead of returning it to `free`.
    Retire(usize),
    /// The orchestrator has drained its queue (shutdown handshake).
    OrchDrained,
    Shutdown,
}

/// One card's slice of one layer of one frame — the scatter payload.
struct ShardJob {
    m_run: Option<usize>,
    layer: usize,
    /// Card index into the lease/[`ShardPlan`] (not a worker id — the
    /// orchestrator maps card `c` onto the `c`-th *leased* worker).
    card: usize,
    /// Host threads this card may spend on the job: the lease width
    /// bounds how many cards compute concurrently, so each card gets its
    /// share of the host cores (the full width on every card would
    /// oversubscribe the host with exactly the thread thrash the latency
    /// path exists to avoid).
    intra_threads: usize,
    /// The partition matching this frame's lease width, from the
    /// [`ShardPlanCache`].
    shards: Arc<ShardPlan>,
    /// The layer's full input region (every card streams the whole ping
    /// half, so convolution windows never straddle a card boundary).
    input: Arc<Vec<i8>>,
    reply: Sender<(usize, Result<ShardRun>)>,
}

enum WorkerMsg {
    Run(Batch, ReplyTxs),
    Shard(ShardJob),
    Shutdown,
}

enum OrchMsg {
    Run(Batch, ReplyTxs),
    Shutdown,
}

/// The shard orchestrator's static state: the compiled program, the
/// execution plan it indexes per layer, and the shard partitions for
/// every possible lease width — built directly at start so the
/// orchestrator doesn't hold a whole card's executor memory just to read
/// schedules.
struct ShardOracle {
    plan: ExecutionPlan,
    prog: Program,
    cache: ShardPlanCache,
    max_m: usize,
    m_arch: usize,
    /// Most cards one frame asks to lease (`min(max_shard_cards, pool)`).
    max_lease: usize,
}

/// Cloneable submit-side handle: many producer threads can feed one
/// coordinator (the `Coordinator` itself stays single-owner so that
/// `shutdown` consumes it).
#[derive(Clone)]
pub struct SubmitHandle {
    router_tx: Sender<RouterMsg>,
    next_id: Arc<AtomicU64>,
}

impl SubmitHandle {
    /// Submit a request; returns a receiver for the reply.  The lane is
    /// picked by the coordinator's [`RoutePolicy`].
    pub fn submit(&self, image: Vec<i8>, mode: Mode) -> Receiver<ReplyResult> {
        self.submit_routed(image, mode, None)
    }

    /// Submit with an explicit dispatch-class override (`None` lets the
    /// [`RoutePolicy`] decide).  An override is final — the router never
    /// reassigns it.
    pub fn submit_routed(
        &self,
        image: Vec<i8>,
        mode: Mode,
        class: Option<DispatchClass>,
    ) -> Receiver<ReplyResult> {
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            mode,
            class,
            submitted: Instant::now(),
        };
        // If the router is gone the receiver will simply yield RecvError.
        let _ = self.router_tx.send(RouterMsg::Submit(req, tx));
        rx
    }

    /// Submit and wait.
    pub fn infer(&self, image: Vec<i8>, mode: Mode) -> Result<Reply> {
        Ok(self.submit(image, mode).recv()??)
    }

    /// Submit with an explicit dispatch class and wait.
    pub fn infer_routed(
        &self,
        image: Vec<i8>,
        mode: Mode,
        class: Option<DispatchClass>,
    ) -> Result<Reply> {
        Ok(self.submit_routed(image, mode, class).recv()??)
    }
}

/// The serving coordinator.
pub struct Coordinator {
    handle: SubmitHandle,
    router: Option<JoinHandle<Metrics>>,
    orchestrator: Option<JoinHandle<Metrics>>,
    workers: Vec<JoinHandle<Metrics>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Spin up the router, `cfg.workers` accelerator workers, and the
    /// shard orchestrator.  Both dispatch lanes are always live — any
    /// request may carry an explicit [`DispatchClass`] override, whatever
    /// the [`RoutePolicy`] says.
    pub fn start(cfg: CoordinatorConfig, net: QuantNetwork) -> Result<Self> {
        if net.layers.is_empty() {
            bail!("empty network");
        }
        let n_workers = cfg.workers.max(1);
        let (router_tx, router_rx) = channel::<RouterMsg>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));

        // One channel per card: the router sends batches only to *free*
        // cards and the orchestrator sends shard jobs only to cards it
        // holds a lease on, so a leased card's queue never mixes lanes.
        let mut worker_txs: Vec<Sender<WorkerMsg>> = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = channel::<WorkerMsg>();
            worker_txs.push(tx);
            let sys = BinArraySystem::new(cfg.array, net.clone())?;
            let global = Arc::clone(&metrics);
            let rtx = router_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("binarray-worker-{w}"))
                    .spawn(move || worker_loop(sys, rx, w, rtx, global))?,
            );
        }

        // The shard plans are deterministic from (config, net, cards), so
        // one cache serves every lease width the pool can grant.
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(cfg.array, &net, &prog);
        let cache = ShardPlanCache::new(&plan, n_workers);
        let max_lease = if cfg.max_shard_cards == 0 {
            n_workers
        } else {
            cfg.max_shard_cards.min(n_workers)
        };
        let oracle = ShardOracle {
            cache,
            plan,
            prog,
            max_m: net.max_m(),
            m_arch: cfg.array.m_arch,
            max_lease,
        };
        let (orch_tx, orch_rx) = channel::<OrchMsg>();
        let orchestrator = {
            let global = Arc::clone(&metrics);
            let rtx = router_tx.clone();
            let wtxs = worker_txs.clone();
            std::thread::Builder::new()
                .name("binarray-shard-orch".into())
                .spawn(move || orchestrator_loop(oracle, orch_rx, rtx, wtxs, global))?
        };

        let router = {
            let state = Router {
                rx: router_rx,
                orch_tx,
                worker_txs,
                policy: cfg.policy,
                route: cfg.route,
                batcher: Batcher::new(cfg.policy),
                reply_txs: ReplyMap::new(),
                free: (0..n_workers).collect(),
                live: n_workers,
                leased: 0,
                pending_batches: VecDeque::new(),
                pending_lease: None,
                shard_inflight: 0,
                shutting: false,
                orch_done: false,
                stalled: 0,
                local: Metrics::default(),
                global: Arc::clone(&metrics),
            };
            std::thread::Builder::new()
                .name("binarray-router".into())
                .spawn(move || state.run())?
        };

        Ok(Self {
            handle: SubmitHandle {
                router_tx,
                next_id: Arc::new(AtomicU64::new(0)),
            },
            router: Some(router),
            orchestrator: Some(orchestrator),
            workers,
            metrics,
        })
    }

    /// A cloneable submit handle for producer threads.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    /// Submit a request; returns a receiver for the reply.
    pub fn submit(&self, image: Vec<i8>, mode: Mode) -> Receiver<ReplyResult> {
        self.handle.submit(image, mode)
    }

    /// Submit with an explicit dispatch-class override.
    pub fn submit_routed(
        &self,
        image: Vec<i8>,
        mode: Mode,
        class: Option<DispatchClass>,
    ) -> Receiver<ReplyResult> {
        self.handle.submit_routed(image, mode, class)
    }

    /// Submit and wait.
    pub fn infer(&self, image: Vec<i8>, mode: Mode) -> Result<Reply> {
        self.handle.infer(image, mode)
    }

    /// Submit with an explicit dispatch class and wait.
    pub fn infer_routed(
        &self,
        image: Vec<i8>,
        mode: Mode,
        class: Option<DispatchClass>,
    ) -> Result<Reply> {
        self.handle.infer_routed(image, mode, class)
    }

    /// Drain and stop all threads, returning the final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.handle.router_tx.send(RouterMsg::Shutdown);
        let mut total = Metrics::default();
        // The router exits only after the orchestrator has drained and
        // every queued batch has been handed to a card, then tells the
        // workers to stop — so joining it first is safe and total.
        if let Some(r) = self.router.take() {
            if let Ok(m) = r.join() {
                total.merge(&m);
            }
        }
        if let Some(o) = self.orchestrator.take() {
            if let Ok(m) = o.join() {
                total.merge(&m);
            }
        }
        for w in self.workers.drain(..) {
            if let Ok(m) = w.join() {
                total.merge(&m);
            }
        }
        total
    }
}

/// Registered reply channels keyed by request id.
type ReplyMap = std::collections::HashMap<u64, Sender<ReplyResult>>;

/// The orchestrator's parked request for cards.
struct PendingLease {
    want: usize,
    reply: Sender<Vec<usize>>,
}

/// The router thread's state: admission (classify + batch), the card
/// ledger (which workers are free, busy batching, or leased out), and
/// the shutdown drain.
struct Router {
    rx: Receiver<RouterMsg>,
    orch_tx: Sender<OrchMsg>,
    worker_txs: Vec<Sender<WorkerMsg>>,
    policy: BatchPolicy,
    route: RoutePolicy,
    batcher: Batcher,
    reply_txs: ReplyMap,
    /// Card ledger: worker ids neither batching nor leased.
    free: Vec<usize>,
    /// Workers not yet discovered dead (a send to a panicked worker's
    /// channel fails; the card is then dropped from the pool).
    live: usize,
    /// Cards currently out on lease to the shard orchestrator.
    leased: usize,
    /// Batch-lane work waiting for a free card.
    pending_batches: VecDeque<(Batch, ReplyTxs)>,
    /// Shard-lane lease waiting for a free card (at most one: the
    /// orchestrator leases one frame at a time).
    pending_lease: Option<PendingLease>,
    /// Shard frames handed to the orchestrator and not yet finished
    /// (its queue is invisible to the router, so this is the shard
    /// lane's contribution to the queue-depth signal).
    shard_inflight: usize,
    shutting: bool,
    orch_done: bool,
    /// Consecutive silent ticks while shutting (see the stall valve in
    /// [`Self::run`]).
    stalled: u32,
    local: Metrics,
    global: Arc<Mutex<Metrics>>,
}

/// Shutdown stall valve: after this many consecutive silent 1-second
/// ticks with the drain still blocked, the remaining cards are presumed
/// dead (panicked mid-work, so their WorkerDone will never come) and the
/// parked work is answered with errors instead of wedging `shutdown()`
/// forever.  Generous on purpose: a healthy drain produces router
/// traffic far more often than once a minute.
const SHUTDOWN_STALL_TICKS: u32 = 60;

impl Router {
    fn run(mut self) -> Metrics {
        loop {
            // Deadline-driven wait: block indefinitely when idle;
            // otherwise sleep exactly until the oldest request's
            // max_delay expires.  (A fixed polling tick burns the core
            // the workers need — it cost ~20 % end-to-end on a
            // single-core host; EXPERIMENTS.md §Perf.)  While shutting,
            // tick once a second so a dead pool cannot wedge the drain.
            let msg = if self.shutting {
                self.rx.recv_timeout(Duration::from_secs(1))
            } else if self.batcher.pending() == 0 {
                self.rx.recv().map_err(|_| RecvTimeoutError::Disconnected)
            } else {
                self.rx
                    .recv_timeout(self.policy.max_delay.min(Duration::from_millis(50)))
            };
            if msg.is_ok() {
                self.stalled = 0;
            }
            match msg {
                Ok(RouterMsg::Submit(req, tx)) => self.admit(req, tx),
                Ok(RouterMsg::WorkerDone(w)) => {
                    self.free.push(w);
                    self.service();
                }
                Ok(RouterMsg::Lease { want, reply }) => {
                    debug_assert!(self.pending_lease.is_none(), "one orchestrator, one lease");
                    self.pending_lease = Some(PendingLease { want, reply });
                    self.service();
                }
                Ok(RouterMsg::Unlease(ids)) => {
                    // one Unlease per shard frame, lease width aside
                    self.shard_inflight = self.shard_inflight.saturating_sub(1);
                    self.leased = self.leased.saturating_sub(ids.len());
                    self.free.extend(ids);
                    self.service();
                }
                Ok(RouterMsg::Retire(_)) => {
                    // the orchestrator found a leased card dead: it
                    // leaves the pool instead of rejoining `free`
                    self.leased = self.leased.saturating_sub(1);
                    self.live = self.live.saturating_sub(1);
                    if self.live == 0 {
                        self.fail_pending("worker pool is gone");
                    }
                    self.service();
                }
                Ok(RouterMsg::OrchDrained) => self.orch_done = true,
                Ok(RouterMsg::Shutdown) => self.begin_shutdown(),
                Err(RecvTimeoutError::Disconnected) => {
                    if self.shutting {
                        // every sender is gone mid-drain: nothing more
                        // can arrive, stop instead of spinning
                        break;
                    }
                    self.begin_shutdown();
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.shutting {
                        self.stalled += 1;
                        if self.stalled >= SHUTDOWN_STALL_TICKS {
                            // Whatever is still outstanding will never
                            // finish (dead cards / dead orchestrator):
                            // answer what can be answered and let the
                            // drain conditions fall through.
                            self.fail_pending("worker pool stalled during shutdown");
                            self.leased = 0;
                            self.orch_done = true;
                        }
                    }
                }
            }
            let now = Instant::now();
            while let Some(batch) = self.batcher.cut(now) {
                self.dispatch_cut(batch);
            }
            // Drained: orchestrator dry, every batch handed to a card,
            // every lease returned — the pool can stop.
            if self.shutting
                && self.orch_done
                && self.pending_lease.is_none()
                && self.pending_batches.is_empty()
                && self.leased == 0
            {
                break;
            }
        }
        for tx in &self.worker_txs {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        self.local
    }

    /// Classify and queue one request (or refuse it mid-shutdown).  The
    /// class is stamped exactly once here; the batcher and dispatch never
    /// reassign it.
    fn admit(&mut self, mut req: Request, tx: Sender<ReplyResult>) {
        if self.shutting {
            let mut delta = Metrics::default();
            send_error(&mut delta, req.id, &tx, &anyhow!("coordinator is shutting down"));
            self.note(delta);
            return;
        }
        // The queue depth feeding Adaptive routing counts everything
        // admitted but not finished that the batcher alone can't see:
        // cut batches parked for a free card AND shard frames queued on
        // the (serial) orchestrator.  Under overload the real backlog
        // lives there, and ignoring it would keep the router sharding
        // in exactly the throughput regime `deep_queue` exists to
        // detect.
        let backlog: usize = self.pending_batches.iter().map(|(b, _)| b.requests.len()).sum();
        let depth = self.batcher.pending() + backlog + self.shard_inflight;
        let class = self.route.route(req.class, req.image.len(), depth);
        req.class = Some(class);
        let mut delta = Metrics::default();
        match class {
            DispatchClass::Batch => delta.routed_batch = 1,
            DispatchClass::Shard => delta.routed_shard = 1,
        }
        self.note(delta);
        self.reply_txs.insert(req.id, tx);
        self.batcher.push(req);
    }

    /// Hand a cut batch to its lane.
    fn dispatch_cut(&mut self, batch: Batch) {
        let txs: ReplyTxs = batch
            .requests
            .iter()
            .map(|r| self.reply_txs.remove(&r.id).expect("reply channel registered"))
            .collect();
        match batch.class {
            DispatchClass::Batch => self.dispatch_batch(batch, txs),
            DispatchClass::Shard => {
                let n = batch.requests.len();
                if let Err(e) = self.orch_tx.send(OrchMsg::Run(batch, txs)) {
                    let OrchMsg::Run(b, t) = e.0 else { unreachable!() };
                    self.fail_batch(b, t, "shard orchestrator is gone");
                } else {
                    self.shard_inflight += n;
                }
            }
        }
    }

    /// Send a batch to a free card, or park it until one frees up.
    fn dispatch_batch(&mut self, mut batch: Batch, mut txs: ReplyTxs) {
        while let Some(w) = self.free.pop() {
            match self.worker_txs[w].send(WorkerMsg::Run(batch, txs)) {
                Ok(()) => return,
                Err(e) => {
                    // card `w` is dead (panicked thread): drop it from
                    // the pool and try the next free card
                    self.live = self.live.saturating_sub(1);
                    let WorkerMsg::Run(b, t) = e.0 else { unreachable!() };
                    batch = b;
                    txs = t;
                }
            }
        }
        if self.live == 0 {
            self.fail_batch(batch, txs, "worker pool is gone");
            // nothing parked can ever run either — a pending lease left
            // waiting here would hang the orchestrator and its clients
            self.fail_pending("worker pool is gone");
        } else {
            self.pending_batches.push_back((batch, txs));
        }
    }

    /// A card freed up (or a lease/batch is newly pending): grant the
    /// pending lease first — the shard lane is the latency lane — then
    /// drain parked batches onto the remaining free cards.
    fn service(&mut self) {
        if let Some(pl) = self.pending_lease.take() {
            if self.free.is_empty() {
                self.pending_lease = Some(pl);
            } else {
                self.grant_lease(pl);
            }
        }
        while !self.free.is_empty() {
            let Some((batch, txs)) = self.pending_batches.pop_front() else {
                break;
            };
            self.dispatch_batch(batch, txs);
        }
    }

    /// Grant as many free cards as the lease wants, without waiting for
    /// busy ones: the shard lane adapts its scatter width to what the
    /// batch lane left over (a 1-card grant is the degenerate single-card
    /// shard — still bit-exact, just no latency win).
    fn grant_lease(&mut self, pl: PendingLease) {
        debug_assert!(!self.free.is_empty());
        let k = pl.want.clamp(1, self.free.len());
        let ids: Vec<usize> = self.free.split_off(self.free.len() - k);
        match pl.reply.send(ids) {
            Ok(()) => self.leased += k,
            // orchestrator died mid-request: keep the cards
            Err(e) => self.free.extend(e.0),
        }
    }

    /// Answer everything parked on cards that will never free up: every
    /// pending batch errors out, and a pending lease gets an empty grant
    /// (the orchestrator answers its frame with an error and drains on).
    fn fail_pending(&mut self, reason: &str) {
        while let Some((batch, txs)) = self.pending_batches.pop_front() {
            self.fail_batch(batch, txs, reason);
        }
        if let Some(pl) = self.pending_lease.take() {
            let _ = pl.reply.send(Vec::new());
        }
    }

    /// Answer every request of an undeliverable batch with an error.
    fn fail_batch(&mut self, batch: Batch, txs: ReplyTxs, reason: &str) {
        let mut delta = Metrics::default();
        let e = anyhow!("{reason}");
        for (req, tx) in batch.requests.into_iter().zip(&txs) {
            send_error(&mut delta, req.id, tx, &e);
        }
        self.note(delta);
    }

    /// Flush the batcher and start the drain; the exit condition in
    /// [`Self::run`] stops the pool once both lanes are dry.
    fn begin_shutdown(&mut self) {
        if self.shutting {
            return;
        }
        self.shutting = true;
        for batch in self.batcher.flush() {
            self.dispatch_cut(batch);
        }
        let _ = self.orch_tx.send(OrchMsg::Shutdown);
    }

    /// Record a metrics delta locally and in the live global view.
    fn note(&mut self, delta: Metrics) {
        self.local.merge(&delta);
        if let Ok(mut g) = self.global.lock() {
            g.merge(&delta);
        }
    }
}

/// Record one successful frame into `delta` and answer its caller.
fn send_reply(
    delta: &mut Metrics,
    req: Request,
    tx: &Sender<ReplyResult>,
    logits: Vec<i8>,
    cycles: u64,
    compute_wall: Duration,
) {
    let latency = req.submitted.elapsed();
    delta.completed += 1;
    delta.sim_cycles += cycles;
    delta.latency.record(latency);
    // Queue wait = time from submit until this request's compute began
    // (replies land after the compute, so the compute wall is not wait).
    delta.queue_wait.record(latency.saturating_sub(compute_wall));
    let reply = Reply {
        id: req.id,
        class: golden::argmax(&logits),
        logits,
        cycles,
        latency,
        mode: req.mode,
    };
    let _ = tx.send(Ok(reply));
}

fn send_error(delta: &mut Metrics, id: u64, tx: &Sender<ReplyResult>, e: &anyhow::Error) {
    delta.failed += 1;
    let _ = tx.send(Err(InferError {
        id,
        reason: format!("{e:#}"),
    }));
}

fn worker_loop(
    mut sys: BinArraySystem,
    rx: Receiver<WorkerMsg>,
    id: usize,
    router_tx: Sender<RouterMsg>,
    global: Arc<Mutex<Metrics>>,
) -> Metrics {
    let mut local = Metrics::default();
    let max_m = sys.net.max_m();
    let m_arch = sys.cfg.m_arch;
    let full_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    loop {
        let Ok(msg) = rx.recv() else { break };
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Shard(job) => {
                // Leased to the shard orchestrator: this card's share of
                // the host cores is bounded by the lease width (stamped
                // on the job), so concurrent cards don't thrash the host.
                sys.set_host_threads(job.intra_threads);
                sys.set_mode(job.m_run);
                let shard = &job.shards.mode(job.m_run)[job.layer].cards[job.card];
                let res = sys.run_shard(job.layer, &job.input, shard);
                // The orchestrator counts one reply per dispatched job;
                // errors must be answered like results.  No WorkerDone
                // here — the orchestrator returns the whole lease itself.
                let _ = job.reply.send((job.card, res));
            }
            WorkerMsg::Run(batch, txs) => {
                sys.set_host_threads(full_threads);
                // §IV-D: one mode switch per batch, not per frame.
                let m_run = batch.mode.m_run(max_m, m_arch);
                sys.set_mode(Some(m_run));
                let mut delta = Metrics::default();
                delta.batches += 1;
                // Answer malformed requests up front (the only way a
                // request alone can sink `run_frames`), so a poisoned
                // frame never costs its batchmates any compute — and
                // never kills this worker, stranding callers on
                // RecvError.
                let want_len = sys.input_shape.len();
                let mut good: Vec<(Request, &Sender<ReplyResult>)> = Vec::new();
                for (req, tx) in batch.requests.into_iter().zip(&txs) {
                    if req.image.len() == want_len {
                        good.push((req, tx));
                    } else {
                        let e = anyhow!("image len {} != {want_len}", req.image.len());
                        send_error(&mut delta, req.id, tx, &e);
                    }
                }
                // The surviving batch runs back-to-back on the
                // precomputed plan — one `run_frames` call, zero
                // per-frame setup.
                let images: Vec<&[i8]> = good.iter().map(|(r, _)| r.image.as_slice()).collect();
                let t0 = Instant::now();
                match sys.run_frames(&images) {
                    Ok(results) => {
                        let batch_wall = t0.elapsed();
                        for ((req, tx), (logits, stats)) in good.into_iter().zip(results) {
                            send_reply(&mut delta, req, tx, logits, stats.cycles, batch_wall);
                        }
                        delta.sim_wall += batch_wall;
                        delta.batch_wall += batch_wall;
                    }
                    Err(_) => {
                        // Defense in depth for failures validation can't
                        // see: retry frames one by one so whatever frame
                        // is poisoned errors alone.
                        for (req, tx) in good {
                            let t1 = Instant::now();
                            match sys.run_frames(&[&req.image]) {
                                Ok(mut rs) => {
                                    let (logits, stats) = rs.pop().expect("one frame in/out");
                                    let wall = t1.elapsed();
                                    send_reply(&mut delta, req, tx, logits, stats.cycles, wall);
                                    delta.sim_wall += wall;
                                    delta.batch_wall += wall;
                                }
                                Err(e) => send_error(&mut delta, req.id, tx, &e),
                            }
                        }
                    }
                }
                local.merge(&delta);
                if let Ok(mut g) = global.lock() {
                    g.merge(&delta); // live view across all workers
                }
                // Tell the arbiter this card is free again.
                let _ = router_tx.send(RouterMsg::WorkerDone(id));
            }
        }
    }
    local
}

/// The shard orchestrator: owns each in-flight frame's CU and ping-pong
/// feature buffer, leases cards from the router per frame, scatters every
/// layer's row tiles to the leased cards' queues, and gathers the output
/// tiles back before triggering the next layer.  The CU is the same state
/// machine the in-card executor uses, so instruction-cycle accounting is
/// identical on both paths.
fn orchestrator_loop(
    oracle: ShardOracle,
    rx: Receiver<OrchMsg>,
    router_tx: Sender<RouterMsg>,
    worker_txs: Vec<Sender<WorkerMsg>>,
    global: Arc<Mutex<Metrics>>,
) -> Metrics {
    let mut local = Metrics::default();
    let mut cu = ControlUnit::new();
    cu.park_at(oracle.prog.entry);
    let mut fbuf = vec![0i8; oracle.prog.fbuf_words];
    // Recycled DMA-broadcast buffers (see `run_sharded_frame`).
    let mut spare: Vec<Vec<i8>> = Vec::new();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    loop {
        let Ok(msg) = rx.recv() else { break };
        match msg {
            OrchMsg::Shutdown => break,
            OrchMsg::Run(batch, txs) => {
                let m_run = Some(batch.mode.m_run(oracle.max_m, oracle.m_arch));
                let mut delta = Metrics::default();
                delta.batches += 1;
                for (req, tx) in batch.requests.into_iter().zip(&txs) {
                    // Lease cards: however many of the pool the batch
                    // lane isn't holding right now (≥ 1, ≤ max_lease).
                    let want = oracle.max_lease;
                    let (lease_tx, lease_rx) = channel::<Vec<usize>>();
                    let lease_req = RouterMsg::Lease {
                        want,
                        reply: lease_tx,
                    };
                    let granted: Vec<usize> = if router_tx.send(lease_req).is_ok() {
                        lease_rx.recv().unwrap_or_default()
                    } else {
                        Vec::new()
                    };
                    if granted.is_empty() {
                        let e = anyhow!("no cards to lease (router gone or pool dead)");
                        send_error(&mut delta, req.id, tx, &e);
                        continue;
                    }
                    delta.shard_leases += 1;
                    delta.shard_cards_granted += granted.len() as u64;
                    delta.shard_cards_stolen += (want - granted.len().min(want)) as u64;
                    let t0 = Instant::now();
                    let mut dead = Vec::new();
                    let res = run_sharded_frame(
                        &oracle,
                        &mut cu,
                        &mut fbuf,
                        &mut spare,
                        &worker_txs,
                        &granted,
                        &mut dead,
                        &req.image,
                        m_run,
                        cores,
                    );
                    let frame_wall = t0.elapsed();
                    // Cards whose channel is gone are retired from the
                    // pool; only live cards rejoin the free list (a dead
                    // card handed back would be re-leased and fail every
                    // later frame it lands in).
                    let live: Vec<usize> =
                        granted.into_iter().filter(|w| !dead.contains(w)).collect();
                    for w in dead {
                        let _ = router_tx.send(RouterMsg::Retire(w));
                    }
                    let _ = router_tx.send(RouterMsg::Unlease(live));
                    match res {
                        Ok((logits, stats)) => {
                            send_reply(&mut delta, req, tx, logits, stats.cycles, frame_wall);
                            delta.sim_wall += frame_wall;
                            delta.shard_wall += frame_wall;
                        }
                        Err(e) => send_error(&mut delta, req.id, tx, &e),
                    }
                }
                local.merge(&delta);
                if let Ok(mut g) = global.lock() {
                    g.merge(&delta);
                }
            }
        }
    }
    // Tell the router the shard lane is dry — it stops the workers once
    // the batch lane has drained too.
    let _ = router_tx.send(RouterMsg::OrchDrained);
    local
}

/// Run one frame scattered over the leased cards.  Per layer: enqueue one
/// [`ShardJob`] per card with work, then stitch every returned tile into
/// the pong half.  Frame cycles = CU instruction cycles + Σ max-over-cards
/// layer walls — the latency of a machine as wide as the lease.
///
/// The per-card input broadcast is double-buffered: while layer N's
/// gather is collecting tiles, each arriving tile is also scattered into
/// the buffer that becomes layer N+1's broadcast (chained layers share
/// the region — N's `out_base/out_len` are N+1's `in_base/in_len`).  The
/// serial copy-the-ping-half pass PR 2 ran between layers is gone: the
/// scatter copy overlaps the cards' compute and the gather.
#[allow(clippy::too_many_arguments)]
fn run_sharded_frame(
    oracle: &ShardOracle,
    cu: &mut ControlUnit,
    fbuf: &mut [i8],
    spare: &mut Vec<Vec<i8>>,
    worker_txs: &[Sender<WorkerMsg>],
    leased: &[usize],
    dead: &mut Vec<usize>,
    image: &[i8],
    m_run: Option<usize>,
    cores: usize,
) -> Result<(Vec<i8>, FrameStats)> {
    let n_cards = leased.len();
    let shards = oracle.cache.cards(n_cards);
    let intra_threads = (cores / n_cards.max(1)).max(1);
    let mode = oracle.plan.mode(m_run);
    let layer_shards = shards.mode(m_run);
    let n_layers = mode.layers.len();
    let first = mode.layers.first().expect("non-empty plan");
    if image.len() != first.in_len {
        return Err(anyhow!("image len {} != {}", image.len(), first.in_len));
    }
    fbuf[first.in_base..first.in_base + first.in_len].copy_from_slice(image);

    let mut stats = FrameStats {
        // In shard mode the per-unit stats aggregate per *card* (each
        // card is a whole array; mapping cards onto one card's physical
        // SAs would be meaningless).
        sa_stats: vec![SimStats::default(); n_cards],
        ..Default::default()
    };
    let mut err: Option<anyhow::Error> = None;
    // The next layer's input copy, built during this layer's gather.
    let mut next_bcast: Option<Vec<i8>> = None;

    let layer_cycles = &mut stats.layer_cycles;
    let sa_stats = &mut stats.sa_stats;
    let err_ref = &mut err;
    let next_ref = &mut next_bcast;
    let cu_run = cu.run_frame(&oracle.prog, |lr| {
        if err_ref.is_some() {
            // A card already failed: fall through the remaining layers
            // without dispatching work so the CU still reaches its HLT.
            layer_cycles.push(0);
            return 0;
        }
        let li = lr.layer_id as usize;
        let lp = &mode.layers[li];
        // Broadcast: the input copy built during the previous layer's
        // gather, or — first layer — lifted from the feature buffer.
        let input = Arc::new(match next_ref.take() {
            Some(buf) => buf,
            None => fbuf[lp.in_base..lp.in_base + lp.in_len].to_vec(),
        });
        debug_assert_eq!(input.len(), lp.in_len);
        // Scatter: one tile job per leased card.  The reply channel is
        // per layer, and the orchestrator's own tx is dropped right
        // after the scatter — so a worker that dies without answering
        // surfaces as a recv disconnect (an error reply), never as a
        // gather that blocks forever.
        let (reply_tx, reply_rx) = channel::<(usize, Result<ShardRun>)>();
        let mut sent = 0usize;
        for (card, shard) in layer_shards[li].cards.iter().enumerate() {
            if shard.n_units() == 0 {
                continue; // layer too small for this card — it idles
            }
            let job = ShardJob {
                m_run,
                layer: li,
                card,
                intra_threads,
                shards: Arc::clone(shards),
                input: Arc::clone(&input),
                reply: reply_tx.clone(),
            };
            if worker_txs[leased[card]].send(WorkerMsg::Shard(job)).is_err() {
                dead.push(leased[card]);
                *err_ref = Some(anyhow!("leased card {card} is gone"));
                layer_cycles.push(0);
                return 0;
            }
            sent += 1;
        }
        drop(reply_tx);
        // Gather: exactly `sent` replies belong to this layer (each job
        // answers once, success or error), stitched into the pong half —
        // and, overlapped, into the next layer's broadcast buffer.
        let out = &mut fbuf[lp.out_base..lp.out_base + lp.out_len];
        let mut nb: Option<Vec<i8>> = if li + 1 < n_layers {
            let mut b = spare.pop().unwrap_or_default();
            b.clear();
            b.resize(lp.out_len, 0);
            Some(b)
        } else {
            None
        };
        let mut wall = 0u64;
        for _ in 0..sent {
            match reply_rx.recv() {
                Ok((card, Ok(run))) => {
                    for t in &run.tiles {
                        scatter_tile(lp.out_shape, out, t.rows.clone(), t.chans.clone(), &t.data);
                        if let Some(b) = nb.as_mut() {
                            scatter_tile(lp.out_shape, b, t.rows.clone(), t.chans.clone(), &t.data);
                        }
                    }
                    wall = wall.max(run.wall);
                    sa_stats[card].add(run.stats);
                }
                Ok((card, Err(e))) => {
                    err_ref.get_or_insert(anyhow!("card {card}, layer {li}: {e:#}"));
                }
                Err(_) => {
                    // every sender is gone but replies are missing — a
                    // worker died mid-job without answering
                    err_ref.get_or_insert(anyhow!("layer {li}: a card died before replying"));
                    break;
                }
            }
        }
        // Recycle this layer's broadcast once every card has dropped its
        // clone (a card may still hold one for a beat; skip quietly).
        if let Ok(buf) = Arc::try_unwrap(input) {
            spare.push(buf);
        }
        *next_ref = nb;
        layer_cycles.push(wall);
        wall
    });
    stats.instr_cycles = cu_run.instr_cycles;
    stats.cycles = cu_run.total_cycles();

    if let Some(e) = err {
        return Err(e);
    }
    let last = mode.layers.last().expect("non-empty plan");
    let logits = fbuf[last.out_base..last.out_base + last.out_len].to_vec();
    Ok((logits, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::tensor::Shape;
    use crate::util::{prop, rng::Xoshiro256};

    fn quick_cfg(workers: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
            },
            route: RoutePolicy::BatchOnly,
            max_shard_cards: 0,
        }
    }

    fn shard_cfg(cards: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers: cards,
            policy: BatchPolicy::default(),
            route: RoutePolicy::ShardOnly,
            max_shard_cards: 0,
        }
    }

    #[test]
    fn serves_and_matches_golden() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let reply = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(reply.logits, want);
        assert_eq!(reply.class, golden::argmax(&want));
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.routed_batch, 1);
        assert_eq!(m.routed_shard, 0);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(2), net).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                coord.submit(prop::i8_vec(&mut rng, 48 * 48 * 3), Mode::HighAccuracy)
            })
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            ids.push(rx.recv().unwrap().unwrap().id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<u64>>());
        let m = coord.shutdown();
        assert_eq!(m.completed, 12);
        assert!(m.batches >= 3, "12 reqs / max_batch 4 ⇒ ≥3 batches");
    }

    #[test]
    fn mode_switch_serves_both_modes() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 4); // M=4 on M_arch=2
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let fast = coord.infer(img.clone(), Mode::HighThroughput).unwrap();
        let slow = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        assert!(slow.cycles > fast.cycles * 3 / 2, "{} vs {}", slow.cycles, fast.cycles);
        let want_fast = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        assert_eq!(fast.logits, want_fast);
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy {
                    max_batch: 64,
                    max_delay: Duration::from_secs(60), // never ripe on its own
                },
                ..quick_cfg(1)
            },
            net,
        )
        .unwrap();
        let rxs: Vec<_> = (0..3)
            .map(|_| coord.submit(prop::i8_vec(&mut rng, 48 * 48 * 3), Mode::HighAccuracy))
            .collect();
        std::thread::sleep(Duration::from_millis(5));
        let m = coord.shutdown(); // flush must run the stragglers
        assert_eq!(m.completed, 3);
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn failing_request_gets_error_reply_not_hang() {
        let mut rng = Xoshiro256::new(5);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(1), net).unwrap();
        // Wrong-size image: the worker must answer Err, stay alive, and
        // keep serving its batchmates.
        let bad = coord.submit(vec![0i8; 7], Mode::HighAccuracy);
        let good_img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let good = coord.submit(good_img, Mode::HighAccuracy);
        let bad_reply = bad.recv().expect("reply, not a dead channel");
        assert!(bad_reply.is_err());
        let good_reply = good.recv().unwrap().expect("batchmate unharmed");
        assert!(!good_reply.logits.is_empty());
        // and infer() surfaces the error as Err, not a hang
        assert!(coord.infer(vec![1i8; 3], Mode::HighThroughput).is_err());
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 2);
    }

    #[test]
    fn sharded_frames_match_golden_and_cut_latency_cycles() {
        let mut rng = Xoshiro256::new(6);
        let net = cnn_a_quant(&mut rng, 4);
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let want_hi = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        let want_lo = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        let mut cycles_by_cards = Vec::new();
        for cards in [1usize, 2] {
            let coord = Coordinator::start(shard_cfg(cards), net.clone()).unwrap();
            let hi = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
            let lo = coord.infer(img.clone(), Mode::HighThroughput).unwrap();
            assert_eq!(hi.logits, want_hi, "{cards} cards");
            assert_eq!(lo.logits, want_lo, "{cards} cards");
            assert!(hi.cycles > lo.cycles);
            cycles_by_cards.push(hi.cycles);
            let m = coord.shutdown();
            assert_eq!(m.completed, 2);
            assert_eq!(m.batches, 2, "sharded batches are single frames");
            assert_eq!(m.routed_shard, 2);
            assert_eq!(m.shard_leases, 2);
            // an idle pool leases its full width
            assert_eq!(m.shard_cards_granted, 2 * cards as u64);
            assert_eq!(m.shard_cards_stolen, 0);
        }
        // 2 cards must beat 1 card in simulated frame latency
        assert!(cycles_by_cards[1] < cycles_by_cards[0], "{cycles_by_cards:?}");
    }

    #[test]
    fn sharded_bad_frame_errors_and_pool_survives() {
        let mut rng = Xoshiro256::new(7);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(shard_cfg(2), net.clone()).unwrap();
        assert!(coord.infer(vec![0i8; 5], Mode::HighAccuracy).is_err());
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let ok = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(ok.logits, want);
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 1);
    }

    #[test]
    fn explicit_override_beats_the_policy() {
        // a BatchOnly coordinator must still serve an explicit Shard
        // request through the shard lane — and vice versa
        let mut rng = Xoshiro256::new(8);
        let net = cnn_a_quant(&mut rng, 2);
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        let coord = Coordinator::start(quick_cfg(2), net.clone()).unwrap();
        let shard = coord
            .infer_routed(img.clone(), Mode::HighAccuracy, Some(DispatchClass::Shard))
            .unwrap();
        assert_eq!(shard.logits, want);
        let batch = coord
            .infer_routed(img.clone(), Mode::HighAccuracy, Some(DispatchClass::Batch))
            .unwrap();
        assert_eq!(batch.logits, want);
        let m = coord.shutdown();
        assert_eq!(m.completed, 2);
        assert_eq!(m.routed_shard, 1);
        assert_eq!(m.routed_batch, 1);
        assert_eq!(m.shard_leases, 1);
        assert!(m.shard_cards_granted >= 1);
    }

    #[test]
    fn max_shard_cards_caps_the_lease() {
        let mut rng = Xoshiro256::new(9);
        let net = cnn_a_quant(&mut rng, 2);
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 3,
                route: RoutePolicy::ShardOnly,
                max_shard_cards: 2,
                ..quick_cfg(3)
            },
            net,
        )
        .unwrap();
        coord.infer(img, Mode::HighAccuracy).unwrap();
        let m = coord.shutdown();
        assert_eq!(m.shard_leases, 1);
        assert_eq!(m.shard_cards_granted, 2, "lease capped below pool width");
    }

    #[test]
    fn submit_handles_are_cloneable_across_threads() {
        let mut rng = Xoshiro256::new(10);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(2), net).unwrap();
        let imgs: Vec<Vec<i8>> = (0..4).map(|_| prop::i8_vec(&mut rng, 48 * 48 * 3)).collect();
        let mut rxs = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = imgs
                .iter()
                .map(|img| {
                    let h = coord.handle();
                    s.spawn(move || h.submit(img.clone(), Mode::HighAccuracy))
                })
                .collect();
            for t in handles {
                rxs.push(t.join().unwrap());
            }
        });
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 4);
    }
}
