//! The coordinator proper: router thread + worker pool over simulated
//! BinArray instances.
//!
//! Topology (one process, std threads — the request path has no Python
//! and no async runtime dependency):
//!
//! ```text
//!   submit() ──mpsc──▶ router thread ──(Batcher)──▶ worker queue ─┬▶ worker 0 (BinArraySystem)
//!                                                                 ├▶ worker 1 (BinArraySystem)
//!                                                                 └▶ ...
//!   replies ◀───────────── per-request mpsc channels ◀────────────┘
//! ```
//!
//! Each worker owns a full simulated accelerator (its own weight BRAM and
//! feature buffers — one "card").  Mode switches (§IV-D) happen per batch
//! by flipping the card's `m_run`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::artifacts::QuantNetwork;
use crate::binarray::{ArrayConfig, BinArraySystem};
use crate::golden;

use super::batcher::{Batch, BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::{Mode, Request};

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Reply {
    pub id: u64,
    pub logits: Vec<i8>,
    pub class: usize,
    /// Simulated accelerator cycles spent on this frame.
    pub cycles: u64,
    /// End-to-end host latency (submit → reply).
    pub latency: Duration,
    pub mode: Mode,
}

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub array: ArrayConfig,
    /// Number of worker cards (each a full BinArray instance).
    pub workers: usize,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            array: ArrayConfig::new(1, 8, 2),
            workers: 1,
            policy: BatchPolicy::default(),
        }
    }
}

enum RouterMsg {
    Submit(Request, Sender<Reply>),
    Shutdown,
}

enum WorkerMsg {
    Run(Batch, Vec<Sender<Reply>>),
    Shutdown,
}

/// The serving coordinator.
pub struct Coordinator {
    router_tx: Sender<RouterMsg>,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<Metrics>>,
    next_id: AtomicU64,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Spin up the router and `cfg.workers` accelerator workers.
    pub fn start(cfg: CoordinatorConfig, net: QuantNetwork) -> Result<Self> {
        let (router_tx, router_rx) = channel::<RouterMsg>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let metrics = Arc::new(Mutex::new(Metrics::default()));

        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let rx = Arc::clone(&work_rx);
            let sys = BinArraySystem::new(cfg.array, net.clone())?;
            let global = Arc::clone(&metrics);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("binarray-worker-{w}"))
                    .spawn(move || worker_loop(sys, rx, global))?,
            );
        }

        let policy = cfg.policy;
        let n_workers = cfg.workers;
        let router = std::thread::Builder::new()
            .name("binarray-router".into())
            .spawn(move || router_loop(router_rx, work_tx, policy, n_workers))?;

        Ok(Self {
            router_tx,
            router: Some(router),
            workers,
            next_id: AtomicU64::new(0),
            metrics,
        })
    }

    /// Submit a request; returns a receiver for the reply.
    pub fn submit(&self, image: Vec<i8>, mode: Mode) -> Receiver<Reply> {
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            mode,
            submitted: Instant::now(),
        };
        // If the router is gone the receiver will simply yield RecvError.
        let _ = self.router_tx.send(RouterMsg::Submit(req, tx));
        rx
    }

    /// Submit and wait.
    pub fn infer(&self, image: Vec<i8>, mode: Mode) -> Result<Reply> {
        Ok(self.submit(image, mode).recv()?)
    }

    /// Drain and stop all threads, returning the final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.router_tx.send(RouterMsg::Shutdown);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        let mut total = Metrics::default();
        for w in self.workers.drain(..) {
            if let Ok(m) = w.join() {
                total.merge(&m);
            }
        }
        total
    }
}

fn router_loop(
    rx: Receiver<RouterMsg>,
    work_tx: Sender<WorkerMsg>,
    policy: BatchPolicy,
    n_workers: usize,
) {
    let mut batcher = Batcher::new(policy);
    let mut reply_txs: std::collections::HashMap<u64, Sender<Reply>> =
        std::collections::HashMap::new();
    loop {
        // Deadline-driven wait: block indefinitely when idle; otherwise
        // sleep exactly until the oldest request's max_delay expires.
        // (A fixed polling tick burns the core the workers need — it cost
        // ~20 % end-to-end on a single-core host; EXPERIMENTS.md §Perf.)
        let msg = if batcher.pending() == 0 {
            rx.recv().map_err(|_| std::sync::mpsc::RecvTimeoutError::Disconnected)
        } else {
            rx.recv_timeout(policy.max_delay.min(Duration::from_millis(50)))
        };
        match msg {
            Ok(RouterMsg::Submit(req, tx)) => {
                reply_txs.insert(req.id, tx);
                batcher.push(req);
            }
            Ok(RouterMsg::Shutdown) => {
                for batch in batcher.flush() {
                    dispatch(&work_tx, batch, &mut reply_txs);
                }
                for _ in 0..n_workers {
                    let _ = work_tx.send(WorkerMsg::Shutdown);
                }
                return;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                for batch in batcher.flush() {
                    dispatch(&work_tx, batch, &mut reply_txs);
                }
                for _ in 0..n_workers {
                    let _ = work_tx.send(WorkerMsg::Shutdown);
                }
                return;
            }
        }
        let now = Instant::now();
        while let Some(batch) = batcher.cut(now) {
            dispatch(&work_tx, batch, &mut reply_txs);
        }
    }
}

fn dispatch(
    work_tx: &Sender<WorkerMsg>,
    batch: Batch,
    reply_txs: &mut std::collections::HashMap<u64, Sender<Reply>>,
) {
    let txs: Vec<Sender<Reply>> = batch
        .requests
        .iter()
        .map(|r| reply_txs.remove(&r.id).expect("reply channel registered"))
        .collect();
    let _ = work_tx.send(WorkerMsg::Run(batch, txs));
}

fn worker_loop(
    mut sys: BinArraySystem,
    rx: Arc<Mutex<Receiver<WorkerMsg>>>,
    global: Arc<Mutex<Metrics>>,
) -> Metrics {
    let mut local = Metrics::default();
    let max_m = sys.net.max_m();
    let m_arch = sys.cfg.m_arch;
    loop {
        let msg = {
            let guard = rx.lock().expect("worker rx poisoned");
            guard.recv()
        };
        let Ok(msg) = msg else { break };
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Run(batch, txs) => {
                // §IV-D: one mode switch per batch, not per frame.
                let m_run = batch.mode.m_run(max_m, m_arch);
                sys.set_mode(Some(m_run));
                let mut delta = Metrics::default();
                delta.batches += 1;
                // The whole batch runs back-to-back on the precomputed
                // plan — one `run_frames` call, zero per-frame setup.
                let images = batch.images();
                let t0 = Instant::now();
                let results = sys.run_frames(&images).expect("batch failed");
                let batch_wall = t0.elapsed();
                for ((req, tx), (logits, stats)) in
                    batch.requests.into_iter().zip(txs).zip(results)
                {
                    let latency = req.submitted.elapsed();
                    delta.completed += 1;
                    delta.sim_cycles += stats.cycles;
                    delta.latency.record(latency);
                    // Queue wait = time from submit until this batch's
                    // compute began (replies all land after `run_frames`,
                    // so the whole batch wall is compute, not queueing).
                    delta
                        .queue_wait
                        .record(latency.saturating_sub(batch_wall));
                    let reply = Reply {
                        id: req.id,
                        class: golden::argmax(&logits),
                        logits,
                        cycles: stats.cycles,
                        latency,
                        mode: req.mode,
                    };
                    let _ = tx.send(reply);
                }
                delta.sim_wall += batch_wall;
                local.merge(&delta);
                if let Ok(mut g) = global.lock() {
                    g.merge(&delta); // live view across all workers
                }
            }
        }
    }
    local
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::tensor::Shape;
    use crate::util::{prop, rng::Xoshiro256};

    fn quick_cfg(workers: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
            },
        }
    }

    #[test]
    fn serves_and_matches_golden() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let reply = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(reply.logits, want);
        assert_eq!(reply.class, golden::argmax(&want));
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(2), net).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                coord.submit(prop::i8_vec(&mut rng, 48 * 48 * 3), Mode::HighAccuracy)
            })
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            ids.push(rx.recv().unwrap().id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<u64>>());
        let m = coord.shutdown();
        assert_eq!(m.completed, 12);
        assert!(m.batches >= 3, "12 reqs / max_batch 4 ⇒ ≥3 batches");
    }

    #[test]
    fn mode_switch_serves_both_modes() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 4); // M=4 on M_arch=2
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let fast = coord.infer(img.clone(), Mode::HighThroughput).unwrap();
        let slow = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        assert!(slow.cycles > fast.cycles * 3 / 2, "{} vs {}", slow.cycles, fast.cycles);
        let want_fast = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        assert_eq!(fast.logits, want_fast);
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy {
                    max_batch: 64,
                    max_delay: Duration::from_secs(60), // never ripe on its own
                },
                ..quick_cfg(1)
            },
            net,
        )
        .unwrap();
        let rxs: Vec<_> = (0..3)
            .map(|_| coord.submit(prop::i8_vec(&mut rng, 48 * 48 * 3), Mode::HighAccuracy))
            .collect();
        std::thread::sleep(Duration::from_millis(5));
        let m = coord.shutdown(); // flush must run the stragglers
        assert_eq!(m.completed, 3);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }
}
