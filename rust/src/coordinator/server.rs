//! The coordinator proper: router thread + worker pool over simulated
//! BinArray instances, with an optional cross-card scatter/gather path.
//!
//! Topology (one process, std threads — the request path has no Python
//! and no async runtime dependency):
//!
//! ```text
//!   submit() ──mpsc──▶ router thread ──(Batcher)──▶ worker queue ─┬▶ worker 0 (BinArraySystem)
//!                                                                 ├▶ worker 1 (BinArraySystem)
//!                                                                 └▶ ...
//!   replies ◀───────────── per-request mpsc channels ◀────────────┘
//!
//!   — with ShardPolicy::PerFrame(n) the router instead hands each frame
//!     to the shard orchestrator, which scatters row tiles over the same
//!     worker queue and gathers them layer by layer:
//!
//!   submit() ──▶ router ──(per-frame cut)──▶ orchestrator (CU + frame fbuf)
//!                                         │  per layer: scatter n tile jobs
//!                                         ▼
//!                                   worker queue ─┬▶ worker 0: run_shard ─┐
//!                                                 └▶ worker 1: run_shard ─┤
//!                                         ▲                              │
//!                                         └── gather tiles into pong ◀───┘
//! ```
//!
//! Each worker owns a full simulated accelerator (its own weight BRAM and
//! feature buffers — one "card").  Mode switches (§IV-D) happen per batch
//! by flipping the card's `m_run`.
//!
//! The two dispatch paths trade latency against throughput: the batching
//! path keeps every card busy on *different* frames (throughput scales
//! with workers, per-frame latency is one card's), while the shard path
//! spends the whole pool on *one* frame's row tiles (latency shrinks with
//! workers, at the cost of per-layer scatter/gather traffic).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::artifacts::QuantNetwork;
use crate::binarray::{
    ArrayConfig, BinArraySystem, ControlUnit, ExecutionPlan, FrameStats, ShardPlan, ShardPolicy,
    ShardRun, SimStats,
};
use crate::golden;
use crate::isa::{compile_network, Program};
use crate::tensor::scatter_tile;

use super::batcher::{Batch, BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::{Mode, Request};

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Reply {
    pub id: u64,
    pub logits: Vec<i8>,
    pub class: usize,
    /// Simulated accelerator cycles spent on this frame.
    pub cycles: u64,
    /// End-to-end host latency (submit → reply).
    pub latency: Duration,
    pub mode: Mode,
}

/// A failed inference: the request was admitted but could not be served
/// (malformed image, dead worker pool…).  Failures are *answered* on the
/// reply channel — a bad batch must never strand its callers on
/// `RecvError` or take the worker thread down with it.
#[derive(Clone, Debug)]
pub struct InferError {
    pub id: u64,
    pub reason: String,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {}: {}", self.id, self.reason)
    }
}

impl std::error::Error for InferError {}

/// What arrives on a reply channel: the inference or a per-request error.
pub type ReplyResult = std::result::Result<Reply, InferError>;

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub array: ArrayConfig,
    /// Number of worker cards (each a full BinArray instance).  Grown to
    /// at least `shard.cards()` so sharded frames never queue on a pool
    /// narrower than their scatter width.
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Cross-card sharding: `Off` batches whole frames onto single cards;
    /// `PerFrame(n)` scatters every frame's row tiles over `n` cards.
    pub shard: ShardPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            array: ArrayConfig::new(1, 8, 2),
            workers: 1,
            policy: BatchPolicy::default(),
            shard: ShardPolicy::Off,
        }
    }
}

enum RouterMsg {
    Submit(Request, Sender<ReplyResult>),
    Shutdown,
}

/// One card's slice of one layer of one frame — the scatter payload.
struct ShardJob {
    m_run: Option<usize>,
    layer: usize,
    /// Card index into the [`ShardPlan`] (not a worker id: any idle
    /// worker may pick the job up; the index only selects the
    /// sub-schedule).
    card: usize,
    /// The layer's full input region (every card streams the whole ping
    /// half, so convolution windows never straddle a card boundary).
    input: Arc<Vec<i8>>,
    reply: Sender<(usize, Result<ShardRun>)>,
}

enum WorkerMsg {
    Run(Batch, Vec<Sender<ReplyResult>>),
    Shard(ShardJob),
    Shutdown,
}

enum OrchMsg {
    Run(Batch, Vec<Sender<ReplyResult>>),
    Shutdown,
}

/// The shard orchestrator's static state: the compiled program, the
/// execution plan it indexes per layer, and the shard partition — built
/// directly at start so the orchestrator doesn't hold a whole card's
/// executor memory just to read schedules.
struct ShardOracle {
    plan: ExecutionPlan,
    prog: Program,
    shards: Arc<ShardPlan>,
    max_m: usize,
    m_arch: usize,
}

/// Where the router sends cut batches.
enum Dispatch {
    /// Straight to the worker queue (whole-frame batching).
    Workers(Sender<WorkerMsg>),
    /// To the shard orchestrator (scatter/gather per frame).
    Orchestrator(Sender<OrchMsg>),
}

/// Cloneable submit-side handle: many producer threads can feed one
/// coordinator (the `Coordinator` itself stays single-owner so that
/// `shutdown` consumes it).
#[derive(Clone)]
pub struct SubmitHandle {
    router_tx: Sender<RouterMsg>,
    next_id: Arc<AtomicU64>,
}

impl SubmitHandle {
    /// Submit a request; returns a receiver for the reply.
    pub fn submit(&self, image: Vec<i8>, mode: Mode) -> Receiver<ReplyResult> {
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            mode,
            submitted: Instant::now(),
        };
        // If the router is gone the receiver will simply yield RecvError.
        let _ = self.router_tx.send(RouterMsg::Submit(req, tx));
        rx
    }

    /// Submit and wait.
    pub fn infer(&self, image: Vec<i8>, mode: Mode) -> Result<Reply> {
        Ok(self.submit(image, mode).recv()??)
    }
}

/// The serving coordinator.
pub struct Coordinator {
    handle: SubmitHandle,
    router: Option<JoinHandle<()>>,
    orchestrator: Option<JoinHandle<Metrics>>,
    workers: Vec<JoinHandle<Metrics>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Spin up the router, `cfg.workers` accelerator workers, and — when
    /// `cfg.shard` is `PerFrame` — the shard orchestrator.
    pub fn start(cfg: CoordinatorConfig, net: QuantNetwork) -> Result<Self> {
        if net.layers.is_empty() {
            bail!("empty network");
        }
        let (router_tx, router_rx) = channel::<RouterMsg>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        // The pool must cover the shard width: fewer workers than cards
        // would serialize a frame's shard jobs while Reply.cycles still
        // reported the n-card machine's parallel latency.
        let n_workers = match cfg.shard {
            ShardPolicy::Off => cfg.workers.max(1),
            ShardPolicy::PerFrame(_) => cfg.workers.max(cfg.shard.cards()),
        };

        // The shard plan is deterministic from (config, net, cards), so
        // every thread shares one copy, built alongside the
        // orchestrator's plan/program oracle.
        let shard_state: Option<ShardOracle> = if cfg.shard.is_sharded() {
            let prog = compile_network(&net);
            let plan = ExecutionPlan::new(cfg.array, &net, &prog);
            Some(ShardOracle {
                shards: Arc::new(ShardPlan::new(&plan, cfg.shard.cards())),
                plan,
                prog,
                max_m: net.max_m(),
                m_arch: cfg.array.m_arch,
            })
        } else {
            None
        };

        // Sharded cards run one frame's shards *concurrently*, so each
        // card gets its slice of the host cores for intra-card threading
        // — the full width on every card would oversubscribe the host
        // with the exact thread thrash the latency path exists to avoid.
        // The divisor is the shard width (cards in flight per frame),
        // not the pool size: extra workers beyond the shard width idle.
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let card_threads = cores / cfg.shard.cards();
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let rx = Arc::clone(&work_rx);
            let sys = if cfg.shard.is_sharded() {
                BinArraySystem::with_host_threads(cfg.array, net.clone(), card_threads)?
            } else {
                BinArraySystem::new(cfg.array, net.clone())?
            };
            let global = Arc::clone(&metrics);
            let sp = shard_state.as_ref().map(|o| Arc::clone(&o.shards));
            workers.push(
                std::thread::Builder::new()
                    .name(format!("binarray-worker-{w}"))
                    .spawn(move || worker_loop(sys, rx, global, sp))?,
            );
        }

        let (dispatch, orchestrator) = match shard_state {
            Some(oracle) => {
                let (orch_tx, orch_rx) = channel::<OrchMsg>();
                let global = Arc::clone(&metrics);
                let wtx = work_tx.clone();
                let orch = std::thread::Builder::new()
                    .name("binarray-shard-orch".into())
                    .spawn(move || orchestrator_loop(oracle, orch_rx, wtx, n_workers, global))?;
                (Dispatch::Orchestrator(orch_tx), Some(orch))
            }
            None => (Dispatch::Workers(work_tx), None),
        };

        let policy = cfg.policy.effective(cfg.shard);
        let router = std::thread::Builder::new()
            .name("binarray-router".into())
            .spawn(move || router_loop(router_rx, dispatch, policy, n_workers))?;

        Ok(Self {
            handle: SubmitHandle {
                router_tx,
                next_id: Arc::new(AtomicU64::new(0)),
            },
            router: Some(router),
            orchestrator,
            workers,
            metrics,
        })
    }

    /// A cloneable submit handle for producer threads.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    /// Submit a request; returns a receiver for the reply.
    pub fn submit(&self, image: Vec<i8>, mode: Mode) -> Receiver<ReplyResult> {
        self.handle.submit(image, mode)
    }

    /// Submit and wait.
    pub fn infer(&self, image: Vec<i8>, mode: Mode) -> Result<Reply> {
        self.handle.infer(image, mode)
    }

    /// Drain and stop all threads, returning the final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.handle.router_tx.send(RouterMsg::Shutdown);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        let mut total = Metrics::default();
        // The orchestrator (when present) must drain before the workers
        // stop — it is the one who tells them to, once its queue is dry.
        if let Some(o) = self.orchestrator.take() {
            if let Ok(m) = o.join() {
                total.merge(&m);
            }
        }
        for w in self.workers.drain(..) {
            if let Ok(m) = w.join() {
                total.merge(&m);
            }
        }
        total
    }
}

/// Registered reply channels keyed by request id.
type ReplyMap = std::collections::HashMap<u64, Sender<ReplyResult>>;

/// Router shutdown: flush the batcher's stragglers, then stop the pool —
/// directly for the batching path, or via the orchestrator (which still
/// needs the workers to serve the flushed frames' shard jobs first).
fn drain_and_stop(
    batcher: &mut Batcher,
    reply_txs: &mut ReplyMap,
    to: &Dispatch,
    n_workers: usize,
) {
    for batch in batcher.flush() {
        dispatch(to, batch, reply_txs);
    }
    match to {
        Dispatch::Workers(tx) => {
            for _ in 0..n_workers {
                let _ = tx.send(WorkerMsg::Shutdown);
            }
        }
        Dispatch::Orchestrator(tx) => {
            let _ = tx.send(OrchMsg::Shutdown);
        }
    }
}

fn router_loop(
    rx: Receiver<RouterMsg>,
    dispatch_to: Dispatch,
    policy: BatchPolicy,
    n_workers: usize,
) {
    let mut batcher = Batcher::new(policy);
    let mut reply_txs = ReplyMap::new();
    loop {
        // Deadline-driven wait: block indefinitely when idle; otherwise
        // sleep exactly until the oldest request's max_delay expires.
        // (A fixed polling tick burns the core the workers need — it cost
        // ~20 % end-to-end on a single-core host; EXPERIMENTS.md §Perf.)
        let msg = if batcher.pending() == 0 {
            rx.recv().map_err(|_| std::sync::mpsc::RecvTimeoutError::Disconnected)
        } else {
            rx.recv_timeout(policy.max_delay.min(Duration::from_millis(50)))
        };
        match msg {
            Ok(RouterMsg::Submit(req, tx)) => {
                reply_txs.insert(req.id, tx);
                batcher.push(req);
            }
            Ok(RouterMsg::Shutdown) | Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                drain_and_stop(&mut batcher, &mut reply_txs, &dispatch_to, n_workers);
                return;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
        }
        let now = Instant::now();
        while let Some(batch) = batcher.cut(now) {
            dispatch(&dispatch_to, batch, &mut reply_txs);
        }
    }
}

fn dispatch(to: &Dispatch, batch: Batch, reply_txs: &mut ReplyMap) {
    let txs: Vec<Sender<ReplyResult>> = batch
        .requests
        .iter()
        .map(|r| reply_txs.remove(&r.id).expect("reply channel registered"))
        .collect();
    match to {
        Dispatch::Workers(tx) => {
            let _ = tx.send(WorkerMsg::Run(batch, txs));
        }
        Dispatch::Orchestrator(tx) => {
            let _ = tx.send(OrchMsg::Run(batch, txs));
        }
    }
}

/// Record one successful frame into `delta` and answer its caller.
fn send_reply(
    delta: &mut Metrics,
    req: Request,
    tx: &Sender<ReplyResult>,
    logits: Vec<i8>,
    cycles: u64,
    compute_wall: Duration,
) {
    let latency = req.submitted.elapsed();
    delta.completed += 1;
    delta.sim_cycles += cycles;
    delta.latency.record(latency);
    // Queue wait = time from submit until this request's compute began
    // (replies land after the compute, so the compute wall is not wait).
    delta.queue_wait.record(latency.saturating_sub(compute_wall));
    let reply = Reply {
        id: req.id,
        class: golden::argmax(&logits),
        logits,
        cycles,
        latency,
        mode: req.mode,
    };
    let _ = tx.send(Ok(reply));
}

fn send_error(delta: &mut Metrics, id: u64, tx: &Sender<ReplyResult>, e: &anyhow::Error) {
    delta.failed += 1;
    let _ = tx.send(Err(InferError {
        id,
        reason: format!("{e:#}"),
    }));
}

fn worker_loop(
    mut sys: BinArraySystem,
    rx: Arc<Mutex<Receiver<WorkerMsg>>>,
    global: Arc<Mutex<Metrics>>,
    shards: Option<Arc<ShardPlan>>,
) -> Metrics {
    let mut local = Metrics::default();
    let max_m = sys.net.max_m();
    let m_arch = sys.cfg.m_arch;
    loop {
        let msg = {
            let guard = rx.lock().expect("worker rx poisoned");
            guard.recv()
        };
        let Ok(msg) = msg else { break };
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Shard(job) => {
                let res = match &shards {
                    Some(sp) => {
                        sys.set_mode(job.m_run);
                        let shard = &sp.mode(job.m_run)[job.layer].cards[job.card];
                        sys.run_shard(job.layer, &job.input, shard)
                    }
                    None => Err(anyhow!("worker has no shard plan")),
                };
                // The orchestrator counts one reply per dispatched job;
                // errors must be answered like results.
                let _ = job.reply.send((job.card, res));
            }
            WorkerMsg::Run(batch, txs) => {
                // §IV-D: one mode switch per batch, not per frame.
                let m_run = batch.mode.m_run(max_m, m_arch);
                sys.set_mode(Some(m_run));
                let mut delta = Metrics::default();
                delta.batches += 1;
                // Answer malformed requests up front (the only way a
                // request alone can sink `run_frames`), so a poisoned
                // frame never costs its batchmates any compute — and
                // never kills this worker, stranding callers on
                // RecvError.
                let want_len = sys.input_shape.len();
                let mut good: Vec<(Request, &Sender<ReplyResult>)> = Vec::new();
                for (req, tx) in batch.requests.into_iter().zip(&txs) {
                    if req.image.len() == want_len {
                        good.push((req, tx));
                    } else {
                        let e = anyhow!("image len {} != {want_len}", req.image.len());
                        send_error(&mut delta, req.id, tx, &e);
                    }
                }
                // The surviving batch runs back-to-back on the
                // precomputed plan — one `run_frames` call, zero
                // per-frame setup.
                let images: Vec<&[i8]> = good.iter().map(|(r, _)| r.image.as_slice()).collect();
                let t0 = Instant::now();
                match sys.run_frames(&images) {
                    Ok(results) => {
                        let batch_wall = t0.elapsed();
                        for ((req, tx), (logits, stats)) in good.into_iter().zip(results) {
                            send_reply(&mut delta, req, tx, logits, stats.cycles, batch_wall);
                        }
                        delta.sim_wall += batch_wall;
                    }
                    Err(_) => {
                        // Defense in depth for failures validation can't
                        // see: retry frames one by one so whatever frame
                        // is poisoned errors alone.
                        for (req, tx) in good {
                            let t1 = Instant::now();
                            match sys.run_frames(&[&req.image]) {
                                Ok(mut rs) => {
                                    let (logits, stats) = rs.pop().expect("one frame in/out");
                                    let wall = t1.elapsed();
                                    send_reply(&mut delta, req, tx, logits, stats.cycles, wall);
                                    delta.sim_wall += wall;
                                }
                                Err(e) => send_error(&mut delta, req.id, tx, &e),
                            }
                        }
                    }
                }
                local.merge(&delta);
                if let Ok(mut g) = global.lock() {
                    g.merge(&delta); // live view across all workers
                }
            }
        }
    }
    local
}

/// The shard orchestrator: owns each in-flight frame's CU and ping-pong
/// feature buffer, scatters every layer's row tiles over the worker
/// queue, and gathers the cards' output tiles back before triggering the
/// next layer.  The CU is the same state machine the in-card executor
/// uses, so instruction-cycle accounting is identical on both paths.
fn orchestrator_loop(
    oracle: ShardOracle,
    rx: Receiver<OrchMsg>,
    work_tx: Sender<WorkerMsg>,
    n_workers: usize,
    global: Arc<Mutex<Metrics>>,
) -> Metrics {
    let mut local = Metrics::default();
    let mut cu = ControlUnit::new();
    cu.park_at(oracle.prog.entry);
    let mut fbuf = vec![0i8; oracle.prog.fbuf_words];
    loop {
        let Ok(msg) = rx.recv() else { break };
        match msg {
            OrchMsg::Shutdown => break,
            OrchMsg::Run(batch, txs) => {
                let m_run = Some(batch.mode.m_run(oracle.max_m, oracle.m_arch));
                let mut delta = Metrics::default();
                delta.batches += 1;
                for (req, tx) in batch.requests.into_iter().zip(&txs) {
                    let t0 = Instant::now();
                    let res = run_sharded_frame(
                        &oracle, &mut cu, &mut fbuf, &work_tx, &req.image, m_run,
                    );
                    let frame_wall = t0.elapsed();
                    match res {
                        Ok((logits, stats)) => {
                            send_reply(&mut delta, req, tx, logits, stats.cycles, frame_wall);
                            delta.sim_wall += frame_wall;
                        }
                        Err(e) => send_error(&mut delta, req.id, tx, &e),
                    }
                }
                local.merge(&delta);
                if let Ok(mut g) = global.lock() {
                    g.merge(&delta);
                }
            }
        }
    }
    // The pool stops only after the orchestrator has drained: flushed
    // frames still need workers for their shard jobs.
    for _ in 0..n_workers {
        let _ = work_tx.send(WorkerMsg::Shutdown);
    }
    local
}

/// Run one frame scattered over the worker pool.  Per layer: copy the
/// ping half's input region once (the "DMA broadcast"), enqueue one
/// [`ShardJob`] per card with work, then stitch every returned tile into
/// the pong half.  Frame cycles = CU instruction cycles + Σ max-over-cards
/// layer walls — the latency of an `n_cards`-card machine.
fn run_sharded_frame(
    oracle: &ShardOracle,
    cu: &mut ControlUnit,
    fbuf: &mut [i8],
    work_tx: &Sender<WorkerMsg>,
    image: &[i8],
    m_run: Option<usize>,
) -> Result<(Vec<i8>, FrameStats)> {
    let mode = oracle.plan.mode(m_run);
    let layer_shards = oracle.shards.mode(m_run);
    let first = mode.layers.first().expect("non-empty plan");
    if image.len() != first.in_len {
        return Err(anyhow!("image len {} != {}", image.len(), first.in_len));
    }
    fbuf[first.in_base..first.in_base + first.in_len].copy_from_slice(image);

    let mut stats = FrameStats {
        // In shard mode the per-unit stats aggregate per *card* (each
        // card is a whole array; mapping cards onto one card's physical
        // SAs would be meaningless).
        sa_stats: vec![SimStats::default(); oracle.shards.n_cards],
        ..Default::default()
    };
    let mut err: Option<anyhow::Error> = None;

    let layer_cycles = &mut stats.layer_cycles;
    let sa_stats = &mut stats.sa_stats;
    let err_ref = &mut err;
    let cu_run = cu.run_frame(&oracle.prog, |lr| {
        if err_ref.is_some() {
            // A card already failed: fall through the remaining layers
            // without dispatching work so the CU still reaches its HLT.
            layer_cycles.push(0);
            return 0;
        }
        let li = lr.layer_id as usize;
        let lp = &mode.layers[li];
        // Scatter: broadcast the input region, one tile job per card.
        // The reply channel is per layer, and the orchestrator's own tx
        // is dropped right after the scatter — so a worker that dies
        // without answering surfaces as a recv disconnect (an error
        // reply), never as a gather that blocks forever.
        let (reply_tx, reply_rx) = channel::<(usize, Result<ShardRun>)>();
        let input = Arc::new(fbuf[lp.in_base..lp.in_base + lp.in_len].to_vec());
        let mut sent = 0usize;
        for (card, shard) in layer_shards[li].cards.iter().enumerate() {
            if shard.n_units() == 0 {
                continue; // layer too small for this card — it idles
            }
            let job = ShardJob {
                m_run,
                layer: li,
                card,
                input: Arc::clone(&input),
                reply: reply_tx.clone(),
            };
            if work_tx.send(WorkerMsg::Shard(job)).is_err() {
                *err_ref = Some(anyhow!("worker pool disconnected"));
                layer_cycles.push(0);
                return 0;
            }
            sent += 1;
        }
        drop(reply_tx);
        // Gather: exactly `sent` replies belong to this layer (each job
        // answers once, success or error), stitched into the pong half.
        let out = &mut fbuf[lp.out_base..lp.out_base + lp.out_len];
        let mut wall = 0u64;
        for _ in 0..sent {
            match reply_rx.recv() {
                Ok((card, Ok(run))) => {
                    for t in &run.tiles {
                        scatter_tile(lp.out_shape, out, t.rows.clone(), t.chans.clone(), &t.data);
                    }
                    wall = wall.max(run.wall);
                    sa_stats[card].add(run.stats);
                }
                Ok((card, Err(e))) => {
                    err_ref.get_or_insert(anyhow!("card {card}, layer {li}: {e:#}"));
                }
                Err(_) => {
                    // every sender is gone but replies are missing — a
                    // worker died mid-job without answering
                    err_ref.get_or_insert(anyhow!("layer {li}: a card died before replying"));
                    break;
                }
            }
        }
        layer_cycles.push(wall);
        wall
    });
    stats.instr_cycles = cu_run.instr_cycles;
    stats.cycles = cu_run.total_cycles();

    if let Some(e) = err {
        return Err(e);
    }
    let last = mode.layers.last().expect("non-empty plan");
    let logits = fbuf[last.out_base..last.out_base + last.out_len].to_vec();
    Ok((logits, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::tensor::Shape;
    use crate::util::{prop, rng::Xoshiro256};

    fn quick_cfg(workers: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
            },
            shard: ShardPolicy::Off,
        }
    }

    #[test]
    fn serves_and_matches_golden() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let reply = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(reply.logits, want);
        assert_eq!(reply.class, golden::argmax(&want));
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(2), net).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                coord.submit(prop::i8_vec(&mut rng, 48 * 48 * 3), Mode::HighAccuracy)
            })
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            ids.push(rx.recv().unwrap().unwrap().id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<u64>>());
        let m = coord.shutdown();
        assert_eq!(m.completed, 12);
        assert!(m.batches >= 3, "12 reqs / max_batch 4 ⇒ ≥3 batches");
    }

    #[test]
    fn mode_switch_serves_both_modes() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 4); // M=4 on M_arch=2
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let fast = coord.infer(img.clone(), Mode::HighThroughput).unwrap();
        let slow = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        assert!(slow.cycles > fast.cycles * 3 / 2, "{} vs {}", slow.cycles, fast.cycles);
        let want_fast = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        assert_eq!(fast.logits, want_fast);
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy {
                    max_batch: 64,
                    max_delay: Duration::from_secs(60), // never ripe on its own
                },
                ..quick_cfg(1)
            },
            net,
        )
        .unwrap();
        let rxs: Vec<_> = (0..3)
            .map(|_| coord.submit(prop::i8_vec(&mut rng, 48 * 48 * 3), Mode::HighAccuracy))
            .collect();
        std::thread::sleep(Duration::from_millis(5));
        let m = coord.shutdown(); // flush must run the stragglers
        assert_eq!(m.completed, 3);
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn failing_request_gets_error_reply_not_hang() {
        let mut rng = Xoshiro256::new(5);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(1), net).unwrap();
        // Wrong-size image: the worker must answer Err, stay alive, and
        // keep serving its batchmates.
        let bad = coord.submit(vec![0i8; 7], Mode::HighAccuracy);
        let good_img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let good = coord.submit(good_img, Mode::HighAccuracy);
        let bad_reply = bad.recv().expect("reply, not a dead channel");
        assert!(bad_reply.is_err());
        let good_reply = good.recv().unwrap().expect("batchmate unharmed");
        assert!(!good_reply.logits.is_empty());
        // and infer() surfaces the error as Err, not a hang
        assert!(coord.infer(vec![1i8; 3], Mode::HighThroughput).is_err());
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 2);
    }

    #[test]
    fn sharded_frames_match_golden_and_cut_latency_cycles() {
        let mut rng = Xoshiro256::new(6);
        let net = cnn_a_quant(&mut rng, 4);
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let want_hi = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        let want_lo = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        let mut cycles_by_cards = Vec::new();
        for cards in [1usize, 2] {
            let coord = Coordinator::start(
                CoordinatorConfig {
                    array: ArrayConfig::new(1, 8, 2),
                    workers: cards,
                    policy: BatchPolicy::default(),
                    shard: ShardPolicy::PerFrame(cards),
                },
                net.clone(),
            )
            .unwrap();
            let hi = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
            let lo = coord.infer(img.clone(), Mode::HighThroughput).unwrap();
            assert_eq!(hi.logits, want_hi, "{cards} cards");
            assert_eq!(lo.logits, want_lo, "{cards} cards");
            assert!(hi.cycles > lo.cycles);
            cycles_by_cards.push(hi.cycles);
            let m = coord.shutdown();
            assert_eq!(m.completed, 2);
            assert_eq!(m.batches, 2, "sharded batches are single frames");
        }
        // 2 cards must beat 1 card in simulated frame latency
        assert!(cycles_by_cards[1] < cycles_by_cards[0], "{cycles_by_cards:?}");
    }

    #[test]
    fn sharded_bad_frame_errors_and_pool_survives() {
        let mut rng = Xoshiro256::new(7);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(
            CoordinatorConfig {
                array: ArrayConfig::new(1, 8, 2),
                workers: 2,
                policy: BatchPolicy::default(),
                shard: ShardPolicy::PerFrame(2),
            },
            net.clone(),
        )
        .unwrap();
        assert!(coord.infer(vec![0i8; 5], Mode::HighAccuracy).is_err());
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let ok = coord.infer(img.clone(), Mode::HighAccuracy).unwrap();
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(ok.logits, want);
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 1);
    }

    #[test]
    fn submit_handles_are_cloneable_across_threads() {
        let mut rng = Xoshiro256::new(8);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(2), net).unwrap();
        let imgs: Vec<Vec<i8>> = (0..4).map(|_| prop::i8_vec(&mut rng, 48 * 48 * 3)).collect();
        let mut rxs = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = imgs
                .iter()
                .map(|img| {
                    let h = coord.handle();
                    s.spawn(move || h.submit(img.clone(), Mode::HighAccuracy))
                })
                .collect();
            for t in handles {
                rxs.push(t.join().unwrap());
            }
        });
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 4);
    }
}
