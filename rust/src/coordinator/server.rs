//! The coordinator proper: a routing/arbitration thread plus a worker
//! pool of simulated BinArray instances, serving two dispatch lanes
//! concurrently over the same cards.
//!
//! Topology (one process, std threads — the request path has no Python
//! and no async runtime dependency):
//!
//! ```text
//!   submit() ──mpsc──▶ router thread (stamps DispatchClass, batches,
//!            ▲         arbitrates cards between the lanes)
//!            │              │
//!   WorkerDone/Lease/       ├─ Batch lane: whole batches to free cards
//!   Unlease notifications   │      ─▶ worker 0 (BinArraySystem) ─▶ replies
//!            │              │      ─▶ worker 1 ...
//!            │              └─ Shard lane: frames to the orchestrator
//!            │                     │ lease k free cards from the router
//!            └─────────────────────┤ per layer: scatter k tile jobs to
//!                                  │   the *leased* cards' queues,
//!                                  │   gather tiles into the pong half
//!                                  └ return the lease, answer the caller
//! ```
//!
//! Each worker owns a full simulated accelerator (its own weight BRAM and
//! feature buffers — one "card").  Mode switches (§IV-D) happen per batch
//! by flipping the card's `m_run`.
//!
//! The two lanes trade latency against throughput per *request*, not per
//! coordinator: the batching lane keeps cards busy on *different* frames
//! (throughput scales with workers, per-frame latency is one card's),
//! while the shard lane spends *leased* cards on one frame's row tiles
//! (latency shrinks with the lease width).  The router is the arbiter:
//! cards are leased to the shard orchestrator only while they are not
//! running a batch, and a pending lease has priority over queued batches
//! when a card frees up (the shard lane is the latency lane).  Whatever
//! the lane, replies are bit-identical to [`golden::forward`].
//!
//! Deadlines thread through the whole path: expired work is shed with
//! [`InferError::DeadlineExceeded`] at every point where it would next
//! cost something (admission, the batcher queue, a worker about to
//! compute it, the orchestrator about to lease for it), and a pending
//! lease may wait a bounded, slack-derived budget
//! ([`CoordinatorConfig::lease_slack`]) for busy cards to free before
//! accepting a narrow grant — under bursty batch traffic a slightly
//! later, wider lease is the lower-latency choice.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::artifacts::QuantNetwork;
use crate::binarray::{
    ArrayConfig, BinArraySystem, ControlUnit, FrameStats, ShardPlan, ShardRun, SimStats,
};
use crate::golden;
use crate::tensor::scatter_tile;

use super::batcher::{Arbitration, Batch, BatchPolicy, Batcher};
use super::capacity::CapacityModel;
use super::metrics::{Metrics, ModelMetrics};
use super::registry::{ModelEntry, ModelId, ModelRegistry};
use super::route::{ClassTable, DispatchClass, RoutePolicy, ServiceClass, N_CLASSES};
use super::{Mode, Request};

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Reply {
    pub id: u64,
    pub logits: Vec<i8>,
    pub class: usize,
    /// Simulated accelerator cycles spent on this frame.
    pub cycles: u64,
    /// End-to-end host latency (submit → reply).
    pub latency: Duration,
    pub mode: Mode,
}

/// A request that was admitted but not served.  Failures are *answered*
/// on the reply channel — a bad batch must never strand its callers on
/// `RecvError` or take the worker thread down with it — and they are
/// typed, so a caller can tell QoS shedding apart from real faults.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InferError {
    /// The request could not be served (malformed image, dead worker
    /// pool…).
    Failed { id: u64, reason: String },
    /// The request was shed unserved: its deadline expired before any
    /// card started computing it, so the coordinator answered instead of
    /// burning compute on a reply nobody can use.
    DeadlineExceeded { id: u64 },
    /// The request was *refused at admission*: the capacity model proved
    /// its deadline/SLO unmeetable under the best pace this pool has
    /// ever shown (or its class's admission budget is full).  Refused
    /// work is never queued and never computed — `earliest_feasible` is
    /// the model's floor on how much end-to-end budget a resubmission
    /// would need right now.
    AdmissionRefused { id: u64, earliest_feasible: Duration },
    /// The request named a model the registry doesn't serve.  Like an
    /// admission refusal it costs nothing: never queued, never computed
    /// (and counted into the `admission_refused` bucket, so the
    /// `submitted == completed + failed + admission_refused` identity
    /// holds per model too).
    UnknownModel { id: u64, model: u32 },
}

impl InferError {
    /// The id of the request this error answers.
    pub fn id(&self) -> u64 {
        match self {
            InferError::Failed { id, .. }
            | InferError::DeadlineExceeded { id }
            | InferError::AdmissionRefused { id, .. }
            | InferError::UnknownModel { id, .. } => *id,
        }
    }

    /// Was this a deadline shed (as opposed to a serving fault)?
    pub fn is_deadline(&self) -> bool {
        matches!(self, InferError::DeadlineExceeded { .. })
    }

    /// Was this an admission refusal (never admitted, zero cost)?
    pub fn is_refused(&self) -> bool {
        matches!(
            self,
            InferError::AdmissionRefused { .. } | InferError::UnknownModel { .. }
        )
    }
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::Failed { id, reason } => write!(f, "request {id}: {reason}"),
            InferError::DeadlineExceeded { id } => {
                write!(f, "request {id}: deadline exceeded before compute started")
            }
            InferError::AdmissionRefused { id, earliest_feasible } => write!(
                f,
                "request {id}: admission refused — SLO provably unmeetable \
                 (earliest feasible budget ≥ {earliest_feasible:?})"
            ),
            InferError::UnknownModel { id, model } => {
                write!(f, "request {id}: model#{model} is not registered")
            }
        }
    }
}

impl std::error::Error for InferError {}

/// What arrives on a reply channel: the inference or a per-request error.
pub type ReplyResult = std::result::Result<Reply, InferError>;

/// Coordinator construction parameters.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub array: ArrayConfig,
    /// Worker cards in the pool (each a full BinArray instance), shared
    /// by both dispatch lanes.
    pub workers: usize,
    pub policy: BatchPolicy,
    /// How requests *without* an explicit [`DispatchClass`] override are
    /// routed (explicit overrides are always honored).
    pub route: RoutePolicy,
    /// Cap on the cards one shard-lane frame may lease (`0` = the whole
    /// pool).  A frame's actual scatter width is `min(max_shard_cards,
    /// cards not busy in the batch lane, pool size)`, decided per lease.
    pub max_shard_cards: usize,
    /// Lease-width hysteresis: how long a pending shard lease may wait
    /// for busy cards to free before accepting a grant narrower than it
    /// asked for.  Per frame the actual budget is further capped at half
    /// the frame's remaining deadline slack (a lease must never spend
    /// the slack it exists to protect).  `Duration::ZERO` = take
    /// whatever is free immediately.
    pub lease_slack: Duration,
    /// Per-[`ServiceClass`] QoS contracts: latency SLO (stamped as the
    /// deadline of requests that don't carry one), default dispatch-lane
    /// bias, and admission budget.  The default table keeps `Standard`
    /// contract-free.
    pub classes: ClassTable,
    /// Cross-lane arbitration rule for the batcher: SLO-aware by
    /// default, oldest-first as the deadline-blind escape hatch.
    pub arbitration: Arbitration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            array: ArrayConfig::new(1, 8, 2),
            workers: 1,
            policy: BatchPolicy::default(),
            route: RoutePolicy::BatchOnly,
            max_shard_cards: 0,
            lease_slack: Duration::ZERO,
            classes: ClassTable::default(),
            arbitration: Arbitration::default(),
        }
    }
}

/// Reply channels of one cut batch, in request order.
type ReplyTxs = Vec<Sender<ReplyResult>>;

enum RouterMsg {
    Submit(Request, Sender<ReplyResult>),
    /// A worker finished a batch and is free again.
    WorkerDone(usize),
    /// The shard orchestrator wants up to `want` cards, and will accept
    /// a narrower grant after `wait` (the frame's hysteresis budget).
    Lease {
        want: usize,
        wait: Duration,
        reply: Sender<Vec<usize>>,
    },
    /// The orchestrator returns leased cards and retires `frames` frames
    /// from the shard-inflight ledger.  `frames` is explicit — the
    /// inflight count is incremented per *request* at dispatch, so the
    /// decrement must not assume shard batches are singletons (today's
    /// `BatchPolicy::effective` invariant, not a law of nature).  A
    /// frame that never got a lease unleases `ids: []`.
    Unlease { ids: Vec<usize>, frames: usize },
    /// The orchestrator discovered a leased card is dead (its channel is
    /// gone): drop it from the pool instead of returning it to `free`.
    Retire(usize),
    /// The orchestrator has drained its queue (shutdown handshake).
    OrchDrained,
    Shutdown,
}

/// One card's slice of one layer of one frame — the scatter payload.
struct ShardJob {
    /// The model this frame was admitted under: the worker resolves (or
    /// lazily builds) its accelerator instance for `(entry.id,
    /// entry.epoch)` before running the tile.
    entry: Arc<ModelEntry>,
    m_run: Option<usize>,
    layer: usize,
    /// Card index into the lease/[`ShardPlan`] (not a worker id — the
    /// orchestrator maps card `c` onto the `c`-th *leased* worker).
    card: usize,
    /// Host threads this card may spend on the job: the lease width
    /// bounds how many cards compute concurrently, so each card gets its
    /// share of the host cores (the full width on every card would
    /// oversubscribe the host with exactly the thread thrash the latency
    /// path exists to avoid).
    intra_threads: usize,
    /// The partition matching this frame's lease width, from the
    /// [`ShardPlanCache`].
    shards: Arc<ShardPlan>,
    /// The layer's full input region (every card streams the whole ping
    /// half, so convolution windows never straddle a card boundary).
    input: Arc<Vec<i8>>,
    reply: Sender<(usize, Result<ShardRun>)>,
}

enum WorkerMsg {
    Run(Batch, ReplyTxs),
    Shard(ShardJob),
    Shutdown,
}

enum OrchMsg {
    Run(Batch, ReplyTxs),
    Shutdown,
}

/// The shard orchestrator's static state.  Everything model-specific
/// (plan, program, shard partitions, capacity) now rides on each frame's
/// pinned [`ModelEntry`] — the orchestrator itself only keeps the
/// pool-level lease policy.
struct ShardOracle {
    /// Most cards one frame asks to lease (`min(max_shard_cards, pool)`).
    max_lease: usize,
    /// Per-frame cap on the lease-width hysteresis wait
    /// ([`CoordinatorConfig::lease_slack`]).
    lease_slack: Duration,
}

/// One inference, described declaratively.  This is the single submit
/// API: every knob the old `submit_*`/`infer_*` method family exposed is
/// a builder setter here, and the defaults reproduce the plain
/// `submit(image, mode)` behavior.
///
/// ```ignore
/// let reply = coordinator.infer(
///     InferRequest::new(image)
///         .mode(Mode::HighThroughput)
///         .model(gtsrb_v2)
///         .service(ServiceClass::Interactive)
///         .deadline(Instant::now() + Duration::from_millis(5))
///         .route(DispatchClass::Shard),
/// )?;
/// ```
#[derive(Clone, Debug)]
pub struct InferRequest {
    image: Vec<i8>,
    mode: Mode,
    model: ModelId,
    route: Option<DispatchClass>,
    deadline: Option<Instant>,
    service: ServiceClass,
}

impl InferRequest {
    /// A request for `image` with every knob at its default:
    /// [`Mode::HighAccuracy`], the registry's default model, routing by
    /// the coordinator's [`RoutePolicy`], no explicit deadline,
    /// [`ServiceClass::Standard`].
    pub fn new(image: Vec<i8>) -> Self {
        Self {
            image,
            mode: Mode::HighAccuracy,
            model: ModelId::DEFAULT,
            route: None,
            deadline: None,
            service: ServiceClass::Standard,
        }
    }

    /// Runtime accuracy mode (§IV-D).
    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }

    /// Which registered model serves this request.
    pub fn model(mut self, model: ModelId) -> Self {
        self.model = model;
        self
    }

    /// Explicit dispatch-lane override (an override is final — the
    /// router never reassigns it).  Accepts a bare [`DispatchClass`] or
    /// an `Option` for call sites that thread one through.
    pub fn route(mut self, route: impl Into<Option<DispatchClass>>) -> Self {
        self.route = route.into();
        self
    }

    /// Absolute completion deadline.  Slack feeds adaptive routing and
    /// lease hysteresis; expired work is answered with
    /// [`InferError::DeadlineExceeded`] instead of being computed.
    pub fn deadline(mut self, deadline: impl Into<Option<Instant>>) -> Self {
        self.deadline = deadline.into();
        self
    }

    /// Named QoS class: its SLO becomes the deadline when none is set,
    /// its dispatch bias applies when no route override is set, and its
    /// admission budget plus the capacity model may *refuse* the work up
    /// front with [`InferError::AdmissionRefused`].
    pub fn service(mut self, service: ServiceClass) -> Self {
        self.service = service;
        self
    }
}

/// Cloneable submit-side handle: many producer threads can feed one
/// coordinator (the `Coordinator` itself stays single-owner so that
/// `shutdown` consumes it).
#[derive(Clone)]
pub struct SubmitHandle {
    router_tx: Sender<RouterMsg>,
    next_id: Arc<AtomicU64>,
}

impl SubmitHandle {
    /// Submit a request; returns a receiver for the reply.
    pub fn submit(&self, req: InferRequest) -> Receiver<ReplyResult> {
        let (tx, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image: req.image,
            mode: req.mode,
            model: req.model,
            entry: None, // resolved (and pinned) by the router at admission
            class: req.route,
            deadline: req.deadline,
            service: req.service,
            submitted: Instant::now(),
        };
        // If the router is gone the receiver will simply yield RecvError.
        let _ = self.router_tx.send(RouterMsg::Submit(req, tx));
        rx
    }

    /// Submit and wait.
    pub fn infer(&self, req: InferRequest) -> Result<Reply> {
        Ok(self.submit(req).recv()??)
    }
}

/// The serving coordinator.
pub struct Coordinator {
    handle: SubmitHandle,
    router: Option<JoinHandle<Metrics>>,
    orchestrator: Option<JoinHandle<Metrics>>,
    workers: Vec<JoinHandle<Metrics>>,
    registry: Arc<ModelRegistry>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Single-model convenience: build a one-entry registry (the model
    /// is registered as `"default"` under `cfg.array`) and start the
    /// pool on it.  Exactly the pre-registry behavior.
    pub fn start(cfg: CoordinatorConfig, net: QuantNetwork) -> Result<Self> {
        let registry = ModelRegistry::new(cfg.workers.max(1));
        registry.register("default", cfg.array, net, 0)?;
        Self::with_registry(cfg, Arc::new(registry))
    }

    /// Spin up the router, `cfg.workers` accelerator workers, and the
    /// shard orchestrator over a shared [`ModelRegistry`].  Both
    /// dispatch lanes are always live — any request may carry an
    /// explicit [`DispatchClass`] override, whatever the [`RoutePolicy`]
    /// says.  Models may be registered or hot-swapped on the registry at
    /// any time; workers build per-model accelerator instances lazily on
    /// first use.
    pub fn with_registry(cfg: CoordinatorConfig, registry: Arc<ModelRegistry>) -> Result<Self> {
        let n_workers = cfg.workers.max(1);
        let (router_tx, router_rx) = channel::<RouterMsg>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));

        // One channel per card: the router sends batches only to *free*
        // cards and the orchestrator sends shard jobs only to cards it
        // holds a lease on, so a leased card's queue never mixes lanes.
        let mut worker_txs: Vec<Sender<WorkerMsg>> = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = channel::<WorkerMsg>();
            worker_txs.push(tx);
            let global = Arc::clone(&metrics);
            let rtx = router_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("binarray-worker-{w}"))
                    .spawn(move || worker_loop(rx, w, rtx, global))?,
            );
        }
        // The registry's shard caches were built for its own card
        // ceiling; leases never exceed what every entry has plans for.
        let max_lease = if cfg.max_shard_cards == 0 {
            n_workers
        } else {
            cfg.max_shard_cards.min(n_workers)
        }
        .min(registry.max_cards());
        let oracle = ShardOracle {
            max_lease,
            lease_slack: cfg.lease_slack,
        };
        // The router's fallback pricing when a request carries no
        // registry entry (unit rigs): the default model's capacity
        // model, or a plain seed for registries populated after start.
        let capacity = registry
            .default_model()
            .map(|e| Arc::clone(&e.capacity))
            .unwrap_or_else(|| Arc::new(CapacityModel::fixed(1_000)));
        let (orch_tx, orch_rx) = channel::<OrchMsg>();
        let orchestrator = {
            let global = Arc::clone(&metrics);
            let rtx = router_tx.clone();
            let wtxs = worker_txs.clone();
            std::thread::Builder::new()
                .name("binarray-shard-orch".into())
                .spawn(move || orchestrator_loop(oracle, orch_rx, rtx, wtxs, global))?
        };

        let router = {
            let state = Router {
                rx: router_rx,
                orch_tx,
                worker_txs,
                policy: cfg.policy,
                route: cfg.route,
                classes: cfg.classes,
                registry: Arc::clone(&registry),
                capacity: Arc::clone(&capacity),
                batcher: Batcher::with_qos(cfg.policy, cfg.classes, cfg.arbitration),
                reply_txs: ReplyMap::new(),
                free: (0..n_workers).collect(),
                live: n_workers,
                leased: 0,
                running: vec![0; n_workers],
                batch_inflight: 0,
                class_inflight: [0; N_CLASSES],
                model_inflight: std::collections::HashMap::new(),
                queued_cycles: [0; N_CLASSES],
                card_load: vec![CardLoad::default(); n_workers],
                orch_ledger: VecDeque::new(),
                orch_cycles: 0,
                pending_batches: VecDeque::new(),
                pending_lease: None,
                shard_inflight: 0,
                shutting: false,
                orch_done: false,
                stalled: 0,
                local: Metrics::default(),
                global: Arc::clone(&metrics),
            };
            std::thread::Builder::new()
                .name("binarray-router".into())
                .spawn(move || state.run())?
        };

        Ok(Self {
            handle: SubmitHandle {
                router_tx,
                next_id: Arc::new(AtomicU64::new(0)),
            },
            router: Some(router),
            orchestrator: Some(orchestrator),
            workers,
            registry,
            metrics,
        })
    }

    /// A cloneable submit handle for producer threads.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    /// The model registry this coordinator serves from — register or
    /// hot-swap models on it at any time.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// Submit a request; returns a receiver for the reply.
    pub fn submit(&self, req: InferRequest) -> Receiver<ReplyResult> {
        self.handle.submit(req)
    }

    /// Submit and wait.
    pub fn infer(&self, req: InferRequest) -> Result<Reply> {
        self.handle.infer(req)
    }

    /// Drain and stop all threads, returning the final metrics.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.handle.router_tx.send(RouterMsg::Shutdown);
        let mut total = Metrics::default();
        // The router exits only after the orchestrator has drained and
        // every queued batch has been handed to a card, then tells the
        // workers to stop — so joining it first is safe and total.
        if let Some(r) = self.router.take() {
            if let Ok(m) = r.join() {
                total.merge(&m);
            }
        }
        if let Some(o) = self.orchestrator.take() {
            if let Ok(m) = o.join() {
                total.merge(&m);
            }
        }
        for w in self.workers.drain(..) {
            if let Ok(m) = w.join() {
                total.merge(&m);
            }
        }
        total
    }
}

/// Registered reply channels keyed by request id.
type ReplyMap = std::collections::HashMap<u64, Sender<ReplyResult>>;

/// The orchestrator's parked request for cards.  While `expires` is in
/// the future the router may hold the lease open waiting for busy cards
/// to free (lease-width hysteresis); at expiry it grants whatever ≥ 1
/// cards are free.
struct PendingLease {
    want: usize,
    reply: Sender<Vec<usize>>,
    /// When the lease was requested (feeds the `lease_wait` metric).
    asked: Instant,
    /// End of the hysteresis window: grant narrow rather than wait past
    /// this point.
    expires: Instant,
}

/// What [`Router::lease_decision`] says to do with a pending lease.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LeaseDecision {
    /// Grant this many cards now.
    Grant(usize),
    /// Keep waiting (hysteresis window still open, or nothing free).
    Wait,
}

/// One card's committed batch-lane work: the estimated cycles it is
/// running and the per-class/per-model request counts — cleared
/// wholesale on `WorkerDone` (the card answers everything it was handed,
/// shed or served, before reporting done).
#[derive(Clone, Debug, Default)]
struct CardLoad {
    cycles: u64,
    count: [u64; N_CLASSES],
    /// Per-model request counts (a batch never mixes models, so this
    /// holds at most one entry — kept as a vec for the same wholesale
    /// retirement the class counts get).
    models: Vec<(u32, u64)>,
}

/// The router thread's state: admission (SLO stamping, budget/capacity
/// gates, classify + batch), the card ledger (which workers are free,
/// busy batching, or leased out, and how much estimated work each
/// holds), and the shutdown drain.
struct Router {
    rx: Receiver<RouterMsg>,
    orch_tx: Sender<OrchMsg>,
    worker_txs: Vec<Sender<WorkerMsg>>,
    policy: BatchPolicy,
    route: RoutePolicy,
    /// Per-class QoS contracts (SLO, lane bias, admission budget).
    classes: ClassTable,
    /// The model registry: admission resolves every request's model here
    /// and pins the published entry onto the request.
    registry: Arc<ModelRegistry>,
    /// Fallback admission pricing for requests that carry no registry
    /// entry (unit rigs driving the router with an empty registry).
    capacity: Arc<CapacityModel>,
    batcher: Batcher,
    reply_txs: ReplyMap,
    /// Card ledger: worker ids neither batching nor leased.
    free: Vec<usize>,
    /// Workers not yet discovered dead (a send to a panicked worker's
    /// channel fails; the card is then dropped from the pool).
    live: usize,
    /// Cards currently out on lease to the shard orchestrator.
    leased: usize,
    /// Requests currently computing on each card in the batch lane
    /// (zero for free/leased cards) — live batches are queue depth the
    /// batcher can't see.
    running: Vec<usize>,
    /// Σ `running`: batch-lane requests handed to cards and not yet
    /// done.  Without this term `Adaptive` keeps sharding while the
    /// pool is saturated — exactly the throughput regime `deep_queue`
    /// exists to detect.
    batch_inflight: usize,
    /// Admitted-but-unanswered requests per service class — the
    /// admission-budget gate.  Incremented at admission; decremented
    /// wherever the answer leaves the router's sight (batcher shed,
    /// failed batch, `WorkerDone`'s card load, `Unlease`'s ledger pops).
    class_inflight: [u64; N_CLASSES],
    /// Admitted-but-unanswered requests per model — the per-model half
    /// of the admission budget (a [`ModelEntry::admission_limit`] caps
    /// it).  Kept balanced exactly like `class_inflight`: incremented at
    /// admission, decremented via `CardLoad::models`, the shard ledger's
    /// model column, batcher sheds and failed batches.
    model_inflight: std::collections::HashMap<u32, u64>,
    /// Estimated cycles still queued in the batcher, per class — the
    /// class-aware slice of the capacity backlog (SLO-aware arbitration
    /// lets an urgent class cut ahead of laxer queued work, so only
    /// equal-or-more-urgent queued cycles count against it).
    queued_cycles: [u64; N_CLASSES],
    /// Per-card committed batch-lane work (see [`CardLoad`]).
    card_load: Vec<CardLoad>,
    /// Shard frames handed to the (FIFO, serial) orchestrator:
    /// `(class index, estimated cycles, model id)` in hand-off order —
    /// popped front-first on every `Unlease`-retired frame.
    orch_ledger: VecDeque<(usize, u64, u32)>,
    /// Σ cycles in `orch_ledger`, maintained at push/pop so the admit
    /// path's backlog read is O(1) instead of an O(ledger) walk.
    orch_cycles: u64,
    /// Batch-lane work waiting for a free card.
    pending_batches: VecDeque<(Batch, ReplyTxs)>,
    /// Shard-lane lease waiting for a free card (at most one: the
    /// orchestrator leases one frame at a time).
    pending_lease: Option<PendingLease>,
    /// Shard frames handed to the orchestrator and not yet finished
    /// (its queue is invisible to the router, so this is the shard
    /// lane's contribution to the queue-depth signal).
    shard_inflight: usize,
    shutting: bool,
    orch_done: bool,
    /// Consecutive silent ticks while shutting (see the stall valve in
    /// [`Self::run`]).
    stalled: u32,
    local: Metrics,
    global: Arc<Mutex<Metrics>>,
}

/// Shutdown stall valve: after this many consecutive silent 1-second
/// ticks with the drain still blocked, the remaining cards are presumed
/// dead (panicked mid-work, so their WorkerDone will never come) and the
/// parked work is answered with errors instead of wedging `shutdown()`
/// forever.  Generous on purpose: a healthy drain produces router
/// traffic far more often than once a minute.
const SHUTDOWN_STALL_TICKS: u32 = 60;

impl Router {
    fn run(mut self) -> Metrics {
        loop {
            let msg = match self.wake_after() {
                // idle: block until something happens
                None => self.rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
                Some(d) => self.rx.recv_timeout(d),
            };
            match msg {
                Ok(m) => {
                    self.stalled = 0;
                    self.handle(m);
                }
                Err(RecvTimeoutError::Disconnected) => {
                    if self.shutting {
                        // every sender is gone mid-drain: nothing more
                        // can arrive, stop instead of spinning
                        break;
                    }
                    self.begin_shutdown();
                }
                Err(RecvTimeoutError::Timeout) => self.on_tick(),
            }
            self.pump(Instant::now());
            // Drained: orchestrator dry, every batch handed to a card,
            // every lease returned — the pool can stop.
            if self.shutting
                && self.orch_done
                && self.pending_lease.is_none()
                && self.pending_batches.is_empty()
                && self.leased == 0
            {
                break;
            }
        }
        for tx in &self.worker_txs {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        self.local
    }

    /// How long the loop may sleep before something it owns needs
    /// attention.  `None` = block indefinitely (fully idle).  A fixed
    /// polling tick burns the core the workers need — it cost ~20 %
    /// end-to-end on a single-core host (EXPERIMENTS.md §Perf) — so
    /// every timeout here is tied to a real event: the oldest queued
    /// request's `max_delay`, a pending lease's hysteresis expiry (only
    /// meaningful while a card is free to grant — with none free the
    /// next `WorkerDone`/`Unlease` message wakes the loop anyway), or
    /// the once-a-second shutdown drain tick that keeps a dead pool
    /// from wedging `shutdown()`.
    fn wake_after(&self) -> Option<Duration> {
        let mut wake: Option<Duration> = None;
        if self.shutting {
            wake = Some(Duration::from_secs(1));
        } else if self.batcher.pending() > 0
            && !self.free.is_empty()
            && self.pending_lease.is_none()
            && self.pending_batches.is_empty()
        {
            // Queued work that a free card could cut once it ripens.
            // With no card free (or the pool spoken for by a lease) the
            // timer stays unarmed: cuts are gated on a free card anyway,
            // and the WorkerDone/Unlease that frees one wakes the loop —
            // re-arming here would busy-spin at max_delay == 0.
            wake = Some(self.policy.max_delay.min(Duration::from_millis(50)));
        }
        if let Some(d) = self.batcher.next_deadline() {
            // Deadlined work queued: wake at its deadline so the shed
            // gate answers it promptly even while every card is busy
            // (the 100 µs floor keeps a just-passed — possibly
            // stale-low — cache from spinning the loop; the next pump's
            // shed scan refreshes it).
            let until = d
                .saturating_duration_since(Instant::now())
                .max(Duration::from_micros(100));
            wake = Some(wake.map_or(until, |w| w.min(until)));
        }
        if let Some(pl) = &self.pending_lease {
            if !self.free.is_empty() {
                let remaining = pl.expires.saturating_duration_since(Instant::now());
                let until = remaining.max(Duration::from_micros(100));
                wake = Some(wake.map_or(until, |w| w.min(until)));
            }
        }
        wake
    }

    /// Apply one router message to the ledger.  Factored out of the
    /// loop so the failure paths (card retirement, orchestrator death,
    /// the shutdown stall valve) are deterministically testable message
    /// by message.
    fn handle(&mut self, msg: RouterMsg) {
        match msg {
            RouterMsg::Submit(req, tx) => self.admit(req, tx),
            RouterMsg::WorkerDone(w) => {
                self.batch_inflight = self.batch_inflight.saturating_sub(self.running[w]);
                self.running[w] = 0;
                // The card answered everything it was handed (served,
                // shed or errored): retire its committed load and the
                // per-class inflight slots in one go.
                let load = std::mem::take(&mut self.card_load[w]);
                for (ci, n) in load.count.iter().enumerate() {
                    self.class_inflight[ci] = self.class_inflight[ci].saturating_sub(*n);
                }
                for (model, n) in load.models {
                    self.retire_model(model, n);
                }
                self.free.push(w);
                self.service();
            }
            RouterMsg::Lease { want, wait, reply } => {
                debug_assert!(self.pending_lease.is_none(), "one orchestrator, one lease");
                let now = Instant::now();
                // a runaway wait must not overflow Instant arithmetic
                let wait = wait.min(Duration::from_secs(3600));
                self.pending_lease = Some(PendingLease {
                    want,
                    reply,
                    asked: now,
                    expires: now + wait,
                });
                self.service();
            }
            RouterMsg::Unlease { ids, frames } => {
                self.shard_inflight = self.shard_inflight.saturating_sub(frames);
                // The orchestrator answers frames in hand-off order (it
                // is serial and FIFO), so each retired frame pops the
                // front of the shard ledger.
                for _ in 0..frames {
                    if let Some((ci, cycles, model)) = self.orch_ledger.pop_front() {
                        self.class_inflight[ci] = self.class_inflight[ci].saturating_sub(1);
                        self.orch_cycles = self.orch_cycles.saturating_sub(cycles);
                        self.retire_model(model, 1);
                    }
                }
                self.leased = self.leased.saturating_sub(ids.len());
                self.free.extend(ids);
                self.service();
            }
            RouterMsg::Retire(_) => {
                // the orchestrator found a leased card dead: it
                // leaves the pool instead of rejoining `free`
                self.leased = self.leased.saturating_sub(1);
                self.live = self.live.saturating_sub(1);
                if self.live == 0 {
                    self.fail_pending("worker pool is gone");
                }
                self.service();
            }
            RouterMsg::OrchDrained => self.orch_done = true,
            RouterMsg::Shutdown => self.begin_shutdown(),
        }
    }

    /// A `recv` timeout fired: while shutting, count toward the stall
    /// valve.  (Expired lease-hysteresis windows are handled by the
    /// `service` in the caller's `pump`.)
    fn on_tick(&mut self) {
        if self.shutting {
            self.stalled += 1;
            if self.stalled >= SHUTDOWN_STALL_TICKS {
                // Whatever is still outstanding will never finish (dead
                // cards / dead orchestrator): answer what can be
                // answered, zero the work ledgers the dead threads will
                // never retire, and let the drain conditions fall
                // through.
                self.fail_pending("worker pool stalled during shutdown");
                self.leased = 0;
                self.orch_done = true;
                self.orch_ledger.clear();
                self.orch_cycles = 0;
                self.card_load.fill(CardLoad::default());
                self.class_inflight = [0; N_CLASSES];
                self.model_inflight.clear();
            }
        }
    }

    /// Per-request estimated cycles: the pinned model entry's pricing,
    /// or the router's fallback capacity model for rig requests that
    /// bypassed the registry.
    fn est_of(&self, req: &Request) -> u64 {
        match &req.entry {
            Some(e) => e.capacity.est_cycles(req.mode),
            None => self.capacity.est_cycles(req.mode),
        }
    }

    /// Retire `n` admitted-request slots from a model's inflight count.
    fn retire_model(&mut self, model: u32, n: u64) {
        if let Some(v) = self.model_inflight.get_mut(&model) {
            *v = v.saturating_sub(n);
            if *v == 0 {
                self.model_inflight.remove(&model);
            }
        }
    }

    /// Post-message housekeeping: shed queued work whose deadline
    /// already passed (before it costs a cut, a card or a lease), cut
    /// and dispatch ripe batches, and re-examine the pending lease
    /// (its hysteresis window may just have expired).
    fn pump(&mut self, now: Instant) {
        for req in self.batcher.shed_expired(now) {
            // the request leaves the queue: retire its admission ledgers
            let ci = req.service.index();
            self.class_inflight[ci] = self.class_inflight[ci].saturating_sub(1);
            self.queued_cycles[ci] = self.queued_cycles[ci].saturating_sub(self.est_of(&req));
            self.retire_model(req.model.0, 1);
            let Some(tx) = self.reply_txs.remove(&req.id) else {
                continue;
            };
            let mut delta = Metrics::default();
            send_shed(&mut delta, &req, &tx);
            self.note(delta);
        }
        // Batch-lane cuts are gated on a card that can take the work
        // *now* (free, not spoken for by a lease, no batch already
        // parked ahead): the cut is the arbitration decision, so it
        // must happen at card-free time over the whole queue — cutting
        // eagerly and parking FIFO would freeze the lane pick long
        // before a card frees and quietly defeat SLO-aware arbitration
        // under overload.  Shard-class cuts stay eager: the
        // orchestrator owns its own (depth-tracked) queue.
        loop {
            let allow_batch = !self.free.is_empty()
                && self.pending_lease.is_none()
                && self.pending_batches.is_empty();
            let Some(batch) = self.batcher.cut_gated(now, allow_batch) else {
                break;
            };
            self.dispatch_cut(batch);
        }
        self.service();
    }

    /// Everything admitted but not finished: queued in the batcher, cut
    /// but parked for a free card, queued/running on the (serial) shard
    /// orchestrator, AND running on busy batch cards.  Under overload
    /// the real backlog lives in the parked/running terms, and ignoring
    /// them would keep `Adaptive` sharding in exactly the throughput
    /// regime `deep_queue` exists to detect.
    fn queue_depth(&self) -> usize {
        let parked: usize = self.pending_batches.iter().map(|(b, _)| b.requests.len()).sum();
        self.batcher.pending() + parked + self.shard_inflight + self.batch_inflight
    }

    /// Estimated cycles committed ahead of a new request of `service`:
    /// everything running on cards or already cut (parked batches, the
    /// orchestrator's FIFO queue) counts in full — it cannot be
    /// reordered — while batcher-queued work counts only for classes at
    /// least as urgent (SLO-aware arbitration lets the new request cut
    /// ahead of laxer queues).  Under-counting is safe here: the
    /// capacity gate refuses only when even this floor overshoots the
    /// deadline.
    fn backlog_cycles(&self, service: ServiceClass) -> u64 {
        let queued: u64 = self.queued_cycles[..=service.index()].iter().sum();
        let parked: u64 = self
            .pending_batches
            .iter()
            .flat_map(|(b, _)| b.requests.iter())
            .map(|r| self.est_of(r))
            .sum();
        let running: u64 = self.card_load.iter().map(|l| l.cycles).sum();
        queued
            .saturating_add(parked)
            .saturating_add(running)
            .saturating_add(self.orch_cycles)
    }

    /// The capacity model's floor on how much end-to-end budget a new
    /// request of `(service, mode)` needs right now (always finite —
    /// models are seeded with their plan-derived pace at construction).
    /// `cap` is the request's model's pricing (or the fallback).
    fn earliest_feasible(&self, cap: &CapacityModel, service: ServiceClass, mode: Mode) -> Duration {
        cap.earliest_feasible(mode, self.backlog_cycles(service), self.live.max(1))
    }

    /// Admit one request: stamp its class SLO as the deadline, apply the
    /// admission gates (budget, capacity), classify, and queue — or
    /// answer it on the spot (refused mid-shutdown, shed when already
    /// expired, `AdmissionRefused` when the gates prove the SLO
    /// unmeetable).  Refused work is never queued and never computed.
    /// The dispatch class is stamped exactly once here; the batcher and
    /// dispatch never reassign it.
    fn admit(&mut self, mut req: Request, tx: Sender<ReplyResult>) {
        let ci = req.service.index();
        {
            let mut delta = Metrics::default();
            delta.submitted = 1;
            delta.classes[ci].submitted = 1;
            delta.models.entry(req.model.0).or_default().submitted = 1;
            self.note(delta);
        }
        if self.shutting {
            let mut delta = Metrics::default();
            send_error(&mut delta, req.id, &tx, &anyhow!("coordinator is shutting down"));
            self.note(delta);
            return;
        }
        // Resolve the model.  The registry's *current* published entry
        // is pinned onto the request here — a concurrent hot swap never
        // changes what an admitted request runs on.  An unknown model is
        // a typed refusal; an empty registry (unit rigs driving the
        // router directly) keeps the pre-registry fallback pricing.
        match self.registry.get(req.model) {
            Some(e) => req.entry = Some(e),
            None if self.registry.is_empty() => {}
            None => {
                let mut delta = Metrics::default();
                send_unknown_model(&mut delta, &req, &tx);
                self.note(delta);
                return;
            }
        }
        let cap: Arc<CapacityModel> = req
            .entry
            .as_ref()
            .map(|e| Arc::clone(&e.capacity))
            .unwrap_or_else(|| Arc::clone(&self.capacity));
        let spec = *self.classes.spec(req.service);
        // A class SLO becomes the request's deadline (explicit deadlines
        // win): from here on the whole deadline machinery — EDF cuts,
        // shed gates, met/missed accounting — enforces the SLO.
        if req.deadline.is_none() {
            req.deadline = spec.slo.map(|slo| req.submitted + slo);
        }
        let now = Instant::now();
        if req.expired(now) {
            let mut delta = Metrics::default();
            send_shed(&mut delta, &req, &tx);
            self.note(delta);
            return;
        }
        // Gate 1: the class admission budget — at the cap, refuse
        // instead of queueing work the class has no room for.
        if spec.admission_limit > 0 && self.class_inflight[ci] >= spec.admission_limit as u64 {
            let earliest = self.earliest_feasible(&cap, req.service, req.mode);
            let mut delta = Metrics::default();
            send_refused(&mut delta, &req, &tx, earliest);
            self.note(delta);
            return;
        }
        // Gate 1b: the per-model admission budget (together with the
        // class budget: per-(tenant, model) limits).
        if let Some(e) = &req.entry {
            let inflight = self.model_inflight.get(&e.id.0).copied().unwrap_or(0);
            if e.admission_limit > 0 && inflight >= e.admission_limit as u64 {
                let earliest = self.earliest_feasible(&cap, req.service, req.mode);
                let mut delta = Metrics::default();
                send_refused(&mut delta, &req, &tx, earliest);
                self.note(delta);
                return;
            }
        }
        // Gate 2: the capacity model — refuse a deadline that even the
        // pool's best observed pace can't meet over the committed
        // backlog.  Provably-unmeetable work is answered in O(1) here
        // instead of riding the queue to the shed gate.  The gate is a
        // *class* contract: only classes that declare an SLO opt into
        // refusal — a bare deadline on an SLO-free class keeps the
        // scalar-deadline semantics (queue, maybe shed) unchanged.
        if let (Some(_), Some(d)) = (spec.slo, req.deadline) {
            let need = self.earliest_feasible(&cap, req.service, req.mode);
            if now + need > d {
                let mut delta = Metrics::default();
                send_refused(&mut delta, &req, &tx, need);
                self.note(delta);
                return;
            }
        }
        let depth = self.queue_depth();
        let slack = req.slack(now);
        // A caller's explicit lane override wins; otherwise the class's
        // dispatch bias; otherwise the route policy decides.
        let class = self
            .route
            .route(req.class.or(spec.dispatch_bias), req.image.len(), depth, slack);
        req.class = Some(class);
        let mut delta = Metrics::default();
        match class {
            DispatchClass::Batch => delta.routed_batch = 1,
            DispatchClass::Shard => delta.routed_shard = 1,
        }
        self.note(delta);
        self.class_inflight[ci] += 1;
        *self.model_inflight.entry(req.model.0).or_insert(0) += 1;
        self.queued_cycles[ci] =
            self.queued_cycles[ci].saturating_add(cap.est_cycles(req.mode));
        self.reply_txs.insert(req.id, tx);
        self.batcher.push(req);
    }

    /// Hand a cut batch to its lane.  A request whose reply channel is
    /// already gone was answered at another gate (shed at the queue,
    /// refused, failed) — it is dropped from the batch here, tolerantly:
    /// the old `.expect("reply channel registered")` panicked the whole
    /// router thread on that overlap, exactly on the failure paths where
    /// the answer mattered most.
    fn dispatch_cut(&mut self, batch: Batch) {
        let Batch { mode, class, model, entry, requests: cut } = batch;
        let mut requests = Vec::with_capacity(cut.len());
        let mut txs: ReplyTxs = Vec::with_capacity(cut.len());
        for r in cut {
            let Some(tx) = self.reply_txs.remove(&r.id) else {
                continue; // answered elsewhere; nothing left to do
            };
            // the request leaves the batcher queue: move its estimated
            // cycles out of the queued ledger (it rides the dispatched
            // ledgers from here)
            let ci = r.service.index();
            self.queued_cycles[ci] = self.queued_cycles[ci].saturating_sub(self.est_of(&r));
            requests.push(r);
            txs.push(tx);
        }
        if requests.is_empty() {
            return;
        }
        let batch = Batch { mode, class, model, entry, requests };
        match batch.class {
            DispatchClass::Batch => self.dispatch_batch(batch, txs),
            DispatchClass::Shard => {
                let ledger: Vec<(usize, u64, u32)> = batch
                    .requests
                    .iter()
                    .map(|r| (r.service.index(), self.est_of(r), r.model.0))
                    .collect();
                let n = batch.requests.len();
                if let Err(e) = self.orch_tx.send(OrchMsg::Run(batch, txs)) {
                    let OrchMsg::Run(b, t) = e.0 else { unreachable!() };
                    self.fail_batch(b, t, "shard orchestrator is gone");
                } else {
                    self.shard_inflight += n;
                    for &(_, cycles, _) in &ledger {
                        self.orch_cycles = self.orch_cycles.saturating_add(cycles);
                    }
                    self.orch_ledger.extend(ledger);
                }
            }
        }
    }

    /// Send a batch to a free card, or park it until one frees up.  A
    /// pending lease owns the free cards for its (bounded) hysteresis
    /// window — the shard lane is the latency lane, and a batch
    /// snatching the card the lease was waiting on would defeat the
    /// wait — so fresh cuts park while a lease is pending.
    fn dispatch_batch(&mut self, mut batch: Batch, mut txs: ReplyTxs) {
        if self.pending_lease.is_some() {
            self.pending_batches.push_back((batch, txs));
            return;
        }
        let n = batch.requests.len();
        let mut load = CardLoad::default();
        for r in &batch.requests {
            load.cycles = load.cycles.saturating_add(self.est_of(r));
            load.count[r.service.index()] += 1;
            match load.models.iter_mut().find(|(m, _)| *m == r.model.0) {
                Some(slot) => slot.1 += 1,
                None => load.models.push((r.model.0, 1)),
            }
        }
        while let Some(w) = self.free.pop() {
            match self.worker_txs[w].send(WorkerMsg::Run(batch, txs)) {
                Ok(()) => {
                    self.running[w] = n;
                    self.batch_inflight += n;
                    self.card_load[w] = load;
                    return;
                }
                Err(e) => {
                    // card `w` is dead (panicked thread): drop it from
                    // the pool and try the next free card
                    self.live = self.live.saturating_sub(1);
                    let WorkerMsg::Run(b, t) = e.0 else { unreachable!() };
                    batch = b;
                    txs = t;
                }
            }
        }
        if self.live == 0 {
            self.fail_batch(batch, txs, "worker pool is gone");
            // nothing parked can ever run either — a pending lease left
            // waiting here would hang the orchestrator and its clients
            self.fail_pending("worker pool is gone");
        } else {
            self.pending_batches.push_back((batch, txs));
        }
    }

    /// A card freed up (or a lease/batch is newly pending, or a
    /// hysteresis window may have expired): decide the pending lease
    /// first — the shard lane is the latency lane — then, only once no
    /// lease is waiting, drain parked batches onto the free cards.
    fn service(&mut self) {
        if let Some(pl) = self.pending_lease.take() {
            match self.lease_decision(&pl, Instant::now()) {
                LeaseDecision::Grant(k) => self.grant_lease(pl, k),
                LeaseDecision::Wait => self.pending_lease = Some(pl),
            }
        }
        if self.pending_lease.is_some() {
            // the free cards are spoken for until the lease resolves
            return;
        }
        while !self.free.is_empty() {
            let Some((batch, txs)) = self.pending_batches.pop_front() else {
                break;
            };
            self.dispatch_batch(batch, txs);
        }
    }

    /// Lease-width hysteresis: grant immediately once the full ask (or
    /// as much of it as live cards can ever cover) is free; otherwise
    /// hold the lease open until its window expires, then grant
    /// whatever ≥ 1 cards are free.  While shutting there is no point
    /// waiting — grant what's there and keep the drain moving.
    fn lease_decision(&self, pl: &PendingLease, now: Instant) -> LeaseDecision {
        if self.free.is_empty() {
            // nothing to grant; the next WorkerDone/Unlease re-decides
            return LeaseDecision::Wait;
        }
        let target = pl.want.min(self.live).max(1);
        if self.free.len() >= target {
            return LeaseDecision::Grant(target);
        }
        if self.shutting || now >= pl.expires {
            return LeaseDecision::Grant(self.free.len());
        }
        LeaseDecision::Wait
    }

    /// Grant `k` free cards to the pending lease (a 1-card grant is the
    /// degenerate single-card shard — still bit-exact, just no latency
    /// win).
    fn grant_lease(&mut self, pl: PendingLease, k: usize) {
        debug_assert!(k >= 1 && k <= self.free.len());
        let ids: Vec<usize> = self.free.split_off(self.free.len() - k);
        match pl.reply.send(ids) {
            Ok(()) => {
                self.leased += k;
                let waited = Instant::now().saturating_duration_since(pl.asked);
                let mut delta = Metrics::default();
                delta.lease_wait.record(waited);
                self.note(delta);
            }
            // orchestrator died mid-request: keep the cards
            Err(e) => self.free.extend(e.0),
        }
    }

    /// Answer everything parked on cards that will never free up: every
    /// pending batch errors out, and a pending lease gets an empty grant
    /// (the orchestrator answers its frame with an error and drains on).
    fn fail_pending(&mut self, reason: &str) {
        while let Some((batch, txs)) = self.pending_batches.pop_front() {
            self.fail_batch(batch, txs, reason);
        }
        if let Some(pl) = self.pending_lease.take() {
            let _ = pl.reply.send(Vec::new());
        }
    }

    /// Answer every request of an undeliverable batch with an error
    /// (and retire its admission slots — the answers just went out).
    fn fail_batch(&mut self, batch: Batch, txs: ReplyTxs, reason: &str) {
        let mut delta = Metrics::default();
        let e = anyhow!("{reason}");
        for (req, tx) in batch.requests.into_iter().zip(&txs) {
            let ci = req.service.index();
            self.class_inflight[ci] = self.class_inflight[ci].saturating_sub(1);
            self.retire_model(req.model.0, 1);
            send_error(&mut delta, req.id, tx, &e);
        }
        self.note(delta);
    }

    /// Flush the batcher and start the drain; the exit condition in
    /// [`Self::run`] stops the pool once both lanes are dry.
    fn begin_shutdown(&mut self) {
        if self.shutting {
            return;
        }
        self.shutting = true;
        for batch in self.batcher.flush() {
            self.dispatch_cut(batch);
        }
        let _ = self.orch_tx.send(OrchMsg::Shutdown);
    }

    /// Record a metrics delta locally and in the live global view.
    fn note(&mut self, delta: Metrics) {
        self.local.merge(&delta);
        if let Ok(mut g) = self.global.lock() {
            g.merge(&delta);
        }
    }
}

/// Record one successful frame into `delta` and answer its caller.
/// Deadlined frames count `deadline_met`/`deadline_missed` off the
/// moment the reply is sent — a late frame still completes (the shed
/// paths already refused it everywhere refusing was cheaper).
fn send_reply(
    delta: &mut Metrics,
    req: Request,
    tx: &Sender<ReplyResult>,
    logits: Vec<i8>,
    cycles: u64,
    compute_wall: Duration,
) {
    let latency = req.submitted.elapsed();
    delta.completed += 1;
    delta.sim_cycles += cycles;
    delta.latency.record(latency);
    // Queue wait = time from submit until this request's compute began
    // (replies land after the compute, so the compute wall is not wait).
    delta.queue_wait.record(latency.saturating_sub(compute_wall));
    let cm = &mut delta.classes[req.service.index()];
    cm.completed += 1;
    cm.latency.record(latency);
    let mm = model_metrics(delta, &req);
    mm.completed += 1;
    mm.latency.record(latency);
    if let Some(d) = req.deadline {
        if Instant::now() <= d {
            delta.deadline_met += 1;
            delta.classes[req.service.index()].slo_met += 1;
        } else {
            delta.deadline_missed += 1;
            delta.classes[req.service.index()].slo_missed += 1;
        }
    }
    let reply = Reply {
        id: req.id,
        class: golden::argmax(&logits),
        logits,
        cycles,
        latency,
        mode: req.mode,
    };
    let _ = tx.send(Ok(reply));
}

fn send_error(delta: &mut Metrics, id: u64, tx: &Sender<ReplyResult>, e: &anyhow::Error) {
    delta.failed += 1;
    let _ = tx.send(Err(InferError::Failed {
        id,
        reason: format!("{e:#}"),
    }));
}

/// Shed one expired request: answered (never dropped) with the typed
/// deadline error, counted into both `failed` and `deadline_shed`.
fn send_shed(delta: &mut Metrics, req: &Request, tx: &Sender<ReplyResult>) {
    debug_assert!(req.deadline.is_some(), "only deadlined requests shed");
    delta.failed += 1;
    delta.deadline_shed += 1;
    delta.classes[req.service.index()].shed += 1;
    let _ = tx.send(Err(InferError::DeadlineExceeded { id: req.id }));
}

/// Refuse one request at admission: answered with the typed refusal —
/// counted as `admission_refused`, *not* `failed` (the work was never
/// admitted; `submitted == completed + failed + admission_refused`).
fn send_refused(
    delta: &mut Metrics,
    req: &Request,
    tx: &Sender<ReplyResult>,
    earliest_feasible: Duration,
) {
    delta.admission_refused += 1;
    delta.classes[req.service.index()].admission_refused += 1;
    model_metrics(delta, req).refused += 1;
    let _ = tx.send(Err(InferError::AdmissionRefused {
        id: req.id,
        earliest_feasible,
    }));
}

/// Refuse a request naming a model the registry doesn't serve: typed,
/// counted into the refusal bucket (globally, per class and per model),
/// never queued.
fn send_unknown_model(delta: &mut Metrics, req: &Request, tx: &Sender<ReplyResult>) {
    delta.admission_refused += 1;
    delta.classes[req.service.index()].admission_refused += 1;
    model_metrics(delta, req).refused += 1;
    let _ = tx.send(Err(InferError::UnknownModel {
        id: req.id,
        model: req.model.0,
    }));
}

/// The per-model metrics slot for a request, its display name adopted
/// from the pinned entry the first time one is seen.
fn model_metrics<'a>(delta: &'a mut Metrics, req: &Request) -> &'a mut ModelMetrics {
    let mm = delta.models.entry(req.model.0).or_default();
    if mm.name.is_empty() {
        if let Some(e) = &req.entry {
            mm.name = e.name.to_string();
        }
    }
    mm
}

/// Drop guard armed around a worker's batch: if the thread panics
/// mid-batch, the unwind still posts this card's `WorkerDone`, so the
/// router retires its committed load and per-class admission slots
/// instead of leaking them into permanently inflated backlog (and
/// spurious refusals).  The freed card's next dispatch fails its send
/// and retires it through the normal dead-card path; the batch's reply
/// channels drop with the stack, answering callers with `RecvError`.
struct WorkerDoneGuard {
    id: usize,
    router_tx: Sender<RouterMsg>,
    armed: bool,
}

impl Drop for WorkerDoneGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.router_tx.send(RouterMsg::WorkerDone(self.id));
        }
    }
}

/// Resolve (or lazily build) this card's accelerator instance for a
/// model entry.  Keyed by model id, validated by epoch: a hot swap bumps
/// the epoch, so the first post-swap batch rebuilds from the entry's
/// already-compiled parts — no recompile, just executor construction —
/// and every later batch reuses it.
fn system_for<'a>(
    systems: &'a mut std::collections::HashMap<u32, (u64, BinArraySystem)>,
    entry: &ModelEntry,
) -> Result<&'a mut BinArraySystem> {
    let stale = match systems.get(&entry.id.0) {
        Some((epoch, _)) => *epoch != entry.epoch,
        None => true,
    };
    if stale {
        let sys = BinArraySystem::from_parts(
            entry.cfg,
            (*entry.net).clone(),
            (*entry.prog).clone(),
            (*entry.plan).clone(),
        )?;
        systems.insert(entry.id.0, (entry.epoch, sys));
    }
    Ok(&mut systems.get_mut(&entry.id.0).expect("just inserted").1)
}

fn worker_loop(
    rx: Receiver<WorkerMsg>,
    id: usize,
    router_tx: Sender<RouterMsg>,
    global: Arc<Mutex<Metrics>>,
) -> Metrics {
    let mut local = Metrics::default();
    // One accelerator instance per (model, epoch), built on first use.
    let mut systems: std::collections::HashMap<u32, (u64, BinArraySystem)> =
        std::collections::HashMap::new();
    let full_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    loop {
        let Ok(msg) = rx.recv() else { break };
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Shard(job) => {
                let sys = match system_for(&mut systems, &job.entry) {
                    Ok(sys) => sys,
                    Err(e) => {
                        // answered like a result — the orchestrator
                        // counts one reply per dispatched job
                        let _ = job.reply.send((job.card, Err(e)));
                        continue;
                    }
                };
                // Leased to the shard orchestrator: this card's share of
                // the host cores is bounded by the lease width (stamped
                // on the job), so concurrent cards don't thrash the host.
                sys.set_host_threads(job.intra_threads);
                sys.set_mode(job.m_run);
                let shard = &job.shards.mode(job.m_run)[job.layer].cards[job.card];
                let res = sys.run_shard(job.layer, &job.input, shard);
                // The orchestrator counts one reply per dispatched job;
                // errors must be answered like results.  No WorkerDone
                // here — the orchestrator returns the whole lease itself.
                let _ = job.reply.send((job.card, res));
            }
            WorkerMsg::Run(batch, txs) => {
                let mut done_guard = WorkerDoneGuard {
                    id,
                    router_tx: router_tx.clone(),
                    armed: true,
                };
                let mut delta = Metrics::default();
                delta.batches += 1;
                'run: {
                    // Batches never mix models (the batcher's lanes are
                    // keyed by (model, epoch)), so one resolve serves
                    // the whole batch.
                    let Some(entry) = batch.entry.clone() else {
                        let e = anyhow!("batch carries no model entry");
                        for (req, tx) in batch.requests.into_iter().zip(&txs) {
                            send_error(&mut delta, req.id, tx, &e);
                        }
                        break 'run;
                    };
                    let sys = match system_for(&mut systems, &entry) {
                        Ok(sys) => sys,
                        Err(e) => {
                            for (req, tx) in batch.requests.into_iter().zip(&txs) {
                                send_error(&mut delta, req.id, tx, &e);
                            }
                            break 'run;
                        }
                    };
                    sys.set_host_threads(full_threads);
                    // §IV-D: one mode switch per batch, not per frame.
                    let m_run = batch.mode.m_run(entry.max_m(), entry.cfg.m_arch);
                    sys.set_mode(Some(m_run));
                    // Answer malformed requests up front (the only way a
                    // request alone can sink `run_frames`), so a poisoned
                    // frame never costs its batchmates any compute — and
                    // never kills this worker, stranding callers on
                    // RecvError.  Expired requests are shed here too: this
                    // is the last gate before the card burns cycles on them.
                    let want_len = sys.input_shape.len();
                    let now = Instant::now();
                    let mut good: Vec<(Request, &Sender<ReplyResult>)> = Vec::new();
                    for (req, tx) in batch.requests.into_iter().zip(&txs) {
                        if req.expired(now) {
                            send_shed(&mut delta, &req, tx);
                        } else if req.image.len() == want_len {
                            good.push((req, tx));
                        } else {
                            let e = anyhow!("image len {} != {want_len}", req.image.len());
                            send_error(&mut delta, req.id, tx, &e);
                        }
                    }
                    // The surviving batch runs back-to-back on the
                    // precomputed plan — one `run_frames` call, zero
                    // per-frame setup.
                    let images: Vec<&[i8]> = good.iter().map(|(r, _)| r.image.as_slice()).collect();
                    let t0 = Instant::now();
                    match sys.run_frames(&images) {
                        Ok(results) => {
                            let batch_wall = t0.elapsed();
                            // calibrate this model's admission capacity:
                            // the card just did `results.len()` frames of
                            // this mode in `batch_wall`
                            entry.capacity.observe(batch.mode, results.len(), batch_wall, 1);
                            for ((req, tx), (logits, stats)) in good.into_iter().zip(results) {
                                send_reply(&mut delta, req, tx, logits, stats.cycles, batch_wall);
                            }
                            delta.sim_wall += batch_wall;
                            delta.batch_wall += batch_wall;
                        }
                        Err(_) => {
                            // Defense in depth for failures validation can't
                            // see: retry frames one by one so whatever frame
                            // is poisoned errors alone.
                            for (req, tx) in good {
                                let t1 = Instant::now();
                                match sys.run_frames(&[&req.image]) {
                                    Ok(mut rs) => {
                                        let (logits, stats) = rs.pop().expect("one frame in/out");
                                        let wall = t1.elapsed();
                                        entry.capacity.observe(batch.mode, 1, wall, 1);
                                        send_reply(&mut delta, req, tx, logits, stats.cycles, wall);
                                        delta.sim_wall += wall;
                                        delta.batch_wall += wall;
                                    }
                                    Err(e) => send_error(&mut delta, req.id, tx, &e),
                                }
                            }
                        }
                    }
                }
                local.merge(&delta);
                if let Ok(mut g) = global.lock() {
                    g.merge(&delta); // live view across all workers
                }
                // Tell the arbiter this card is free again.
                done_guard.armed = false;
                let _ = router_tx.send(RouterMsg::WorkerDone(id));
            }
        }
    }
    local
}

/// The shard orchestrator: owns each in-flight frame's CU and ping-pong
/// feature buffer, leases cards from the router per frame, scatters every
/// layer's row tiles to the leased cards' queues, and gathers the output
/// tiles back before triggering the next layer.  The CU is the same state
/// machine the in-card executor uses, so instruction-cycle accounting is
/// identical on both paths.
fn orchestrator_loop(
    oracle: ShardOracle,
    rx: Receiver<OrchMsg>,
    router_tx: Sender<RouterMsg>,
    worker_txs: Vec<Sender<WorkerMsg>>,
    global: Arc<Mutex<Metrics>>,
) -> Metrics {
    let mut local = Metrics::default();
    let mut cu = ControlUnit::new();
    // Per-frame scratch: regrown/re-parked per frame, since multi-model
    // traffic interleaves arbitrarily on this (serial) lane.
    let mut fbuf: Vec<i8> = Vec::new();
    // Recycled DMA-broadcast buffers (see `run_sharded_frame`).
    let mut spare: Vec<Vec<i8>> = Vec::new();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    loop {
        let Ok(msg) = rx.recv() else { break };
        match msg {
            OrchMsg::Shutdown => break,
            OrchMsg::Run(batch, txs) => {
                let mut delta = Metrics::default();
                delta.batches += 1;
                for (req, tx) in batch.requests.into_iter().zip(&txs) {
                    // Every frame runs on the model entry pinned at
                    // admission — a hot swap mid-queue never changes the
                    // plan an already-admitted frame scatters under.
                    let Some(entry) = req.entry.clone() else {
                        let e = anyhow!("request carries no model entry");
                        send_error(&mut delta, req.id, tx, &e);
                        let _ = router_tx.send(RouterMsg::Unlease {
                            ids: Vec::new(),
                            frames: 1,
                        });
                        continue;
                    };
                    let m_run = Some(req.mode.m_run(entry.max_m(), entry.cfg.m_arch));
                    // Last gate before a lease is spent: a frame whose
                    // deadline already passed is shed, not scattered.
                    // Its slot in the router's shard-inflight ledger is
                    // still retired — one Unlease per frame, lease or
                    // not, keeps the Adaptive depth signal exact.
                    let now = Instant::now();
                    if req.expired(now) {
                        send_shed(&mut delta, &req, tx);
                        let _ = router_tx.send(RouterMsg::Unlease {
                            ids: Vec::new(),
                            frames: 1,
                        });
                        continue;
                    }
                    // Lease cards: however many of the pool the batch
                    // lane isn't holding right now (≥ 1, ≤ max_lease).
                    // The router may hold the grant open up to `wait`
                    // hoping for a wider lease — never more than half
                    // the frame's remaining slack.
                    let want = oracle.max_lease;
                    let wait = match req.slack(now) {
                        Some(s) => oracle.lease_slack.min(s / 2),
                        None => oracle.lease_slack,
                    };
                    let (lease_tx, lease_rx) = channel::<Vec<usize>>();
                    let lease_req = RouterMsg::Lease {
                        want,
                        wait,
                        reply: lease_tx,
                    };
                    let granted: Vec<usize> = if router_tx.send(lease_req).is_ok() {
                        lease_rx.recv().unwrap_or_default()
                    } else {
                        Vec::new()
                    };
                    if granted.is_empty() {
                        let e = anyhow!("no cards to lease (router gone or pool dead)");
                        send_error(&mut delta, req.id, tx, &e);
                        let _ = router_tx.send(RouterMsg::Unlease {
                            ids: Vec::new(),
                            frames: 1,
                        });
                        continue;
                    }
                    delta.shard_leases += 1;
                    delta.shard_cards_granted += granted.len() as u64;
                    delta.shard_cards_stolen += (want - granted.len().min(want)) as u64;
                    // The lease wait may have eaten the rest of the
                    // slack (bounded, but the pool may have been busy):
                    // re-check before burning the cards.
                    if req.expired(Instant::now()) {
                        send_shed(&mut delta, &req, tx);
                        let _ = router_tx.send(RouterMsg::Unlease {
                            ids: granted,
                            frames: 1,
                        });
                        continue;
                    }
                    let width = granted.len();
                    let t0 = Instant::now();
                    let mut dead = Vec::new();
                    // Park the CU at this model's entry point and size
                    // the feature buffer for its plan.
                    fbuf.clear();
                    fbuf.resize(entry.prog.fbuf_words, 0);
                    cu.park_at(entry.prog.entry);
                    let res = run_sharded_frame(
                        &entry,
                        &mut cu,
                        &mut fbuf,
                        &mut spare,
                        &worker_txs,
                        &granted,
                        &mut dead,
                        &req.image,
                        m_run,
                        cores,
                    );
                    let frame_wall = t0.elapsed();
                    // Cards whose channel is gone are retired from the
                    // pool; only live cards rejoin the free list (a dead
                    // card handed back would be re-leased and fail every
                    // later frame it lands in).
                    let live: Vec<usize> =
                        granted.into_iter().filter(|w| !dead.contains(w)).collect();
                    for w in dead {
                        let _ = router_tx.send(RouterMsg::Retire(w));
                    }
                    let _ = router_tx.send(RouterMsg::Unlease {
                        ids: live,
                        frames: 1,
                    });
                    match res {
                        Ok((logits, stats)) => {
                            // charged in card-time: `width` cards spent
                            // `frame_wall` each on this frame
                            entry.capacity.observe(req.mode, 1, frame_wall, width);
                            send_reply(&mut delta, req, tx, logits, stats.cycles, frame_wall);
                            delta.sim_wall += frame_wall;
                            delta.shard_wall += frame_wall;
                        }
                        Err(e) => send_error(&mut delta, req.id, tx, &e),
                    }
                }
                local.merge(&delta);
                if let Ok(mut g) = global.lock() {
                    g.merge(&delta);
                }
            }
        }
    }
    // Tell the router the shard lane is dry — it stops the workers once
    // the batch lane has drained too.
    let _ = router_tx.send(RouterMsg::OrchDrained);
    local
}

/// Run one frame scattered over the leased cards.  Per layer: enqueue one
/// [`ShardJob`] per card with work, then stitch every returned tile into
/// the pong half.  Frame cycles = CU instruction cycles + Σ max-over-cards
/// layer walls — the latency of a machine as wide as the lease.
///
/// The per-card input broadcast is double-buffered: while layer N's
/// gather is collecting tiles, each arriving tile is also scattered into
/// the buffer that becomes layer N+1's broadcast (chained layers share
/// the region — N's `out_base/out_len` are N+1's `in_base/in_len`).  The
/// serial copy-the-ping-half pass PR 2 ran between layers is gone: the
/// scatter copy overlaps the cards' compute and the gather.
#[allow(clippy::too_many_arguments)]
fn run_sharded_frame(
    entry: &Arc<ModelEntry>,
    cu: &mut ControlUnit,
    fbuf: &mut [i8],
    spare: &mut Vec<Vec<i8>>,
    worker_txs: &[Sender<WorkerMsg>],
    leased: &[usize],
    dead: &mut Vec<usize>,
    image: &[i8],
    m_run: Option<usize>,
    cores: usize,
) -> Result<(Vec<i8>, FrameStats)> {
    let n_cards = leased.len();
    let shards = entry.cache.cards(n_cards);
    let intra_threads = (cores / n_cards.max(1)).max(1);
    let mode = entry.plan.mode(m_run);
    let layer_shards = shards.mode(m_run);
    let n_layers = mode.layers.len();
    let first = mode.layers.first().expect("non-empty plan");
    if image.len() != first.in_len {
        return Err(anyhow!("image len {} != {}", image.len(), first.in_len));
    }
    fbuf[first.in_base..first.in_base + first.in_len].copy_from_slice(image);

    let mut stats = FrameStats {
        // In shard mode the per-unit stats aggregate per *card* (each
        // card is a whole array; mapping cards onto one card's physical
        // SAs would be meaningless).
        sa_stats: vec![SimStats::default(); n_cards],
        ..Default::default()
    };
    let mut err: Option<anyhow::Error> = None;
    // The next layer's input copy, built during this layer's gather.
    let mut next_bcast: Option<Vec<i8>> = None;

    let layer_cycles = &mut stats.layer_cycles;
    let sa_stats = &mut stats.sa_stats;
    let err_ref = &mut err;
    let next_ref = &mut next_bcast;
    let cu_run = cu.run_frame(&entry.prog, |lr| {
        if err_ref.is_some() {
            // A card already failed: fall through the remaining layers
            // without dispatching work so the CU still reaches its HLT.
            layer_cycles.push(0);
            return 0;
        }
        let li = lr.layer_id as usize;
        let lp = &mode.layers[li];
        // Broadcast: the input copy built during the previous layer's
        // gather, or — first layer — lifted from the feature buffer.
        let input = Arc::new(match next_ref.take() {
            Some(buf) => buf,
            None => fbuf[lp.in_base..lp.in_base + lp.in_len].to_vec(),
        });
        debug_assert_eq!(input.len(), lp.in_len);
        // Scatter: one tile job per leased card.  The reply channel is
        // per layer, and the orchestrator's own tx is dropped right
        // after the scatter — so a worker that dies without answering
        // surfaces as a recv disconnect (an error reply), never as a
        // gather that blocks forever.
        let (reply_tx, reply_rx) = channel::<(usize, Result<ShardRun>)>();
        let mut sent = 0usize;
        for (card, shard) in layer_shards[li].cards.iter().enumerate() {
            if shard.n_units() == 0 {
                continue; // layer too small for this card — it idles
            }
            let job = ShardJob {
                entry: Arc::clone(entry),
                m_run,
                layer: li,
                card,
                intra_threads,
                shards: Arc::clone(shards),
                input: Arc::clone(&input),
                reply: reply_tx.clone(),
            };
            if worker_txs[leased[card]].send(WorkerMsg::Shard(job)).is_err() {
                dead.push(leased[card]);
                *err_ref = Some(anyhow!("leased card {card} is gone"));
                layer_cycles.push(0);
                return 0;
            }
            sent += 1;
        }
        drop(reply_tx);
        // Gather: exactly `sent` replies belong to this layer (each job
        // answers once, success or error), stitched into the pong half —
        // and, overlapped, into the next layer's broadcast buffer.
        let out = &mut fbuf[lp.out_base..lp.out_base + lp.out_len];
        let mut nb: Option<Vec<i8>> = if li + 1 < n_layers {
            let mut b = spare.pop().unwrap_or_default();
            b.clear();
            b.resize(lp.out_len, 0);
            Some(b)
        } else {
            None
        };
        let mut wall = 0u64;
        for _ in 0..sent {
            match reply_rx.recv() {
                Ok((card, Ok(run))) => {
                    for t in &run.tiles {
                        scatter_tile(lp.out_shape, out, t.rows.clone(), t.chans.clone(), &t.data);
                        if let Some(b) = nb.as_mut() {
                            scatter_tile(lp.out_shape, b, t.rows.clone(), t.chans.clone(), &t.data);
                        }
                    }
                    wall = wall.max(run.wall);
                    sa_stats[card].add(run.stats);
                }
                Ok((card, Err(e))) => {
                    err_ref.get_or_insert(anyhow!("card {card}, layer {li}: {e:#}"));
                }
                Err(_) => {
                    // every sender is gone but replies are missing — a
                    // worker died mid-job without answering
                    err_ref.get_or_insert(anyhow!("layer {li}: a card died before replying"));
                    break;
                }
            }
        }
        // Recycle this layer's broadcast once every card has dropped its
        // clone (a card may still hold one for a beat; skip quietly).
        if let Ok(buf) = Arc::try_unwrap(input) {
            spare.push(buf);
        }
        *next_ref = nb;
        layer_cycles.push(wall);
        wall
    });
    stats.instr_cycles = cu_run.instr_cycles;
    stats.cycles = cu_run.total_cycles();

    if let Some(e) = err {
        return Err(e);
    }
    let last = mode.layers.last().expect("non-empty plan");
    let logits = fbuf[last.out_base..last.out_base + last.out_len].to_vec();
    Ok((logits, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::route::ClassSpec;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::tensor::Shape;
    use crate::util::{prop, rng::Xoshiro256};

    fn quick_cfg(workers: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers,
            policy: BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
            },
            route: RoutePolicy::BatchOnly,
            ..Default::default()
        }
    }

    fn shard_cfg(cards: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            array: ArrayConfig::new(1, 8, 2),
            workers: cards,
            policy: BatchPolicy::default(),
            route: RoutePolicy::ShardOnly,
            ..Default::default()
        }
    }

    /// A Router with real channels but no threads behind them: messages
    /// are applied via `handle`/`pump` directly, so the ledger paths the
    /// stress suites only hit by luck (retirement, orchestrator death,
    /// the stall valve, lease hysteresis) are deterministic here.
    struct RouterRig {
        router: Router,
        /// Keep-alive for the router's `orch_tx` — set to `None` to
        /// simulate orchestrator death (sends start failing).
        #[allow(dead_code)]
        orch_rx: Option<Receiver<OrchMsg>>,
        worker_rxs: Vec<Receiver<WorkerMsg>>,
    }

    fn router_rig(workers: usize, route: RoutePolicy) -> RouterRig {
        let (_tx, rx) = channel::<RouterMsg>();
        let (orch_tx, orch_rx) = channel::<OrchMsg>();
        let mut worker_txs = Vec::new();
        let mut worker_rxs = Vec::new();
        for _ in 0..workers {
            let (t, r) = channel::<WorkerMsg>();
            worker_txs.push(t);
            worker_rxs.push(r);
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_delay: Duration::ZERO,
        };
        RouterRig {
            router: Router {
                rx,
                orch_tx,
                worker_txs,
                policy,
                route,
                classes: ClassTable::default(),
                registry: Arc::new(ModelRegistry::new(workers)),
                capacity: Arc::new(CapacityModel::fixed(1_000)),
                batcher: Batcher::new(policy),
                reply_txs: ReplyMap::new(),
                free: (0..workers).collect(),
                live: workers,
                leased: 0,
                running: vec![0; workers],
                batch_inflight: 0,
                class_inflight: [0; N_CLASSES],
                model_inflight: std::collections::HashMap::new(),
                queued_cycles: [0; N_CLASSES],
                card_load: vec![CardLoad::default(); workers],
                orch_ledger: VecDeque::new(),
                orch_cycles: 0,
                pending_batches: VecDeque::new(),
                pending_lease: None,
                shard_inflight: 0,
                shutting: false,
                orch_done: false,
                stalled: 0,
                local: Metrics::default(),
                global: Arc::new(Mutex::new(Metrics::default())),
            },
            orch_rx: Some(orch_rx),
            worker_rxs,
        }
    }

    fn rig_request(id: u64, class: Option<DispatchClass>) -> Request {
        Request {
            id,
            image: vec![0i8; 16],
            mode: Mode::HighAccuracy,
            model: ModelId::DEFAULT,
            entry: None,
            class,
            deadline: None,
            service: ServiceClass::Standard,
            submitted: Instant::now(),
        }
    }

    /// A rig batch: model-less, like the rig requests it carries.
    fn rig_batch(class: DispatchClass, requests: Vec<Request>) -> Batch {
        Batch {
            mode: Mode::HighAccuracy,
            class,
            model: ModelId::DEFAULT,
            entry: None,
            requests,
        }
    }

    /// `Retire` of a leased card: the card leaves the pool (never back
    /// on the free list), the lease ledger stays balanced, and when the
    /// last card retires the parked work is answered instead of wedged.
    #[test]
    fn retire_of_leased_card_balances_the_ledger() {
        let mut rig = router_rig(2, RoutePolicy::BatchOnly);
        // the orchestrator asks for the whole pool and gets it
        let (lease_tx, lease_rx) = channel::<Vec<usize>>();
        rig.router.shard_inflight = 1; // one frame handed to the orchestrator
        rig.router.handle(RouterMsg::Lease {
            want: 2,
            wait: Duration::ZERO,
            reply: lease_tx,
        });
        let granted = lease_rx.try_recv().expect("idle pool grants instantly");
        assert_eq!(granted.len(), 2);
        assert_eq!(rig.router.leased, 2);
        assert!(rig.router.free.is_empty());
        // one leased card turns out dead; the other returns with the frame
        let (dead, alive) = (granted[0], granted[1]);
        rig.router.handle(RouterMsg::Retire(dead));
        assert_eq!(rig.router.live, 1);
        assert_eq!(rig.router.leased, 1);
        rig.router.handle(RouterMsg::Unlease {
            ids: vec![alive],
            frames: 1,
        });
        assert_eq!(rig.router.leased, 0);
        assert_eq!(rig.router.shard_inflight, 0);
        assert_eq!(rig.router.free, vec![alive], "dead card never rejoins free");
        // park a batch while the remaining card is busy, then retire it:
        // the parked work must be failed, not stranded
        rig.router.free.clear();
        let (reply_tx, reply_rx) = channel::<ReplyResult>();
        rig.router.pending_batches.push_back((
            rig_batch(
                DispatchClass::Batch,
                vec![rig_request(7, Some(DispatchClass::Batch))],
            ),
            vec![reply_tx],
        ));
        rig.router.handle(RouterMsg::Retire(alive));
        assert_eq!(rig.router.live, 0);
        let err = reply_rx
            .try_recv()
            .expect("parked batch answered when the pool died")
            .expect_err("an error answer");
        assert!(!err.is_deadline());
        assert_eq!(rig.router.local.failed, 1);
    }

    /// Orchestrator death during `OrchMsg::Run`: `dispatch_cut` must
    /// fall back to answering the batch with errors, and the
    /// shard-inflight ledger must not count the frames that never went.
    #[test]
    fn orchestrator_death_fails_the_batch_not_the_router() {
        let mut rig = router_rig(1, RoutePolicy::ShardOnly);
        rig.orch_rx = None; // the orchestrator is gone
        let (tx, reply_rx) = channel::<ReplyResult>();
        let req = rig_request(0, Some(DispatchClass::Shard));
        rig.router.handle(RouterMsg::Submit(req, tx));
        rig.router.pump(Instant::now());
        let err = reply_rx
            .try_recv()
            .expect("answered despite the dead orchestrator")
            .expect_err("an error answer");
        match err {
            InferError::Failed { reason, .. } => {
                assert!(reason.contains("orchestrator"), "{reason}")
            }
            other => panic!("unexpected error {other:?}"),
        }
        assert_eq!(rig.router.shard_inflight, 0, "undelivered frames not counted");
        assert_eq!(rig.router.local.failed, 1);
    }

    /// The shutdown stall valve: a drain blocked on cards that will
    /// never answer (their WorkerDone is never coming) must answer the
    /// parked work and release the exit conditions after
    /// `SHUTDOWN_STALL_TICKS` silent ticks — `shutdown()` never wedges.
    #[test]
    fn shutdown_stall_valve_answers_parked_work() {
        let mut rig = router_rig(1, RoutePolicy::BatchOnly);
        // the only card is "busy" and will never report done
        rig.router.free.clear();
        rig.router.leased = 1;
        let (reply_tx, reply_rx) = channel::<ReplyResult>();
        rig.router.pending_batches.push_back((
            rig_batch(
                DispatchClass::Batch,
                vec![rig_request(3, Some(DispatchClass::Batch))],
            ),
            vec![reply_tx],
        ));
        rig.router.handle(RouterMsg::Shutdown);
        assert!(rig.router.shutting);
        // silent ticks accumulate; one before the valve nothing happens
        for _ in 0..SHUTDOWN_STALL_TICKS - 1 {
            rig.router.on_tick();
        }
        assert!(reply_rx.try_recv().is_err(), "valve must not fire early");
        assert!(!rig.router.orch_done);
        rig.router.on_tick();
        let err = reply_rx
            .try_recv()
            .expect("stalled drain answers parked work")
            .expect_err("an error answer");
        assert!(matches!(err, InferError::Failed { .. }));
        assert_eq!(rig.router.leased, 0);
        assert!(rig.router.orch_done);
        assert!(rig.router.pending_batches.is_empty());
    }

    /// The Adaptive depth signal counts batches *running* on busy cards
    /// — a saturated pool must read as a deep queue even when the
    /// batcher itself is empty.
    #[test]
    fn queue_depth_counts_live_batches() {
        let route = RoutePolicy::Adaptive {
            shard_min_len: 0,
            deep_queue: 3,
            tight_slack: Duration::ZERO,
        };
        let mut rig = router_rig(1, route);
        // the pool is saturated: 5 requests computing on the one card
        rig.router.free.clear();
        rig.router.running[0] = 5;
        rig.router.batch_inflight = 5;
        assert_eq!(rig.router.queue_depth(), 5);
        let (tx, _reply1) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(rig_request(0, None), tx));
        assert_eq!(rig.router.local.routed_batch, 1, "deep (live) queue ⇒ batch");
        assert_eq!(rig.router.local.routed_shard, 0);
        // the card drains: depth falls back under deep_queue ⇒ shard
        rig.router.handle(RouterMsg::WorkerDone(0));
        assert_eq!(rig.router.batch_inflight, 0);
        let (tx2, _reply2) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(rig_request(1, None), tx2));
        assert_eq!(rig.router.local.routed_shard, 1, "shallow queue ⇒ shard");
    }

    /// Lease-width hysteresis at the ledger level: a lease that wants
    /// more cards than are free waits inside its window, widens when a
    /// card frees, and settles for what's there once the window expires.
    #[test]
    fn lease_hysteresis_waits_widens_and_expires() {
        // case 1: window open — wait, then widen on WorkerDone
        let mut rig = router_rig(2, RoutePolicy::BatchOnly);
        rig.router.free = vec![0];
        rig.router.running[1] = 1;
        rig.router.batch_inflight = 1;
        let (lease_tx, lease_rx) = channel::<Vec<usize>>();
        rig.router.handle(RouterMsg::Lease {
            want: 2,
            wait: Duration::from_secs(60),
            reply: lease_tx,
        });
        assert!(lease_rx.try_recv().is_err(), "holds out for the full width");
        assert!(rig.router.pending_lease.is_some());
        rig.router.handle(RouterMsg::WorkerDone(1));
        let granted = lease_rx.try_recv().expect("full width granted");
        assert_eq!(granted.len(), 2);
        assert_eq!(rig.router.leased, 2);
        assert_eq!(rig.router.local.lease_wait.count(), 1);

        // case 2: expired window — take the narrow grant immediately
        let mut rig = router_rig(2, RoutePolicy::BatchOnly);
        rig.router.free = vec![0];
        rig.router.running[1] = 1;
        rig.router.batch_inflight = 1;
        let (lease_tx, lease_rx) = channel::<Vec<usize>>();
        rig.router.handle(RouterMsg::Lease {
            want: 2,
            wait: Duration::ZERO,
            reply: lease_tx,
        });
        let granted = lease_rx.try_recv().expect("expired window grants narrow");
        assert_eq!(granted, vec![0]);
        assert_eq!(rig.router.leased, 1);

        // case 3: want capped by live cards — a dead pool can't make
        // the lease wait for width that can never come
        let mut rig = router_rig(2, RoutePolicy::BatchOnly);
        rig.router.live = 1;
        rig.router.free = vec![0];
        let (lease_tx, lease_rx) = channel::<Vec<usize>>();
        rig.router.handle(RouterMsg::Lease {
            want: 2,
            wait: Duration::from_secs(60),
            reply: lease_tx,
        });
        let granted = lease_rx.try_recv().expect("live-capped target grants now");
        assert_eq!(granted, vec![0]);
    }

    /// While a lease waits out its hysteresis window, fresh batch-lane
    /// work stays *queued* instead of stealing the free cards the lease
    /// is holding — the cut itself is gated on a card that can take the
    /// work now — and it dispatches the moment the lease returns the
    /// pool.
    #[test]
    fn pending_lease_parks_fresh_batches() {
        let mut rig = router_rig(2, RoutePolicy::BatchOnly);
        rig.router.free = vec![0];
        rig.router.running[1] = 1;
        rig.router.batch_inflight = 1;
        let (lease_tx, lease_rx) = channel::<Vec<usize>>();
        rig.router.handle(RouterMsg::Lease {
            want: 2,
            wait: Duration::from_secs(60),
            reply: lease_tx,
        });
        assert!(rig.router.pending_lease.is_some());
        // a batch-lane request arrives; its cut is deferred while the
        // lease holds the pool (the free card is spoken for)
        let (tx, _reply) = channel::<ReplyResult>();
        let req = rig_request(0, Some(DispatchClass::Batch));
        rig.router.handle(RouterMsg::Submit(req, tx));
        rig.router.pump(Instant::now());
        assert_eq!(
            rig.router.batcher.pending(),
            1,
            "work stays queued while the lease holds the pool"
        );
        assert!(rig.router.pending_batches.is_empty(), "nothing parked");
        assert_eq!(rig.router.free, vec![0], "free card not stolen");
        // the busy card frees: the lease wins it; the queued work still
        // can't cut (the lease took both cards)
        rig.router.handle(RouterMsg::WorkerDone(1));
        assert_eq!(lease_rx.try_recv().expect("lease resolved").len(), 2);
        rig.router.pump(Instant::now());
        assert_eq!(rig.router.batcher.pending(), 1);
        // lease returns: the queued batch finally cuts onto a card
        rig.router.handle(RouterMsg::Unlease {
            ids: vec![0, 1],
            frames: 0,
        });
        rig.router.pump(Instant::now());
        assert_eq!(rig.router.batcher.pending(), 0, "queued batch dispatched");
        assert!(rig.router.pending_batches.is_empty());
        let sent = rig.worker_rxs.iter().any(|rx| rx.try_recv().is_ok());
        assert!(sent, "the batch landed on a worker queue");
        assert_eq!(rig.router.batch_inflight, 1);
    }

    /// Regression for the `dispatch_cut` panic: a request answered at
    /// another gate (shed at the queue racing a batch failure) has no
    /// reply channel left when its batch is cut — the router must drop
    /// it tolerantly and keep answering the survivors, on both lanes'
    /// failure paths, instead of panicking the whole router thread.
    #[test]
    fn dispatch_cut_tolerates_already_answered_requests() {
        // shard lane, orchestrator dead: the cut must fail the batch
        // gracefully even though one of its requests was already
        // answered (its tx is gone from the reply map)
        let mut rig = router_rig(1, RoutePolicy::ShardOnly);
        rig.orch_rx = None;
        let answered = rig_request(0, Some(DispatchClass::Shard));
        let (tx1, survivor_rx) = channel::<ReplyResult>();
        let survivor = rig_request(1, Some(DispatchClass::Shard));
        // only the survivor is registered — request 0 was answered at
        // another gate
        rig.router.reply_txs.insert(1, tx1);
        rig.router
            .dispatch_cut(rig_batch(DispatchClass::Shard, vec![answered, survivor]));
        let err = survivor_rx
            .try_recv()
            .expect("survivor answered despite the dead orchestrator")
            .expect_err("an error answer");
        assert!(matches!(err, InferError::Failed { .. }));
        assert_eq!(err.id(), 1);
        assert_eq!(rig.router.local.failed, 1, "only the survivor failed");
        assert_eq!(rig.router.shard_inflight, 0);
        assert!(rig.router.orch_ledger.is_empty());

        // batch lane, pool dead: same overlap through fail_batch
        let mut rig = router_rig(1, RoutePolicy::BatchOnly);
        rig.router.live = 0;
        rig.router.free.clear();
        let (tx1, survivor_rx) = channel::<ReplyResult>();
        rig.router.reply_txs.insert(1, tx1);
        rig.router.dispatch_cut(rig_batch(
            DispatchClass::Batch,
            vec![
                rig_request(0, Some(DispatchClass::Batch)),
                rig_request(1, Some(DispatchClass::Batch)),
            ],
        ));
        let err = survivor_rx
            .try_recv()
            .expect("survivor answered despite the dead pool")
            .expect_err("an error answer");
        assert_eq!(err.id(), 1);

        // a batch whose every request was already answered dissolves
        // without touching any lane
        let mut rig = router_rig(1, RoutePolicy::BatchOnly);
        rig.router.dispatch_cut(rig_batch(
            DispatchClass::Batch,
            vec![rig_request(7, Some(DispatchClass::Batch))],
        ));
        assert!(rig.router.pending_batches.is_empty());
        assert!(rig.worker_rxs[0].try_recv().is_err(), "nothing dispatched");
    }

    /// The class admission budget refuses at the cap — typed, counted,
    /// never queued — and frees as admitted work is answered.
    #[test]
    fn admission_budget_refuses_at_the_cap() {
        let mut rig = router_rig(1, RoutePolicy::BatchOnly);
        rig.router.classes = ClassTable::default().with(
            ServiceClass::Interactive,
            ClassSpec {
                slo: None, // isolate the budget gate from the SLO stamp
                dispatch_bias: None,
                admission_limit: 1,
            },
        );
        let interactive = |id| Request {
            service: ServiceClass::Interactive,
            ..rig_request(id, Some(DispatchClass::Batch))
        };
        // hold the card so the first request stays inflight
        rig.router.free.clear();
        let (tx0, _keep0) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(interactive(0), tx0));
        assert_eq!(rig.router.class_inflight[ServiceClass::Interactive.index()], 1);
        let (tx1, refused_rx) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(interactive(1), tx1));
        let err = refused_rx
            .try_recv()
            .expect("refused instantly, not queued")
            .expect_err("an error answer");
        assert!(err.is_refused(), "typed refusal, got {err:?}");
        assert!(!err.is_deadline());
        assert_eq!(err.id(), 1);
        // refused work never entered any ledger or queue
        assert_eq!(rig.router.batcher.pending(), 1, "only the admitted request");
        assert!(!rig.router.reply_txs.contains_key(&1));
        assert_eq!(rig.router.local.admission_refused, 1);
        assert_eq!(rig.router.local.submitted, 2);
        let ci = ServiceClass::Interactive.index();
        assert_eq!(rig.router.local.classes[ci].admission_refused, 1);
        assert_eq!(rig.router.local.classes[ci].submitted, 2);
        // other classes are not throttled by Interactive's budget
        let (tx2, _keep2) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(rig_request(2, None), tx2));
        assert_eq!(rig.router.batcher.pending(), 2);
        // Standard defaults to no deadline: nothing to shed, no refusal
        assert_eq!(rig.router.local.admission_refused, 1);
    }

    /// The capacity gate: an SLO that even the pace floor cannot meet
    /// over the committed backlog is refused at admission — under the
    /// construction seed the same request is admitted (the seeded floor
    /// is microseconds here), and SLO-free classes are never refused
    /// however bad their explicit deadlines look.
    #[test]
    fn capacity_gate_refuses_provably_unmeetable_slos() {
        let mut rig = router_rig(1, RoutePolicy::BatchOnly);
        rig.router.classes = ClassTable::default().with(
            ServiceClass::Interactive,
            ClassSpec {
                slo: Some(Duration::from_millis(5)),
                dispatch_bias: None,
                admission_limit: 0,
            },
        );
        // 10 frames of committed work on the one busy card
        rig.router.free.clear();
        rig.router.running[0] = 10;
        rig.router.batch_inflight = 10;
        rig.router.card_load[0] = CardLoad {
            cycles: 10_000, // 10 × the rig's fixed 1 000-cycle frames
            count: [0, 10, 0],
            ..Default::default()
        };
        let interactive = |id| Request {
            service: ServiceClass::Interactive,
            ..rig_request(id, Some(DispatchClass::Batch))
        };
        // at the construction seed (2.5 ns/cycle) the 11k-cycle floor is
        // ~27 µs ≪ the 5 ms SLO: admitted
        let (tx0, _keep0) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(interactive(0), tx0));
        assert_eq!(rig.router.batcher.pending(), 1);
        assert_eq!(rig.router.local.admission_refused, 0);
        // calibrate: 1 ms per 1 000-cycle frame ⇒ the 10-frame running
        // backlog alone needs 10 ms ≫ the 5 ms SLO
        rig.router.capacity.set_pace_ps(1_000_000);
        let (tx1, refused_rx) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(interactive(1), tx1));
        let err = refused_rx
            .try_recv()
            .expect("refused instantly")
            .expect_err("an error answer");
        let InferError::AdmissionRefused { id, earliest_feasible } = err else {
            panic!("expected AdmissionRefused, got {err:?}");
        };
        assert_eq!(id, 1);
        assert!(
            earliest_feasible >= Duration::from_millis(10),
            "the refusal names the budget floor ({earliest_feasible:?})"
        );
        assert_eq!(rig.router.batcher.pending(), 1, "refused work never queued");
        assert!(!rig.router.reply_txs.contains_key(&1));
        assert_eq!(rig.router.local.admission_refused, 1);
        // an explicit generous deadline opts the same class back in
        let feasible = Request {
            deadline: Some(Instant::now() + Duration::from_secs(60)),
            ..interactive(2)
        };
        let (tx2, _keep2) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(feasible, tx2));
        assert_eq!(rig.router.batcher.pending(), 2);
        // scalar-deadline compat: an SLO-free class with a hopeless
        // explicit deadline is still admitted (queued, eventually shed)
        // — PR-4 semantics unchanged
        let bare = Request {
            deadline: Some(Instant::now() + Duration::from_millis(1)),
            ..rig_request(3, Some(DispatchClass::Batch))
        };
        let (tx3, _keep3) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(bare, tx3));
        assert_eq!(rig.router.batcher.pending(), 3, "no refusal without an SLO");
        assert_eq!(rig.router.local.admission_refused, 1);
    }

    /// An Interactive request's capacity check ignores *laxer* queued
    /// work (SLO-aware arbitration will cut it ahead), but counts
    /// running work in full — the class-aware backlog slice.
    #[test]
    fn backlog_slice_is_class_aware() {
        let mut rig = router_rig(1, RoutePolicy::BatchOnly);
        rig.router.queued_cycles = [1_000, 2_000, 4_000];
        rig.router.card_load[0] = CardLoad {
            cycles: 8_000,
            count: [0, 1, 0],
            ..Default::default()
        };
        assert_eq!(
            rig.router.backlog_cycles(ServiceClass::Interactive),
            1_000 + 8_000,
            "interactive sees only interactive queues + running work"
        );
        assert_eq!(
            rig.router.backlog_cycles(ServiceClass::Standard),
            1_000 + 2_000 + 8_000
        );
        assert_eq!(
            rig.router.backlog_cycles(ServiceClass::Bulk),
            1_000 + 2_000 + 4_000 + 8_000,
            "bulk queues behind everything"
        );
        // the shard ledger counts in full for every class (the
        // orchestrator is FIFO)
        rig.router.orch_ledger.push_back((ServiceClass::Bulk.index(), 500, 0));
        rig.router.orch_cycles = 500;
        assert_eq!(rig.router.backlog_cycles(ServiceClass::Interactive), 9_500);
    }

    /// The admission ledgers stay balanced through dispatch, completion
    /// and the shard lane's Unlease pops.
    #[test]
    fn admission_ledgers_balance_through_the_lanes() {
        let mut rig = router_rig(2, RoutePolicy::BatchOnly);
        let si = ServiceClass::Standard.index();
        let est = rig.router.capacity.est_cycles(Mode::HighAccuracy);
        // admit two batch-lane requests and let the cut dispatch them
        let (tx0, _r0) = channel::<ReplyResult>();
        let (tx1, _r1) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(rig_request(0, None), tx0));
        rig.router.handle(RouterMsg::Submit(rig_request(1, None), tx1));
        assert_eq!(rig.router.class_inflight[si], 2);
        assert_eq!(rig.router.queued_cycles[si], 2 * est);
        rig.router.pump(Instant::now());
        assert_eq!(rig.router.queued_cycles[si], 0, "cut moved cycles to the card");
        let w = (0..2)
            .find(|&w| rig.router.card_load[w].cycles > 0)
            .expect("a card holds the batch");
        assert_eq!(rig.router.card_load[w].cycles, 2 * est);
        assert_eq!(rig.router.class_inflight[si], 2, "inflight until answered");
        rig.router.handle(RouterMsg::WorkerDone(w));
        assert_eq!(rig.router.class_inflight[si], 0);
        assert_eq!(rig.router.card_load[w].cycles, 0);
        // shard lane: the ledger pops per Unlease-retired frame
        let mut rig = router_rig(1, RoutePolicy::ShardOnly);
        let (tx, _r) = channel::<ReplyResult>();
        rig.router.handle(RouterMsg::Submit(rig_request(0, None), tx));
        rig.router.pump(Instant::now());
        assert_eq!(rig.router.orch_ledger.len(), 1);
        assert_eq!(rig.router.orch_cycles, est);
        assert_eq!(rig.router.class_inflight[si], 1);
        rig.router.handle(RouterMsg::Unlease { ids: vec![], frames: 1 });
        assert!(rig.router.orch_ledger.is_empty());
        assert_eq!(rig.router.orch_cycles, 0);
        assert_eq!(rig.router.class_inflight[si], 0);
    }

    /// `send_reply` splits deadlined completions into met vs missed.
    #[test]
    fn send_reply_records_deadline_met_and_missed() {
        let now = Instant::now();
        let mk = |deadline: Option<Instant>| Request {
            id: 0,
            image: vec![],
            mode: Mode::HighAccuracy,
            model: ModelId::DEFAULT,
            entry: None,
            class: None,
            deadline,
            service: ServiceClass::Standard,
            submitted: now,
        };
        let (tx, rx) = channel::<ReplyResult>();
        let mut delta = Metrics::default();
        send_reply(&mut delta, mk(None), &tx, vec![1, 2], 10, Duration::ZERO);
        assert_eq!((delta.deadline_met, delta.deadline_missed), (0, 0));
        send_reply(
            &mut delta,
            mk(Some(now + Duration::from_secs(3600))),
            &tx,
            vec![1, 2],
            10,
            Duration::ZERO,
        );
        assert_eq!((delta.deadline_met, delta.deadline_missed), (1, 0));
        send_reply(&mut delta, mk(Some(now)), &tx, vec![1, 2], 10, Duration::ZERO);
        assert_eq!((delta.deadline_met, delta.deadline_missed), (1, 1));
        assert_eq!(delta.completed, 3);
        drop(rx);
    }

    #[test]
    fn serves_and_matches_golden() {
        let mut rng = Xoshiro256::new(1);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let reply = coord.infer(InferRequest::new(img.clone())).unwrap();
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(reply.logits, want);
        assert_eq!(reply.class, golden::argmax(&want));
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.routed_batch, 1);
        assert_eq!(m.routed_shard, 0);
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let mut rng = Xoshiro256::new(2);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(2), net).unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                coord.submit(InferRequest::new(prop::i8_vec(&mut rng, 48 * 48 * 3)))
            })
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            ids.push(rx.recv().unwrap().unwrap().id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<u64>>());
        let m = coord.shutdown();
        assert_eq!(m.completed, 12);
        assert!(m.batches >= 3, "12 reqs / max_batch 4 ⇒ ≥3 batches");
    }

    #[test]
    fn mode_switch_serves_both_modes() {
        let mut rng = Xoshiro256::new(3);
        let net = cnn_a_quant(&mut rng, 4); // M=4 on M_arch=2
        let coord = Coordinator::start(quick_cfg(1), net.clone()).unwrap();
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let fast = coord.infer(InferRequest::new(img.clone()).mode(Mode::HighThroughput)).unwrap();
        let slow = coord.infer(InferRequest::new(img.clone())).unwrap();
        assert!(slow.cycles > fast.cycles * 3 / 2, "{} vs {}", slow.cycles, fast.cycles);
        let want_fast = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        assert_eq!(fast.logits, want_fast);
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let mut rng = Xoshiro256::new(4);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(
            CoordinatorConfig {
                policy: BatchPolicy {
                    max_batch: 64,
                    max_delay: Duration::from_secs(60), // never ripe on its own
                },
                ..quick_cfg(1)
            },
            net,
        )
        .unwrap();
        let rxs: Vec<_> = (0..3)
            .map(|_| coord.submit(InferRequest::new(prop::i8_vec(&mut rng, 48 * 48 * 3))))
            .collect();
        std::thread::sleep(Duration::from_millis(5));
        let m = coord.shutdown(); // flush must run the stragglers
        assert_eq!(m.completed, 3);
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn failing_request_gets_error_reply_not_hang() {
        let mut rng = Xoshiro256::new(5);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(1), net).unwrap();
        // Wrong-size image: the worker must answer Err, stay alive, and
        // keep serving its batchmates.
        let bad = coord.submit(InferRequest::new(vec![0i8; 7]));
        let good_img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let good = coord.submit(InferRequest::new(good_img));
        let bad_reply = bad.recv().expect("reply, not a dead channel");
        assert!(bad_reply.is_err());
        let good_reply = good.recv().unwrap().expect("batchmate unharmed");
        assert!(!good_reply.logits.is_empty());
        // and infer() surfaces the error as Err, not a hang
        assert!(coord.infer(InferRequest::new(vec![1i8; 3]).mode(Mode::HighThroughput)).is_err());
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 2);
    }

    #[test]
    fn sharded_frames_match_golden_and_cut_latency_cycles() {
        let mut rng = Xoshiro256::new(6);
        let net = cnn_a_quant(&mut rng, 4);
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let want_hi = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        let want_lo = golden::forward(&net, &img, Shape::new(48, 48, 3), Some(2));
        let mut cycles_by_cards = Vec::new();
        for cards in [1usize, 2] {
            let coord = Coordinator::start(shard_cfg(cards), net.clone()).unwrap();
            let hi = coord.infer(InferRequest::new(img.clone())).unwrap();
            let lo = coord.infer(InferRequest::new(img.clone()).mode(Mode::HighThroughput)).unwrap();
            assert_eq!(hi.logits, want_hi, "{cards} cards");
            assert_eq!(lo.logits, want_lo, "{cards} cards");
            assert!(hi.cycles > lo.cycles);
            cycles_by_cards.push(hi.cycles);
            let m = coord.shutdown();
            assert_eq!(m.completed, 2);
            assert_eq!(m.batches, 2, "sharded batches are single frames");
            assert_eq!(m.routed_shard, 2);
            assert_eq!(m.shard_leases, 2);
            // an idle pool leases its full width
            assert_eq!(m.shard_cards_granted, 2 * cards as u64);
            assert_eq!(m.shard_cards_stolen, 0);
        }
        // 2 cards must beat 1 card in simulated frame latency
        assert!(cycles_by_cards[1] < cycles_by_cards[0], "{cycles_by_cards:?}");
    }

    #[test]
    fn sharded_bad_frame_errors_and_pool_survives() {
        let mut rng = Xoshiro256::new(7);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(shard_cfg(2), net.clone()).unwrap();
        assert!(coord.infer(InferRequest::new(vec![0i8; 5])).is_err());
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let ok = coord.infer(InferRequest::new(img.clone())).unwrap();
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        assert_eq!(ok.logits, want);
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 1);
    }

    #[test]
    fn explicit_override_beats_the_policy() {
        // a BatchOnly coordinator must still serve an explicit Shard
        // request through the shard lane — and vice versa
        let mut rng = Xoshiro256::new(8);
        let net = cnn_a_quant(&mut rng, 2);
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let want = golden::forward(&net, &img, Shape::new(48, 48, 3), None);
        let coord = Coordinator::start(quick_cfg(2), net.clone()).unwrap();
        let shard = coord
            .infer(InferRequest::new(img.clone()).route(DispatchClass::Shard))
            .unwrap();
        assert_eq!(shard.logits, want);
        let batch = coord
            .infer(InferRequest::new(img.clone()).route(DispatchClass::Batch))
            .unwrap();
        assert_eq!(batch.logits, want);
        let m = coord.shutdown();
        assert_eq!(m.completed, 2);
        assert_eq!(m.routed_shard, 1);
        assert_eq!(m.routed_batch, 1);
        assert_eq!(m.shard_leases, 1);
        assert!(m.shard_cards_granted >= 1);
    }

    #[test]
    fn max_shard_cards_caps_the_lease() {
        let mut rng = Xoshiro256::new(9);
        let net = cnn_a_quant(&mut rng, 2);
        let img = prop::i8_vec(&mut rng, 48 * 48 * 3);
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers: 3,
                route: RoutePolicy::ShardOnly,
                max_shard_cards: 2,
                ..quick_cfg(3)
            },
            net,
        )
        .unwrap();
        coord.infer(InferRequest::new(img)).unwrap();
        let m = coord.shutdown();
        assert_eq!(m.shard_leases, 1);
        assert_eq!(m.shard_cards_granted, 2, "lease capped below pool width");
    }

    #[test]
    fn submit_handles_are_cloneable_across_threads() {
        let mut rng = Xoshiro256::new(10);
        let net = cnn_a_quant(&mut rng, 2);
        let coord = Coordinator::start(quick_cfg(2), net).unwrap();
        let imgs: Vec<Vec<i8>> = (0..4).map(|_| prop::i8_vec(&mut rng, 48 * 48 * 3)).collect();
        let mut rxs = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = imgs
                .iter()
                .map(|img| {
                    let h = coord.handle();
                    s.spawn(move || h.submit(InferRequest::new(img.clone())))
                })
                .collect();
            for t in handles {
                rxs.push(t.join().unwrap());
            }
        });
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 4);
    }

    // ------------------------------------------------------------------
    // SchedRig: deterministic-schedule coordinator fuzzing.
    //
    // The rig drives the Router's message handlers through seed-derived
    // interleavings of submits, virtual-clock advances, worker
    // completions, orchestrator lease/unlease steps and mid-schedule
    // shutdowns — the arbitration races (lease vs. shed, drain vs.
    // admission, cut vs. card-free) as explicit schedule permutations
    // instead of thread-timing luck.  Every schedule ends with the
    // accounting identity `submitted == completed + failed + refused`
    // checked from the *receiver* side (every reply channel got exactly
    // one answer) and a full quiescence sweep over the ledgers.
    //
    // Determinism: all scheduling time is a virtual clock advanced in
    // whole seconds and passed to `pump(now)`; deadlines sit at
    // fractional offsets (2.5 s / 120 s / ±1 h) so no boundary ever
    // lands within real-clock jitter of a decision point.  A failing
    // schedule replays byte-identically from its printed seed:
    //
    // ```text
    // BINARRAY_SCHED_SEED=0x1234abcd cargo test sched_fuzz
    // ```
    // ------------------------------------------------------------------

    /// One frame the router handed the (emulated) shard orchestrator.
    struct OrchFrame {
        req: Request,
        tx: Sender<ReplyResult>,
    }

    /// Receiver-side outcome counts of one schedule.
    #[derive(Debug, Default, PartialEq, Eq)]
    struct SchedTally {
        ok: u64,
        refused: u64,
        deadline: u64,
        failed: u64,
    }

    struct SchedRig {
        rig: RouterRig,
        rng: Xoshiro256,
        /// Schedule epoch: every virtual instant is `base + whole secs`
        /// (+ a fractional deadline offset), so ordering decisions never
        /// depend on real-clock jitter.
        base: Instant,
        /// The virtual clock passed to every `pump`.
        now: Instant,
        next_id: u64,
        /// Every submitted request's reply receiver, submission order —
        /// the no-orphaned-reply invariant is checked against this.
        replies: Vec<(u64, Receiver<ReplyResult>)>,
        /// Frames queued on the emulated (serial, FIFO) orchestrator.
        orch_q: VecDeque<OrchFrame>,
        /// The one outstanding lease: grant receiver + its frame.
        orch_wait: Option<(Receiver<Vec<usize>>, OrchFrame)>,
        orch_shutdown: bool,
        orch_drained_sent: bool,
        /// Replies the harness sent standing in for workers (`Ok`) and
        /// the orchestrator (sheds/errors) — the router's `local`
        /// metrics never see these, so the identity is asserted as
        /// `submitted == harness_ok + (local.failed + harness_failed)
        /// + local.admission_refused`.
        harness_ok: u64,
        harness_failed: u64,
        model: ModelId,
        /// Append-only schedule log: byte-identical across replays of
        /// the same seed.
        trace: Vec<String>,
    }

    impl SchedRig {
        fn new(seed: u64, registry: &Arc<ModelRegistry>, model: ModelId) -> Self {
            let mut rng = Xoshiro256::new(seed);
            let workers = 1 + rng.below(3) as usize;
            let route = match rng.below(3) {
                0 => RoutePolicy::BatchOnly,
                1 => RoutePolicy::ShardOnly,
                _ => RoutePolicy::Adaptive {
                    shard_min_len: 8,
                    deep_queue: 4,
                    // ZERO disables the slack signal for unexpired work,
                    // so the lane pick never depends on µs of real time.
                    tight_slack: Duration::ZERO,
                },
            };
            let mut rig = router_rig(workers, route);
            let policy = BatchPolicy {
                max_batch: [1, 2, 4][rng.below(3) as usize],
                max_delay: if rng.below(2) == 0 {
                    Duration::ZERO
                } else {
                    Duration::from_secs(2)
                },
            };
            let arb = if rng.below(2) == 0 {
                Arbitration::SloAware
            } else {
                Arbitration::OldestFirst
            };
            // Tight admission budgets so refusals actually happen, and a
            // 120 s Interactive SLO: far from every whole-second pump
            // boundary, near enough that long schedules shed through it.
            let classes = ClassTable::default()
                .with(
                    ServiceClass::Interactive,
                    ClassSpec {
                        slo: Some(Duration::from_secs(120)),
                        dispatch_bias: None,
                        admission_limit: 2,
                    },
                )
                .with(
                    ServiceClass::Bulk,
                    ClassSpec {
                        slo: None,
                        dispatch_bias: Some(DispatchClass::Batch),
                        admission_limit: 3,
                    },
                );
            rig.router.policy = policy;
            rig.router.classes = classes;
            rig.router.batcher = Batcher::with_qos(policy, classes, arb);
            rig.router.registry = Arc::clone(registry);
            let base = Instant::now();
            let trace = vec![format!(
                "cfg workers={workers} route={route:?} max_batch={} max_delay={:?} arb={arb:?}",
                policy.max_batch, policy.max_delay
            )];
            Self {
                rig,
                rng,
                base,
                now: base,
                next_id: 0,
                replies: Vec::new(),
                orch_q: VecDeque::new(),
                orch_wait: None,
                orch_shutdown: false,
                orch_drained_sent: false,
                harness_ok: 0,
                harness_failed: 0,
                model,
                trace,
            }
        }

        fn pump(&mut self) {
            self.rig.router.pump(self.now);
        }

        fn op_submit(&mut self) {
            let id = self.next_id;
            self.next_id += 1;
            let service = match self.rng.below(3) {
                0 => ServiceClass::Interactive,
                1 => ServiceClass::Standard,
                _ => ServiceClass::Bulk,
            };
            let class = match self.rng.below(4) {
                0 => Some(DispatchClass::Batch),
                1 => Some(DispatchClass::Shard),
                _ => None,
            };
            let (deadline, dl) = match self.rng.below(4) {
                // already expired at admission: deterministically shed
                0 => (Some(self.base - Duration::from_secs(1)), "expired"),
                // far future: never expires within a schedule
                1 => (Some(self.base + Duration::from_secs(3600)), "far"),
                // mid: expires once the virtual clock advances ≥ 3 s
                2 => (Some(self.now + Duration::from_millis(2500)), "mid"),
                _ => (None, "none"),
            };
            let model = if self.rng.below(10) == 0 {
                ModelId(777) // unknown: typed refusal at admission
            } else {
                self.model
            };
            let image_len = if self.rng.below(2) == 0 { 4 } else { 32 };
            let mode = if self.rng.below(2) == 0 {
                Mode::HighAccuracy
            } else {
                Mode::HighThroughput
            };
            self.trace.push(format!(
                "submit id={id} svc={} class={class:?} dl={dl} model={} len={image_len}",
                service.label(),
                model.0
            ));
            let (tx, rx) = channel::<ReplyResult>();
            let req = Request {
                id,
                image: vec![0i8; image_len],
                mode,
                model,
                entry: None,
                class,
                deadline,
                service,
                submitted: self.now,
            };
            self.rig.router.handle(RouterMsg::Submit(req, tx));
            self.replies.push((id, rx));
        }

        fn op_advance(&mut self) {
            let k = 1 + self.rng.below(3);
            self.now += Duration::from_secs(k);
            self.trace.push(format!("advance +{k}s"));
        }

        /// One worker step: serve at most one queued batch, asserting
        /// model/epoch homogeneity, then report the card free.
        fn op_worker(&mut self, w: usize) {
            let Ok(msg) = self.rig.worker_rxs[w].try_recv() else {
                return;
            };
            let WorkerMsg::Run(batch, txs) = msg else {
                panic!("rig workers only ever see WorkerMsg::Run");
            };
            assert_eq!(batch.requests.len(), txs.len(), "one reply channel per request");
            let epoch = batch.entry.as_ref().map(|e| e.epoch);
            let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
            for (req, tx) in batch.requests.into_iter().zip(txs) {
                assert_eq!(req.model, batch.model, "batch mixes models");
                assert_eq!(
                    req.entry.as_ref().map(|e| e.epoch),
                    epoch,
                    "request {} rides a mixed-epoch batch",
                    req.id
                );
                let _ = tx.send(Ok(Reply {
                    id: req.id,
                    logits: Vec::new(),
                    class: 0,
                    cycles: 0,
                    latency: Duration::ZERO,
                    mode: req.mode,
                }));
                self.harness_ok += 1;
            }
            self.trace
                .push(format!("worker{w} ran model={} ids={ids:?}", batch.model.0));
            self.rig.router.handle(RouterMsg::WorkerDone(w));
        }

        /// One orchestrator step, mirroring the real loop's protocol
        /// (serial, FIFO, one lease outstanding, one `Unlease` per
        /// frame whether or not a lease was granted).
        fn op_orch(&mut self) {
            if let Some(rx) = &self.rig.orch_rx {
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        OrchMsg::Run(batch, txs) => {
                            for (req, tx) in batch.requests.into_iter().zip(txs) {
                                self.orch_q.push_back(OrchFrame { req, tx });
                            }
                        }
                        OrchMsg::Shutdown => self.orch_shutdown = true,
                    }
                }
            }
            if let Some((grant_rx, frame)) = self.orch_wait.take() {
                match grant_rx.try_recv() {
                    Ok(ids) => {
                        let width = ids.len();
                        if ids.is_empty() {
                            // an empty grant means the pool died
                            let _ = frame.tx.send(Err(InferError::Failed {
                                id: frame.req.id,
                                reason: "no cards to lease (pool dead)".into(),
                            }));
                            self.harness_failed += 1;
                        } else {
                            let _ = frame.tx.send(Ok(Reply {
                                id: frame.req.id,
                                logits: Vec::new(),
                                class: 0,
                                cycles: 0,
                                latency: Duration::ZERO,
                                mode: frame.req.mode,
                            }));
                            self.harness_ok += 1;
                        }
                        self.trace
                            .push(format!("orch served id={} width={width}", frame.req.id));
                        self.rig.router.handle(RouterMsg::Unlease { ids, frames: 1 });
                    }
                    Err(_) => self.orch_wait = Some((grant_rx, frame)),
                }
            } else if let Some(frame) = self.orch_q.pop_front() {
                if frame.req.expired(self.now) {
                    // last gate before a lease is spent (the real
                    // orchestrator's shed): still one Unlease per frame
                    self.trace.push(format!("orch shed id={}", frame.req.id));
                    let _ = frame
                        .tx
                        .send(Err(InferError::DeadlineExceeded { id: frame.req.id }));
                    self.harness_failed += 1;
                    self.rig.router.handle(RouterMsg::Unlease {
                        ids: Vec::new(),
                        frames: 1,
                    });
                } else {
                    let want = 1 + self.rng.below(3) as usize;
                    let wait = if self.rng.below(2) == 0 {
                        Duration::ZERO
                    } else {
                        Duration::from_secs(3600)
                    };
                    self.trace.push(format!(
                        "orch lease id={} want={want} wait={:?}",
                        frame.req.id, wait
                    ));
                    let (ltx, lrx) = channel::<Vec<usize>>();
                    self.rig.router.handle(RouterMsg::Lease {
                        want,
                        wait,
                        reply: ltx,
                    });
                    self.orch_wait = Some((lrx, frame));
                }
            }
            if self.orch_shutdown
                && !self.orch_drained_sent
                && self.orch_q.is_empty()
                && self.orch_wait.is_none()
            {
                self.orch_drained_sent = true;
                self.trace.push("orch drained".into());
                self.rig.router.handle(RouterMsg::OrchDrained);
            }
        }

        fn op_shutdown(&mut self) {
            self.trace.push("shutdown".into());
            self.rig.router.handle(RouterMsg::Shutdown);
        }

        /// The fuzzed portion: 24–63 seed-drawn operations, pumped
        /// after each so sheds/cuts interleave with every message.
        fn run_ops(&mut self) {
            let n_ops = 24 + self.rng.below(40);
            for _ in 0..n_ops {
                match self.rng.below(8) {
                    0..=2 => self.op_submit(),
                    3 => self.op_advance(),
                    4 | 5 => {
                        let w = self.rng.below(self.rig.worker_rxs.len() as u64) as usize;
                        self.op_worker(w);
                    }
                    6 => self.op_orch(),
                    _ => {
                        // rare mid-schedule shutdown: drain vs. admission
                        if self.rng.below(16) == 0 {
                            self.op_shutdown();
                        } else {
                            self.op_advance();
                        }
                    }
                }
                self.pump();
            }
        }

        fn quiescent(&self) -> bool {
            let r = &self.rig.router;
            r.batcher.pending() == 0
                && r.pending_batches.is_empty()
                && r.pending_lease.is_none()
                && r.batch_inflight == 0
                && r.shard_inflight == 0
                && self.orch_q.is_empty()
                && self.orch_wait.is_none()
                && self.orch_drained_sent
        }

        /// Drain to quiescence: shutdown, then bounded rounds of
        /// worker/orchestrator steps under an advancing virtual clock.
        fn drain(&mut self) {
            self.op_shutdown();
            for _ in 0..64 {
                for w in 0..self.rig.worker_rxs.len() {
                    self.op_worker(w);
                }
                self.op_orch();
                self.now += Duration::from_secs(1);
                self.pump();
                if self.quiescent() {
                    break;
                }
            }
        }

        /// Post-drain invariants: quiescent ledgers, no orphaned (or
        /// double-answered) reply, and the accounting identity.
        fn finish(mut self) -> (SchedTally, Vec<String>) {
            assert!(
                self.quiescent(),
                "schedule did not drain: batcher={} parked={} lease={} batch_inflight={} \
                 shard_inflight={} orch_q={} orch_wait={} drained={}",
                self.rig.router.batcher.pending(),
                self.rig.router.pending_batches.len(),
                self.rig.router.pending_lease.is_some(),
                self.rig.router.batch_inflight,
                self.rig.router.shard_inflight,
                self.orch_q.len(),
                self.orch_wait.is_some(),
                self.orch_drained_sent,
            );
            let r = &self.rig.router;
            assert!(r.reply_txs.is_empty(), "reply channels leaked: {:?}", r.reply_txs.keys());
            assert_eq!(r.class_inflight, [0; N_CLASSES], "class admission slots leaked");
            assert!(r.model_inflight.is_empty(), "model admission slots leaked");
            assert_eq!(r.queued_cycles, [0; N_CLASSES], "queued-cycle ledger leaked");
            assert_eq!(r.leased, 0, "cards still leased after drain");
            assert_eq!(r.free.len(), r.live, "free list does not cover the live pool");
            let mut tally = SchedTally::default();
            for (id, rx) in &self.replies {
                let first = rx
                    .try_recv()
                    .unwrap_or_else(|_| panic!("request {id} was never answered (orphaned reply)"));
                match &first {
                    Ok(rep) => {
                        assert_eq!(rep.id, *id, "reply crossed channels");
                        tally.ok += 1;
                    }
                    Err(e) => {
                        assert_eq!(e.id(), *id, "error reply crossed channels");
                        if e.is_refused() {
                            tally.refused += 1;
                        } else if e.is_deadline() {
                            tally.deadline += 1;
                        } else {
                            tally.failed += 1;
                        }
                    }
                }
                assert!(rx.try_recv().is_err(), "request {id} answered twice");
            }
            let m = &r.local;
            assert_eq!(m.submitted, self.replies.len() as u64, "submit counter drifted");
            assert_eq!(
                m.submitted,
                tally.ok + tally.refused + tally.deadline + tally.failed,
                "accounting identity violated: {tally:?}"
            );
            assert_eq!(m.admission_refused, tally.refused, "refusal counter drifted");
            assert_eq!(m.completed, 0, "rig workers answer out-of-band, never the router");
            assert_eq!(tally.ok, self.harness_ok, "harness completions drifted");
            assert_eq!(
                m.failed + self.harness_failed,
                tally.deadline + tally.failed,
                "failure counters drifted (router {} + harness {})",
                m.failed,
                self.harness_failed
            );
            self.trace.push(format!(
                "tally ok={} refused={} deadline={} failed={}",
                tally.ok, tally.refused, tally.deadline, tally.failed
            ));
            (tally, self.trace)
        }
    }

    /// The shared fuzz registry: one compiled model reused across every
    /// schedule (the schedules race arbitration, not compilation).
    fn sched_registry() -> (Arc<ModelRegistry>, ModelId) {
        let reg = Arc::new(ModelRegistry::new(4));
        let net = cnn_a_quant(&mut Xoshiro256::new(5), 2);
        let id = reg
            .register("fuzz", ArrayConfig::new(1, 8, 2), net, 4)
            .expect("fuzz model registers");
        (reg, id)
    }

    fn run_schedule(seed: u64, registry: &Arc<ModelRegistry>, model: ModelId) -> Vec<String> {
        let mut sr = SchedRig::new(seed, registry, model);
        sr.run_ops();
        sr.drain();
        let (_tally, trace) = sr.finish();
        trace
    }

    /// ≥ 1000 fuzzed schedules: the accounting identity, the
    /// no-orphaned-reply invariant and full ledger quiescence must hold
    /// after every deterministic interleaving.  A failing schedule
    /// prints its replay seed.
    #[test]
    fn sched_fuzz_accounting_identity_over_1000_schedules() {
        let (reg, model) = sched_registry();
        if let Some(seed) = prop::env_seed("BINARRAY_SCHED_SEED") {
            run_schedule(seed, &reg, model);
            return;
        }
        for case in 0..1024u64 {
            let seed = prop::case_seed(case);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_schedule(seed, &reg, model)
            }));
            if let Err(p) = result {
                let msg = p
                    .downcast_ref::<String>()
                    .map(String::as_str)
                    .or_else(|| p.downcast_ref::<&str>().copied())
                    .unwrap_or("non-string panic");
                panic!(
                    "schedule {case} (seed {seed:#x}) violated an invariant: {msg}\n  \
                     replay with: BINARRAY_SCHED_SEED={seed:#x} cargo test sched_fuzz"
                );
            }
        }
    }

    /// The replay contract behind the printed seed: the same seed must
    /// reproduce the same schedule byte for byte (operations, batch
    /// compositions, grants, final tally — the whole trace).
    #[test]
    fn sched_schedules_replay_byte_identically() {
        let (reg, model) = sched_registry();
        for case in [0u64, 7, 23] {
            let seed = prop::case_seed(case);
            let a = run_schedule(seed, &reg, model);
            let b = run_schedule(seed, &reg, model);
            assert_eq!(a, b, "seed {seed:#x} did not replay identically");
        }
        // distinct seeds must actually produce distinct schedules — the
        // byte-identity check above would pass vacuously on a trace
        // that ignored its seed
        let a = run_schedule(prop::case_seed(0), &reg, model);
        let b = run_schedule(prop::case_seed(7), &reg, model);
        assert_ne!(a, b, "different seeds produced identical schedules");
    }

    /// A tiny but real registered model (the rig never runs frames, so
    /// only compilability matters — cheapness is the point).
    fn tiny_registry_net(seed: u64) -> crate::artifacts::QuantNetwork {
        let tiny = crate::verify::Budget {
            convs: 1,
            max_d: 3,
            max_kh: 2,
            max_pool: 1,
            max_m: 2,
            denses: 1,
        };
        let (net, _hw) = crate::verify::random_network(&mut Xoshiro256::new(seed), 2, &tiny);
        net
    }

    /// Registry `swap` raced against in-flight batch cuts at *every*
    /// permutation point: requests admitted before the swap pin the old
    /// epoch, requests after it the new one, and no cut batch ever
    /// mixes the two — the epoch-laned batcher keeps them apart.
    #[test]
    fn swap_never_mixes_epochs_in_a_cut_batch() {
        const N: usize = 6;
        let cfg = ArrayConfig::new(1, 4, 1);
        for p in 0..=N {
            let reg = Arc::new(ModelRegistry::new(2));
            let id = reg
                .register("m", cfg, tiny_registry_net(21), 0)
                .expect("tiny model registers");
            let mut rig = router_rig(2, RoutePolicy::BatchOnly);
            let policy = BatchPolicy {
                max_batch: 8, // > N: nothing cuts until the delay ripens
                max_delay: Duration::from_secs(2),
            };
            rig.router.policy = policy;
            rig.router.batcher = Batcher::new(policy);
            rig.router.registry = Arc::clone(&reg);
            let base = Instant::now();
            let mut admit_epochs = Vec::new();
            let mut rxs = Vec::new();
            for i in 0..N {
                if i == p {
                    reg.swap("m", cfg, tiny_registry_net(22)).expect("swap");
                }
                let (tx, rx) = channel::<ReplyResult>();
                let mut req = rig_request(i as u64, Some(DispatchClass::Batch));
                req.model = id;
                req.submitted = base;
                rig.router.handle(RouterMsg::Submit(req, tx));
                rxs.push(rx);
                admit_epochs.push(reg.get(id).expect("registered").epoch);
                // mid-fill pump: must not cut the unripe lane(s)
                rig.router.pump(base);
            }
            if p == N {
                reg.swap("m", cfg, tiny_registry_net(22)).expect("swap");
            }
            assert_eq!(rig.router.batcher.pending(), N, "p={p}: premature cut");
            // the delay ripens both epoch lanes at once; two free cards
            // take the (up to) two cuts in the same pump
            rig.router.pump(base + Duration::from_secs(3));
            let mut seen_epochs = std::collections::BTreeMap::<u64, Vec<u64>>::new();
            for rx in &rig.worker_rxs {
                while let Ok(msg) = rx.try_recv() {
                    let WorkerMsg::Run(batch, _txs) = msg else {
                        panic!("unexpected worker message");
                    };
                    let be = batch
                        .entry
                        .as_ref()
                        .expect("registry-admitted batch pins an entry")
                        .epoch;
                    for r in &batch.requests {
                        let re = r.entry.as_ref().expect("admitted request pins an entry").epoch;
                        assert_eq!(re, be, "p={p}: request {} rides a mixed-epoch batch", r.id);
                        assert_eq!(
                            re, admit_epochs[r.id as usize],
                            "p={p}: request {} lost its admission-time epoch",
                            r.id
                        );
                        seen_epochs.entry(be).or_default().push(r.id);
                    }
                }
            }
            let mut served: Vec<u64> = seen_epochs.values().flatten().copied().collect();
            served.sort_unstable();
            assert_eq!(
                served,
                (0..N as u64).collect::<Vec<_>>(),
                "p={p}: every admitted request dispatches exactly once"
            );
            let distinct = if p == 0 || p == N { 1 } else { 2 };
            assert_eq!(
                seen_epochs.len(),
                distinct,
                "p={p}: expected {distinct} epoch lane(s), saw {:?}",
                seen_epochs
            );
            if 0 < p && p < N {
                assert_ne!(admit_epochs[0], admit_epochs[N - 1], "swap must bump the epoch");
            }
        }
    }
}
