//! Model registry: named (network, config) entries, each with its own
//! compiled program, cached [`ExecutionPlan`], [`ShardPlanCache`] and
//! [`CapacityModel`], atomically published behind an `RwLock` so models
//! can be registered or hot-swapped with zero downtime.
//!
//! BinArray's headline claim is that one instruction-set processor
//! serves networks of very different sizes (§VI) — unlike fixed-function
//! binary accelerators synthesized per network.  The registry is the
//! serving-side realization: the coordinator no longer owns one network
//! per process; every [`Request`](super::Request) names a model, the
//! router resolves it at admission, and the resolved [`ModelEntry`] is
//! *pinned* to the request from that point on.
//!
//! **Swap semantics.**  [`ModelRegistry::swap`] compiles the incoming
//! network outside any lock (registration cost is paid on the caller's
//! thread, never on the serving path), then replaces the slot under a
//! short write lock and bumps the entry's epoch.  In-flight requests
//! keep the `Arc<ModelEntry>` they were admitted under, so they drain on
//! the old plan; admissions after the swap resolve the new entry.  No
//! request ever observes a half-published model and no request fails
//! *because* of a swap — the old plan's workers rebuild lazily on the
//! first post-swap batch (batches never mix epochs, see the batcher's
//! lane key).
//!
//! **Weight-memory accounting.**  Each entry records its compiled weight
//! footprint (`Program::wgt_words`); a registry constructed with a
//! budget refuses registrations that would oversubscribe the modeled
//! weight BRAM across tenants — the per-model half of the
//! per-(tenant, model) admission story (the per-class half lives in
//! [`ClassTable`](super::route::ClassTable)).

use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Result};

use crate::artifacts::QuantNetwork;
use crate::binarray::{ArrayConfig, ExecutionPlan, ShardPlanCache};
use crate::isa::{compile_network, Program};
use crate::tensor::Shape;

use super::capacity::CapacityModel;

/// Dense handle naming a registry slot.  `ModelId::DEFAULT` (slot 0) is
/// what v1 wire frames and model-less [`InferRequest`](super::server::InferRequest)s
/// resolve to.  Ids are stable across swaps — a swap replaces the slot's
/// entry (bumping its epoch), it never renumbers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(pub u32);

impl ModelId {
    /// Slot 0: the model v1 wire traffic and unqualified requests get.
    pub const DEFAULT: ModelId = ModelId(0);
}

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "model#{}", self.0)
    }
}

/// One published model: everything the serving path needs, immutable
/// once published.  Requests hold this via `Arc` from admission to
/// reply, so a concurrent swap can never pull the plan out from under
/// running work.
pub struct ModelEntry {
    pub id: ModelId,
    pub name: Arc<str>,
    /// Bumped on every swap of this slot.  Batches never mix epochs, so
    /// a worker can key its lazily-built accelerator instance on
    /// `(id, epoch)` and rebuild exactly when the model actually changed.
    pub epoch: u64,
    pub cfg: ArrayConfig,
    pub net: Arc<QuantNetwork>,
    pub prog: Arc<Program>,
    pub plan: Arc<ExecutionPlan>,
    pub cache: Arc<ShardPlanCache>,
    /// Per-model admission pricing: this entry's plan-derived frame
    /// costs and its own observed pace.
    pub capacity: Arc<CapacityModel>,
    /// Compiled weight-memory footprint (words) — the registry's
    /// cross-tenant budget currency.
    pub weight_words: u64,
    /// Per-model inflight cap (0 = unlimited), checked at admission
    /// alongside the per-class budget: together per-(tenant, model).
    pub admission_limit: usize,
}

impl ModelEntry {
    pub fn input_shape(&self) -> Shape {
        self.plan.input_shape
    }

    pub fn input_len(&self) -> usize {
        self.plan.input_shape.len()
    }

    pub fn max_m(&self) -> usize {
        self.net.max_m()
    }
}

impl std::fmt::Debug for ModelEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelEntry")
            .field("id", &self.id)
            .field("name", &self.name)
            .field("epoch", &self.epoch)
            .field("cfg", &self.cfg)
            .field("weight_words", &self.weight_words)
            .finish_non_exhaustive()
    }
}

struct Inner {
    slots: Vec<Arc<ModelEntry>>,
    /// Monotonic swap counter shared by all slots — an epoch uniquely
    /// identifies one published entry even across different slots.
    next_epoch: u64,
}

/// The registry proper.  Cheap to share (`Arc<ModelRegistry>`); reads
/// on the admission path are one `RwLock` read + one `Arc` clone.
pub struct ModelRegistry {
    inner: RwLock<Inner>,
    /// Shard-plan fan-out ceiling baked into each entry's cache
    /// (the coordinator's worker-pool width at construction).
    max_cards: usize,
    /// Total weight-word budget across all registered models
    /// (0 = unlimited).  Models whose combined compiled footprint would
    /// exceed it are refused at registration.
    weight_budget: u64,
}

/// Wire addressing is a u8 model field, so a registry never exceeds 256
/// slots — every registered model stays wire-addressable.
pub const MAX_MODELS: usize = 256;

impl ModelRegistry {
    /// An empty registry whose shard plans will fan out over at most
    /// `max_cards` cards.
    pub fn new(max_cards: usize) -> Self {
        Self {
            inner: RwLock::new(Inner { slots: Vec::new(), next_epoch: 0 }),
            max_cards: max_cards.max(1),
            weight_budget: 0,
        }
    }

    /// Like [`Self::new`] with a cross-model weight-memory budget in
    /// words; registrations that would oversubscribe it are refused.
    pub fn with_weight_budget(max_cards: usize, weight_budget: u64) -> Self {
        Self {
            weight_budget,
            ..Self::new(max_cards)
        }
    }

    /// Compile everything an entry needs.  Runs on the caller's thread,
    /// outside the registry lock — the expensive half of register/swap.
    fn compile(
        &self,
        id: ModelId,
        name: Arc<str>,
        epoch: u64,
        cfg: ArrayConfig,
        net: QuantNetwork,
        admission_limit: usize,
    ) -> Result<ModelEntry> {
        if net.layers.is_empty() {
            bail!("model '{name}': empty network");
        }
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(cfg, &net, &prog);
        // Static verification gates publication: a model whose MULW
        // range proof or schedule/ISA lint fails never reaches a slot
        // (register and swap both funnel through here).
        crate::analysis::verify_model(&net, &prog, &plan, self.max_cards)
            .map_err(|e| anyhow!("model '{name}': static analysis rejected the plan: {e}"))?;
        let cache = ShardPlanCache::new(&plan, self.max_cards);
        let capacity = CapacityModel::new(&plan, &net);
        let weight_words = prog.wgt_words as u64;
        Ok(ModelEntry {
            id,
            name,
            epoch,
            cfg,
            net: Arc::new(net),
            prog: Arc::new(prog),
            plan: Arc::new(plan),
            cache: Arc::new(cache),
            capacity: Arc::new(capacity),
            weight_words,
            admission_limit,
        })
    }

    /// Register a new named model; returns its id.  Compilation happens
    /// before the write lock is taken, so serving traffic never stalls
    /// behind a registration.
    pub fn register(
        &self,
        name: &str,
        cfg: ArrayConfig,
        net: QuantNetwork,
        admission_limit: usize,
    ) -> Result<ModelId> {
        // Pre-checks under a read lock (cheap, racy only against other
        // registrars — re-checked under the write lock below).
        let (id, epoch) = {
            let inner = self.inner.read().unwrap();
            if inner.slots.len() >= MAX_MODELS {
                bail!("registry full ({MAX_MODELS} models)");
            }
            if inner.slots.iter().any(|e| &*e.name == name) {
                bail!("model '{name}' already registered (use swap)");
            }
            (ModelId(inner.slots.len() as u32), inner.next_epoch)
        };
        let entry = self.compile(id, Arc::from(name), epoch, cfg, net, admission_limit)?;
        let mut inner = self.inner.write().unwrap();
        // Re-validate: another registrar may have won the race.
        if inner.slots.len() >= MAX_MODELS {
            bail!("registry full ({MAX_MODELS} models)");
        }
        if inner.slots.iter().any(|e| &*e.name == name) {
            bail!("model '{name}' already registered (use swap)");
        }
        if self.weight_budget > 0 {
            let used: u64 = inner.slots.iter().map(|e| e.weight_words).sum();
            if used + entry.weight_words > self.weight_budget {
                bail!(
                    "model '{name}': weight budget exceeded ({} + {} > {})",
                    used,
                    entry.weight_words,
                    self.weight_budget
                );
            }
        }
        let id = ModelId(inner.slots.len() as u32);
        let epoch = inner.next_epoch;
        inner.next_epoch += 1;
        // The racy pre-pick may be stale; publish under the final id.
        let mut entry = entry;
        entry.id = id;
        entry.epoch = epoch;
        inner.slots.push(Arc::new(entry));
        Ok(id)
    }

    /// Hot-swap the named model's network/config.  Compiles outside the
    /// lock, then atomically replaces the slot and bumps its epoch.
    /// In-flight requests keep their old `Arc<ModelEntry>` and drain on
    /// the old plan; every admission after this returns resolves the new
    /// one.
    pub fn swap(&self, name: &str, cfg: ArrayConfig, net: QuantNetwork) -> Result<ModelId> {
        let (id, admission_limit) = {
            let inner = self.inner.read().unwrap();
            let e = inner
                .slots
                .iter()
                .find(|e| &*e.name == name)
                .ok_or_else(|| anyhow::anyhow!("model '{name}' not registered"))?;
            (e.id, e.admission_limit)
        };
        let entry = self.compile(id, Arc::from(name), 0, cfg, net, admission_limit)?;
        let mut inner = self.inner.write().unwrap();
        let slot = id.0 as usize;
        if slot >= inner.slots.len() || &*inner.slots[slot].name != name {
            bail!("model '{name}' disappeared during swap");
        }
        if self.weight_budget > 0 {
            let used: u64 = inner
                .slots
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != slot)
                .map(|(_, e)| e.weight_words)
                .sum();
            if used + entry.weight_words > self.weight_budget {
                bail!("model '{name}': weight budget exceeded by swap");
            }
        }
        let epoch = inner.next_epoch;
        inner.next_epoch += 1;
        let mut entry = entry;
        entry.epoch = epoch;
        inner.slots[slot] = Arc::new(entry);
        Ok(id)
    }

    /// Resolve an id to its current published entry.
    pub fn get(&self, id: ModelId) -> Option<Arc<ModelEntry>> {
        self.inner.read().unwrap().slots.get(id.0 as usize).cloned()
    }

    /// Resolve a name to its current published entry.
    pub fn lookup(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.inner
            .read()
            .unwrap()
            .slots
            .iter()
            .find(|e| &*e.name == name)
            .cloned()
    }

    /// Slot 0 — what v1 wire frames and unqualified requests serve.
    pub fn default_model(&self) -> Option<Arc<ModelEntry>> {
        self.get(ModelId::DEFAULT)
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(id, name)` of every registered model, in slot order.
    pub fn names(&self) -> Vec<(ModelId, String)> {
        self.inner
            .read()
            .unwrap()
            .slots
            .iter()
            .map(|e| (e.id, e.name.to_string()))
            .collect()
    }

    /// Combined compiled weight footprint of every registered model.
    pub fn weight_words(&self) -> u64 {
        self.inner.read().unwrap().slots.iter().map(|e| e.weight_words).sum()
    }

    /// The fan-out ceiling entries' shard caches were built for.
    pub fn max_cards(&self) -> usize {
        self.max_cards
    }
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.read().unwrap();
        f.debug_struct("ModelRegistry")
            .field("models", &inner.slots.len())
            .field("max_cards", &self.max_cards)
            .field("weight_budget", &self.weight_budget)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::util::rng::Xoshiro256;

    fn net(seed: u64, m: usize) -> QuantNetwork {
        cnn_a_quant(&mut Xoshiro256::new(seed), m)
    }

    #[test]
    fn register_resolve_and_default() {
        let reg = ModelRegistry::new(2);
        assert!(reg.is_empty());
        assert!(reg.default_model().is_none());
        let a = reg.register("a", ArrayConfig::new(1, 8, 2), net(1, 2), 0).unwrap();
        let b = reg.register("b", ArrayConfig::new(1, 32, 2), net(2, 4), 3).unwrap();
        assert_eq!(a, ModelId::DEFAULT);
        assert_eq!(b, ModelId(1));
        assert_eq!(reg.len(), 2);
        let ea = reg.get(a).unwrap();
        assert_eq!(&*ea.name, "a");
        assert_eq!(ea.max_m(), 2);
        assert!(ea.weight_words > 0);
        let eb = reg.lookup("b").unwrap();
        assert_eq!(eb.id, b);
        assert_eq!(eb.admission_limit, 3);
        assert_eq!(reg.default_model().unwrap().id, a);
        assert!(reg.get(ModelId(9)).is_none());
        assert!(reg.lookup("nope").is_none());
        assert_eq!(
            reg.names(),
            vec![(a, "a".to_string()), (b, "b".to_string())]
        );
    }

    #[test]
    fn duplicate_and_empty_registrations_are_refused() {
        let reg = ModelRegistry::new(1);
        reg.register("a", ArrayConfig::new(1, 8, 2), net(1, 2), 0).unwrap();
        let err = reg
            .register("a", ArrayConfig::new(1, 8, 2), net(1, 2), 0)
            .expect_err("duplicate name");
        assert!(err.to_string().contains("already registered"), "{err}");
        let err = reg
            .register("empty", ArrayConfig::new(1, 8, 2), QuantNetwork { f_input: 7, layers: vec![] }, 0)
            .expect_err("empty network");
        assert!(err.to_string().contains("empty network"), "{err}");
    }

    #[test]
    fn swap_replaces_in_place_and_bumps_the_epoch() {
        let reg = ModelRegistry::new(2);
        let id = reg.register("a", ArrayConfig::new(1, 8, 2), net(1, 2), 7).unwrap();
        let before = reg.get(id).unwrap();
        // old entry survives the swap for whoever holds it
        let swapped = reg.swap("a", ArrayConfig::new(1, 32, 2), net(9, 4)).unwrap();
        assert_eq!(swapped, id, "swap keeps the slot id");
        let after = reg.get(id).unwrap();
        assert!(after.epoch > before.epoch, "epoch bumped");
        assert_eq!(after.max_m(), 4, "new network published");
        assert_eq!(after.admission_limit, 7, "limit carried over");
        assert_eq!(before.max_m(), 2, "pinned old entry untouched");
        assert_eq!(reg.len(), 1);
        assert!(reg.swap("ghost", ArrayConfig::new(1, 8, 2), net(1, 2)).is_err());
    }

    #[test]
    fn weight_budget_refuses_oversubscription() {
        let probe = ModelRegistry::new(1);
        probe.register("p", ArrayConfig::new(1, 8, 2), net(1, 2), 0).unwrap();
        let one_model = probe.weight_words();
        assert!(one_model > 0);
        // room for one model, not two
        let reg = ModelRegistry::with_weight_budget(1, one_model + one_model / 2);
        reg.register("a", ArrayConfig::new(1, 8, 2), net(1, 2), 0).unwrap();
        let err = reg
            .register("b", ArrayConfig::new(1, 8, 2), net(2, 2), 0)
            .expect_err("budget exceeded");
        assert!(err.to_string().contains("weight budget"), "{err}");
        // swap within the same slot stays inside the budget
        reg.swap("a", ArrayConfig::new(1, 8, 2), net(3, 2)).unwrap();
        // a swap that would blow the budget is refused and the old
        // entry stays published
        let err = reg
            .swap("a", ArrayConfig::new(1, 8, 2), net(4, 4))
            .expect_err("m=4 doubles the planes");
        assert!(err.to_string().contains("weight budget"), "{err}");
        assert_eq!(reg.get(ModelId::DEFAULT).unwrap().max_m(), 2);
    }
}
