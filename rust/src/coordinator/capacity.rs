//! Admission-control capacity model — "can the pool still promise this
//! SLO?" answered *at submit*, not discovered at the shed gate.
//!
//! FINN sizes its dataflow pipeline to a user-stated FPS target before
//! anything runs (arXiv 1612.07119); this is the runtime equivalent for
//! a shared pool.  The model has two halves:
//!
//! * **static cost** — per accuracy mode, an estimated cycle count per
//!   frame derived from the cached [`ExecutionPlan`] schedules (the same
//!   structure the executor walks, so the estimate prices exactly the
//!   work units that will run: per layer, the widest logical-SA group's
//!   serial unit stream, times the sequential level-group passes);
//! * **calibration** — the host's observed *pace* (wall time per
//!   estimated cycle), updated by the workers after every batch as a
//!   running **minimum**.
//!
//! The conservatism guarantee follows from the minimum: the model's
//! predicted service time for a mode never exceeds `est_cycles(mode) ×
//! fastest-pace-ever-observed` — i.e. the prediction is the cheapest
//! this host has ever been seen to do that work.  Admission refuses a
//! request only when even that floor, stacked on the work already
//! committed ahead of it, lands past the deadline — so refused work is
//! provably unmeetable under the best observed behavior.
//!
//! **Cold start.** The running minimum is *seeded at construction* with
//! the pace the plan itself promises: one estimated cycle per simulated
//! 400 MHz tick ([`crate::binarray::CLOCK_HZ`]).  Before any completion
//! the model therefore refuses exactly the work the modeled accelerator
//! itself could not serve — nothing the host could conceivably meet —
//! and, because observations only ever *lower* the minimum, an
//! unrepresentative first batch (cold caches, page faults) can never
//! raise the floor above the seed and mass-refuse the first burst.  The
//! pre-seed behavior (pace undefined until the first completion) priced
//! the very first burst off whatever that first batch happened to
//! measure: slow outlier ⇒ mass-refusal, no completion yet ⇒ the gate
//! proved nothing at all.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::artifacts::{LayerKind, QuantNetwork};
use crate::binarray::ExecutionPlan;

use super::Mode;

/// The construction-time pace seed: picoseconds per estimated cycle at
/// the simulated accelerator's own clock.  The cheapest any frame could
/// conceivably be — the host *simulates* those cycles — so refusals
/// priced off the seed are sound before the first completion.
fn plan_seed_ps() -> u64 {
    (1.0e12 / crate::binarray::CLOCK_HZ).max(1.0) as u64
}

/// Per-mode frame cost + observed host pace (see module docs).
///
/// Shared `Arc`-style between the router (admission decisions, backlog
/// ledger) and the workers/orchestrator (pace observations) — all
/// methods take `&self`; the pace is an atomic minimum.
#[derive(Debug)]
pub struct CapacityModel {
    /// Estimated cycles per frame; index 0 = high accuracy, `m` = the
    /// truncated `m_run = m` plan (same layout as [`ExecutionPlan`]).
    est: Vec<u64>,
    max_m: usize,
    m_arch: usize,
    /// Minimum pace in picoseconds per *estimated* cycle, seeded at
    /// construction with [`plan_seed_ps`] and lowered by observations.
    pace_ps: AtomicU64,
}

impl CapacityModel {
    /// Price every accuracy mode of `plan` (built for `net`).
    pub fn new(plan: &ExecutionPlan, net: &QuantNetwork) -> Self {
        let est = (0..=plan.max_m)
            .map(|i| {
                let m_run = if i == 0 { None } else { Some(i) };
                plan.mode(m_run)
                    .layers
                    .iter()
                    .map(|lp| {
                        let l = &net.layers[lp.layer];
                        let np = l.pool.max(1);
                        // Per-window stream cost: the SA streams the
                        // whole input window (n_c words) per output.
                        let n_c = l.n_c().max(1) as u64;
                        // Widest logical-SA group bounds the layer's
                        // wall (groups run in parallel on the SAs, units
                        // within a group run serially).
                        let widest = lp
                            .assignments
                            .iter()
                            .map(|units| {
                                units
                                    .iter()
                                    .map(|u| match lp.kind {
                                        LayerKind::Conv => {
                                            let windows = (u.rows.len() * np) as u64
                                                * (lp.out_shape.w * np) as u64;
                                            windows * n_c
                                        }
                                        // dense units are ≤ D_arch
                                        // channel chunks: one stream
                                        LayerKind::Dense => n_c,
                                    })
                                    .sum::<u64>()
                            })
                            .max()
                            .unwrap_or(0);
                        widest * lp.seq_m
                    })
                    .sum::<u64>()
                    .max(1)
            })
            .collect();
        Self {
            est,
            max_m: plan.max_m,
            m_arch: plan.cfg.m_arch,
            pace_ps: AtomicU64::new(plan_seed_ps()),
        }
    }

    /// A degenerate single-cost model (router unit rigs, simulations):
    /// every mode prices at `est_cycles`.  Seeded like [`Self::new`].
    pub fn fixed(est_cycles: u64) -> Self {
        Self {
            est: vec![est_cycles.max(1); 2],
            max_m: 1,
            m_arch: 1,
            pace_ps: AtomicU64::new(plan_seed_ps()),
        }
    }

    /// Estimated cycles for one frame of `mode`.
    pub fn est_cycles(&self, mode: Mode) -> u64 {
        let idx = match mode {
            Mode::HighAccuracy => 0,
            Mode::HighThroughput => self.m_arch.clamp(1, self.max_m),
        };
        self.est[idx]
    }

    /// Estimated cycles by raw plan mode index (0 = high accuracy,
    /// `m` = the truncated `m_run = m` plan).  The static analyzer
    /// cross-checks its independent recomputation against these
    /// priced values without going through [`Mode`].
    pub fn est_by_index(&self, idx: usize) -> Option<u64> {
        self.est.get(idx).copied()
    }

    /// Record a completion: `frames` frames of `mode` took `wall` using
    /// `cards` cards at once (1 for a batch-lane run, the lease width
    /// for a sharded frame).  The pace is charged in *card-time* —
    /// `wall × cards` — so a frame scattered over k cards doesn't
    /// masquerade as a k×-faster single card and deflate the floor
    /// (`earliest_feasible` divides by the pool width again; charging
    /// wall alone would discount parallelism twice and quietly disarm
    /// the gate).  Keeps the *minimum* pace (see module docs for why
    /// min is the conservative choice).
    pub fn observe(&self, mode: Mode, frames: usize, wall: Duration, cards: usize) {
        if frames == 0 {
            return;
        }
        let total = self.est_cycles(mode).saturating_mul(frames as u64);
        let card_ps = wall
            .as_nanos()
            .saturating_mul(1000)
            .saturating_mul(cards.max(1) as u128);
        let ps = (card_ps / total as u128).min(u64::MAX as u128);
        self.pace_ps.fetch_min((ps as u64).max(1), Ordering::Relaxed);
    }

    /// The pace floor (ps per estimated cycle): the plan-derived seed
    /// until an observation beats it, the fastest observation after.
    pub fn pace_ps(&self) -> u64 {
        self.pace_ps.load(Ordering::Relaxed)
    }

    /// Force the pace (tests and rigs — production calibration goes
    /// through [`Self::observe`]).
    pub fn set_pace_ps(&self, ps: u64) {
        self.pace_ps.store(ps.max(1), Ordering::Relaxed);
    }

    /// Cheapest time one frame of `mode` could take under the pace
    /// floor (the plan seed at worst, the fastest observation at best).
    pub fn service_floor(&self, mode: Mode) -> Duration {
        ps_to_duration(self.est_cycles(mode) as u128 * self.pace_ps() as u128)
    }

    /// Earliest-completion *floor* for a new frame of `mode` admitted
    /// now: the committed work ahead of it (`backlog_cycles`) plus its
    /// own cost, spread perfectly over `cards` — no queueing overhead,
    /// no stragglers, the fastest pace ever observed (seeded from the
    /// plan before the first completion).  Actual completion can only be
    /// later, so `deadline < now + floor` is a sound refusal.
    pub fn earliest_feasible(&self, mode: Mode, backlog_cycles: u64, cards: usize) -> Duration {
        let total = backlog_cycles as u128 + self.est_cycles(mode) as u128;
        ps_to_duration(total * self.pace_ps() as u128 / cards.max(1) as u128)
    }
}

fn ps_to_duration(ps: u128) -> Duration {
    Duration::from_nanos((ps / 1000).min(u64::MAX as u128) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binarray::ArrayConfig;
    use crate::isa::compile_network;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::util::rng::Xoshiro256;

    fn model() -> CapacityModel {
        let mut rng = Xoshiro256::new(0xCAFE);
        let net = cnn_a_quant(&mut rng, 4);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(1, 8, 2), &net, &prog);
        CapacityModel::new(&plan, &net)
    }

    #[test]
    fn high_throughput_mode_is_priced_cheaper() {
        let m = model();
        let hi = m.est_cycles(Mode::HighAccuracy);
        let lo = m.est_cycles(Mode::HighThroughput);
        assert!(hi > lo, "M=4 on M_arch=2: full accuracy is ~2× the work ({hi} vs {lo})");
        assert!(lo > 0);
    }

    #[test]
    fn fresh_model_is_seeded_with_the_plan_pace() {
        let m = model();
        let seed = plan_seed_ps();
        assert_eq!(seed, 2_500, "400 MHz ⇒ 2.5 ns per simulated cycle");
        assert_eq!(m.pace_ps(), seed);
        // the seed makes every floor finite from the very first submit:
        // a fresh coordinator prices work instead of proving nothing
        assert!(m.service_floor(Mode::HighAccuracy) > Duration::ZERO);
        let est = m.est_cycles(Mode::HighAccuracy);
        assert_eq!(
            m.earliest_feasible(Mode::HighAccuracy, 0, 1),
            ps_to_duration(est as u128 * seed as u128),
        );
    }

    /// The regression the seed exists to prevent: an unrepresentative
    /// first observation (cold caches, page faults) arriving before any
    /// other calibration must not raise the floor and mass-refuse the
    /// first burst — the pace is a minimum and the seed is already in it.
    #[test]
    fn a_slow_first_observation_cannot_raise_the_seeded_floor() {
        let m = CapacityModel::fixed(1_000);
        let seed = m.pace_ps();
        m.observe(Mode::HighAccuracy, 1, Duration::from_secs(10), 1);
        assert_eq!(m.pace_ps(), seed, "slow outlier leaves the seed in place");
    }

    #[test]
    fn pace_is_a_running_minimum() {
        let m = model();
        // start well above any observation this test makes, so the
        // min dynamics (not the construction seed) are what's exercised
        m.set_pace_ps(50_000_000);
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(10), 1);
        let first = m.pace_ps();
        assert!(first < 50_000_000, "observation lowered the floor");
        // a slower observation must not raise the floor
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(40), 1);
        assert_eq!(m.pace_ps(), first);
        // a faster one lowers it
        m.observe(Mode::HighAccuracy, 2, Duration::from_millis(10), 1);
        let lower = m.pace_ps();
        assert!(lower < first, "{lower} < {first}");
        // the service floor for the observed mode never exceeds the
        // cheapest per-frame wall ever seen (the conservatism guarantee)
        assert!(m.service_floor(Mode::HighAccuracy) <= Duration::from_millis(5));
    }

    /// A frame sharded over k cards is charged k card-seconds: the same
    /// work finishing k× faster on k× the cards must not move the
    /// per-card pace floor (parallelism is already credited by
    /// `earliest_feasible`'s division — crediting it here too would
    /// disarm the gate after one wide-sharded frame).
    #[test]
    fn sharded_observation_does_not_deflate_the_pace() {
        let m = CapacityModel::fixed(1_000);
        m.set_pace_ps(20_000_000); // park the floor above the observations
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(10), 1);
        let floor = m.pace_ps();
        // perfect 4-way sharding: wall/4 on 4 cards = the same card-time
        m.observe(Mode::HighAccuracy, 1, Duration::from_micros(2_500), 4);
        assert_eq!(m.pace_ps(), floor, "same card-time, same floor");
        // real sharding has scatter/gather overhead: more card-time,
        // floor untouched
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(4), 4);
        assert_eq!(m.pace_ps(), floor);
    }

    #[test]
    fn earliest_feasible_scales_with_backlog_and_cards() {
        let m = CapacityModel::fixed(1_000);
        m.set_pace_ps(1_000_000); // 1 µs per est-cycle ⇒ 1 ms per frame
        let own = m.earliest_feasible(Mode::HighAccuracy, 0, 1);
        assert_eq!(own, Duration::from_millis(1));
        let queued = m.earliest_feasible(Mode::HighAccuracy, 9_000, 1);
        assert_eq!(queued, Duration::from_millis(10), "9 frames ahead + own");
        let wide = m.earliest_feasible(Mode::HighAccuracy, 9_000, 4);
        assert_eq!(wide, Duration::from_micros(2500), "perfectly parallel floor");
    }
}
