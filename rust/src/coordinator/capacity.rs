//! Admission-control capacity model — "can the pool still promise this
//! SLO?" answered *at submit*, not discovered at the shed gate.
//!
//! FINN sizes its dataflow pipeline to a user-stated FPS target before
//! anything runs (arXiv 1612.07119); this is the runtime equivalent for
//! a shared pool.  The model has two halves:
//!
//! * **static cost** — per accuracy mode, an estimated cycle count per
//!   frame derived from the cached [`ExecutionPlan`] schedules (the same
//!   structure the executor walks, so the estimate prices exactly the
//!   work units that will run: per layer, the widest logical-SA group's
//!   serial unit stream, times the sequential level-group passes);
//! * **calibration** — the host's observed *pace* (wall time per
//!   estimated cycle), updated by the workers after every batch as a
//!   running **minimum**.
//!
//! The conservatism guarantee follows from the minimum: the model's
//! predicted service time for a mode never exceeds `est_cycles(mode) ×
//! fastest-pace-ever-observed` — i.e. the prediction is the cheapest
//! this host has ever been seen to do that work.  Admission refuses a
//! request only when even that floor, stacked on the work already
//! committed ahead of it, lands past the deadline — so refused work is
//! provably unmeetable under the best observed behavior, and an
//! uncalibrated model (no completions yet) refuses nothing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::artifacts::{LayerKind, QuantNetwork};
use crate::binarray::ExecutionPlan;

use super::Mode;

/// Sentinel for "no completion observed yet" — the model predicts
/// nothing (and admission refuses nothing) until a real frame sets the
/// pace.
const UNCALIBRATED: u64 = u64::MAX;

/// Per-mode frame cost + observed host pace (see module docs).
///
/// Shared `Arc`-style between the router (admission decisions, backlog
/// ledger) and the workers/orchestrator (pace observations) — all
/// methods take `&self`; the pace is an atomic minimum.
#[derive(Debug)]
pub struct CapacityModel {
    /// Estimated cycles per frame; index 0 = high accuracy, `m` = the
    /// truncated `m_run = m` plan (same layout as [`ExecutionPlan`]).
    est: Vec<u64>,
    max_m: usize,
    m_arch: usize,
    /// Minimum observed pace in picoseconds per *estimated* cycle
    /// ([`UNCALIBRATED`] until the first completion).
    pace_ps: AtomicU64,
}

impl CapacityModel {
    /// Price every accuracy mode of `plan` (built for `net`).
    pub fn new(plan: &ExecutionPlan, net: &QuantNetwork) -> Self {
        let est = (0..=plan.max_m)
            .map(|i| {
                let m_run = if i == 0 { None } else { Some(i) };
                plan.mode(m_run)
                    .layers
                    .iter()
                    .map(|lp| {
                        let l = &net.layers[lp.layer];
                        let np = l.pool.max(1);
                        // Per-window stream cost: the SA streams the
                        // whole input window (n_c words) per output.
                        let n_c = l.n_c().max(1) as u64;
                        // Widest logical-SA group bounds the layer's
                        // wall (groups run in parallel on the SAs, units
                        // within a group run serially).
                        let widest = lp
                            .assignments
                            .iter()
                            .map(|units| {
                                units
                                    .iter()
                                    .map(|u| match lp.kind {
                                        LayerKind::Conv => {
                                            let windows = (u.rows.len() * np) as u64
                                                * (lp.out_shape.w * np) as u64;
                                            windows * n_c
                                        }
                                        // dense units are ≤ D_arch
                                        // channel chunks: one stream
                                        LayerKind::Dense => n_c,
                                    })
                                    .sum::<u64>()
                            })
                            .max()
                            .unwrap_or(0);
                        widest * lp.seq_m
                    })
                    .sum::<u64>()
                    .max(1)
            })
            .collect();
        Self {
            est,
            max_m: plan.max_m,
            m_arch: plan.cfg.m_arch,
            pace_ps: AtomicU64::new(UNCALIBRATED),
        }
    }

    /// A degenerate single-cost model (router unit rigs, simulations):
    /// every mode prices at `est_cycles`.
    pub fn fixed(est_cycles: u64) -> Self {
        Self {
            est: vec![est_cycles.max(1); 2],
            max_m: 1,
            m_arch: 1,
            pace_ps: AtomicU64::new(UNCALIBRATED),
        }
    }

    /// Estimated cycles for one frame of `mode`.
    pub fn est_cycles(&self, mode: Mode) -> u64 {
        let idx = match mode {
            Mode::HighAccuracy => 0,
            Mode::HighThroughput => self.m_arch.clamp(1, self.max_m),
        };
        self.est[idx]
    }

    /// Record a completion: `frames` frames of `mode` took `wall` using
    /// `cards` cards at once (1 for a batch-lane run, the lease width
    /// for a sharded frame).  The pace is charged in *card-time* —
    /// `wall × cards` — so a frame scattered over k cards doesn't
    /// masquerade as a k×-faster single card and deflate the floor
    /// (`earliest_feasible` divides by the pool width again; charging
    /// wall alone would discount parallelism twice and quietly disarm
    /// the gate).  Keeps the *minimum* pace (see module docs for why
    /// min is the conservative choice).
    pub fn observe(&self, mode: Mode, frames: usize, wall: Duration, cards: usize) {
        if frames == 0 {
            return;
        }
        let total = self.est_cycles(mode).saturating_mul(frames as u64);
        let card_ps = wall
            .as_nanos()
            .saturating_mul(1000)
            .saturating_mul(cards.max(1) as u128);
        let ps = (card_ps / total as u128).min(UNCALIBRATED as u128);
        self.pace_ps.fetch_min((ps as u64).max(1), Ordering::Relaxed);
    }

    /// The observed pace floor (ps per estimated cycle), once any frame
    /// has completed.
    pub fn pace_ps(&self) -> Option<u64> {
        match self.pace_ps.load(Ordering::Relaxed) {
            UNCALIBRATED => None,
            ps => Some(ps),
        }
    }

    /// Force the pace (tests and rigs — production calibration goes
    /// through [`Self::observe`]).
    pub fn set_pace_ps(&self, ps: u64) {
        self.pace_ps.store(ps.max(1), Ordering::Relaxed);
    }

    /// Cheapest time this host has ever been observed to serve one
    /// frame of `mode` (`None` while uncalibrated).
    pub fn service_floor(&self, mode: Mode) -> Option<Duration> {
        let ps = self.pace_ps()?;
        Some(ps_to_duration(self.est_cycles(mode) as u128 * ps as u128))
    }

    /// Earliest-completion *floor* for a new frame of `mode` admitted
    /// now: the committed work ahead of it (`backlog_cycles`) plus its
    /// own cost, spread perfectly over `cards` — no queueing overhead,
    /// no stragglers, the fastest pace ever observed.  Actual completion
    /// can only be later, so `deadline < now + floor` is a sound refusal.
    /// `None` while uncalibrated (nothing is provable yet — admit).
    pub fn earliest_feasible(
        &self,
        mode: Mode,
        backlog_cycles: u64,
        cards: usize,
    ) -> Option<Duration> {
        let ps = self.pace_ps()?;
        let total = backlog_cycles as u128 + self.est_cycles(mode) as u128;
        Some(ps_to_duration(total * ps as u128 / cards.max(1) as u128))
    }
}

fn ps_to_duration(ps: u128) -> Duration {
    Duration::from_nanos((ps / 1000).min(u64::MAX as u128) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::binarray::ArrayConfig;
    use crate::isa::compile_network;
    use crate::isa::compiler::tests_support::cnn_a_quant;
    use crate::util::rng::Xoshiro256;

    fn model() -> CapacityModel {
        let mut rng = Xoshiro256::new(0xCAFE);
        let net = cnn_a_quant(&mut rng, 4);
        let prog = compile_network(&net);
        let plan = ExecutionPlan::new(ArrayConfig::new(1, 8, 2), &net, &prog);
        CapacityModel::new(&plan, &net)
    }

    #[test]
    fn high_throughput_mode_is_priced_cheaper() {
        let m = model();
        let hi = m.est_cycles(Mode::HighAccuracy);
        let lo = m.est_cycles(Mode::HighThroughput);
        assert!(hi > lo, "M=4 on M_arch=2: full accuracy is ~2× the work ({hi} vs {lo})");
        assert!(lo > 0);
    }

    #[test]
    fn uncalibrated_model_proves_nothing() {
        let m = model();
        assert_eq!(m.pace_ps(), None);
        assert_eq!(m.service_floor(Mode::HighAccuracy), None);
        assert_eq!(
            m.earliest_feasible(Mode::HighAccuracy, u64::MAX / 2, 1),
            None,
            "no observation, no refusal — whatever the backlog"
        );
    }

    #[test]
    fn pace_is_a_running_minimum() {
        let m = model();
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(10), 1);
        let first = m.pace_ps().expect("calibrated");
        // a slower observation must not raise the floor
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(40), 1);
        assert_eq!(m.pace_ps(), Some(first));
        // a faster one lowers it
        m.observe(Mode::HighAccuracy, 2, Duration::from_millis(10), 1);
        let lower = m.pace_ps().expect("calibrated");
        assert!(lower < first, "{lower} < {first}");
        // the service floor for the observed mode never exceeds the
        // cheapest per-frame wall ever seen (the conservatism guarantee)
        assert!(m.service_floor(Mode::HighAccuracy).unwrap() <= Duration::from_millis(5));
    }

    /// A frame sharded over k cards is charged k card-seconds: the same
    /// work finishing k× faster on k× the cards must not move the
    /// per-card pace floor (parallelism is already credited by
    /// `earliest_feasible`'s division — crediting it here too would
    /// disarm the gate after one wide-sharded frame).
    #[test]
    fn sharded_observation_does_not_deflate_the_pace() {
        let m = CapacityModel::fixed(1_000);
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(10), 1);
        let floor = m.pace_ps().expect("calibrated");
        // perfect 4-way sharding: wall/4 on 4 cards = the same card-time
        m.observe(Mode::HighAccuracy, 1, Duration::from_micros(2_500), 4);
        assert_eq!(m.pace_ps(), Some(floor), "same card-time, same floor");
        // real sharding has scatter/gather overhead: more card-time,
        // floor untouched
        m.observe(Mode::HighAccuracy, 1, Duration::from_millis(4), 4);
        assert_eq!(m.pace_ps(), Some(floor));
    }

    #[test]
    fn earliest_feasible_scales_with_backlog_and_cards() {
        let m = CapacityModel::fixed(1_000);
        m.set_pace_ps(1_000_000); // 1 µs per est-cycle ⇒ 1 ms per frame
        let own = m.earliest_feasible(Mode::HighAccuracy, 0, 1).unwrap();
        assert_eq!(own, Duration::from_millis(1));
        let queued = m.earliest_feasible(Mode::HighAccuracy, 9_000, 1).unwrap();
        assert_eq!(queued, Duration::from_millis(10), "9 frames ahead + own");
        let wide = m.earliest_feasible(Mode::HighAccuracy, 9_000, 4).unwrap();
        assert_eq!(wide, Duration::from_micros(2500), "perfectly parallel floor");
    }
}
